#!/usr/bin/env python3
"""Bench regression guard: compare a fresh benchkit snapshot against a
committed baseline and fail on large throughput regressions.

Usage:
    bench_guard.py BASELINE.json FRESH.json [--max-regress 0.25]

Both files are `BENCH_<group>.json` snapshots written by
`botsched::benchkit` (``BENCH_JSON=1 cargo bench --bench scaling``).
Cases are matched by name.  A case's throughput is its
``throughput_per_s`` when present, else ``1e9 / mean_ns`` (iterations
per second).  The guard fails (exit 1) when any matched case's
throughput dropped by more than ``--max-regress`` (default 25%) relative
to the baseline.  Cases present on only one side are reported but never
fail the guard (benches come and go across PRs).

Compare like with like: a baseline recorded under ``BENCH_SMOKE=1`` must
be compared against a fresh smoke run (CI does exactly that).
"""

import argparse
import json
import sys


def load_cases(path):
    with open(path) as f:
        snap = json.load(f)
    cases = {}
    for case in snap.get("cases", []):
        name = case.get("name")
        thr = case.get("throughput_per_s")
        if thr is None:
            mean_ns = case.get("mean_ns") or 0
            thr = 1e9 / mean_ns if mean_ns > 0 else None
        if name and thr:
            cases[name] = thr
    return snap.get("group", "?"), cases


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="maximum tolerated fractional throughput drop (default 0.25)")
    args = ap.parse_args()

    base_group, base = load_cases(args.baseline)
    fresh_group, fresh = load_cases(args.fresh)
    if base_group != fresh_group:
        print(f"warning: comparing group {base_group!r} against {fresh_group!r}")

    failures = []
    print(f"{'case':<44} {'baseline/s':>12} {'fresh/s':>12} {'delta':>8}")
    for name in sorted(base):
        if name not in fresh:
            print(f"{name:<44} {base[name]:>12.1f} {'missing':>12} {'-':>8}")
            continue
        b, f = base[name], fresh[name]
        delta = (f - b) / b
        flag = ""
        if -delta > args.max_regress:
            failures.append((name, delta))
            flag = "  << REGRESSION"
        print(f"{name:<44} {b:>12.1f} {f:>12.1f} {delta:>+7.1%}{flag}")
    for name in sorted(set(fresh) - set(base)):
        print(f"{name:<44} {'new':>12} {fresh[name]:>12.1f} {'-':>8}")

    if failures:
        worst = min(failures, key=lambda kv: kv[1])
        print(f"\nFAIL: {len(failures)} case(s) regressed more than "
              f"{args.max_regress:.0%} (worst: {worst[0]} at {worst[1]:+.1%})")
        return 1
    print(f"\nOK: no case regressed more than {args.max_regress:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
