# L1: Pallas kernel(s) for the paper's compute hot-spot (candidate-plan
# scoring) plus the pure-jnp correctness oracles.
from . import plan_eval, ref  # noqa: F401
