"""L1 Pallas kernel: batched execution-plan evaluation.

This is the numeric hot-spot of the paper's heuristic planner (Section IV):
every candidate move produced by BALANCE / SPLIT / REPLACE and the FIND
accept/reject test needs the per-VM execution time (eq. 5), the billed cost
(eq. 6/8) and the makespan (eq. 7) of a whole execution plan.  The rust
coordinator batches K candidate plans, aggregates each to per-(vm, app) task
sizes (lossless — exec is linear in size), and scores the batch in a single
XLA execution of this kernel.

Tiling (see DESIGN.md section Hardware-Adaptation): the grid runs over the
candidate axis K in blocks of ``block_k``; each grid step holds one
``(block_k, V, M)`` panel of sizes and gathered performance rows in VMEM,
computes the multiply-reduce on the VPU, applies the hourly ceiling
billing, and reduces cost (sum) and makespan (max) across the VM axis.
The kernel is bandwidth-bound: one pass over each input, no recompute.

Block-size choice (measured in the section-Perf pass, EXPERIMENTS.md):
``block_k = K`` (a single grid step) is shipped for both artifact sizes.
The full working set at K=64, V=128, M=8 is 2 x 2 MiB panels + 64 KiB of
per-VM rows = ~4.2 MiB, comfortably inside a TPU core's 16 MiB VMEM, and
the CPU-PJRT serving path (this repo's hot path) runs 5x faster without
the grid loop (0.12 ms vs 0.58 ms per 64-candidate call).  On a real TPU
a smaller block (8-16) would be preferred when K grows beyond VMEM,
restoring the HBM->VMEM pipeline; the BlockSpec below expresses that by
construction — only ``block_k`` changes.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU behaviour is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HOUR_SECONDS


def _plan_eval_kernel(overhead_ref, hour_ref, sizes_ref, perf_ref, rate_ref,
                      active_ref, exec_ref, cost_ref, span_ref):
    """One grid step: score ``block_k`` candidate plans.

    Refs (VMEM blocks):
      overhead_ref: f32[1, 1]            boot overhead ``o`` (broadcast).
      hour_ref:     f32[1, 1]            billing quantum in seconds.
      sizes_ref:    f32[block_k, V, M]   aggregated task sizes.
      perf_ref:     f32[block_k, V, M]   gathered perf rows.
      rate_ref:     f32[block_k, V]      hourly rate per VM slot.
      active_ref:   f32[block_k, V]      1.0 = slot used, 0.0 = padding.
      exec_ref:     f32[block_k, V]      out: eq. 5 per-VM execution time.
      cost_ref:     f32[block_k]         out: eq. 8 total billed cost.
      span_ref:     f32[block_k]         out: eq. 7 makespan.
    """
    o = overhead_ref[0, 0]
    hour = hour_ref[0, 0]
    sizes = sizes_ref[...]
    perf = perf_ref[...]
    active = active_ref[...]
    # eq. 5: exec_vm = o + sum_t P[it_vm, A_t] * size_t, masked to live slots.
    work = jnp.sum(sizes * perf, axis=-1)
    exec_ = (o + work) * active
    # eq. 6: hourly ceiling billing; inactive slots bill nothing.
    hours = jnp.ceil(exec_ / hour) * active
    # eq. 8 / eq. 7: reduce across the VM axis.
    exec_ref[...] = exec_
    cost_ref[...] = jnp.sum(hours * rate_ref[...], axis=-1)
    span_ref[...] = jnp.max(exec_, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_k",))
def plan_eval(sizes, perf, rate, active, overhead, hour=None, *, block_k=8):
    """Score a batch of candidate execution plans (pallas, interpret mode).

    Args match ``ref.plan_eval_ref``; ``overhead`` / ``hour`` may be python
    floats or f32[1, 1] arrays.  Returns ``(exec, cost, makespan)`` =
    ``(f32[K, V], f32[K], f32[K])``.
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    perf = jnp.asarray(perf, jnp.float32)
    rate = jnp.asarray(rate, jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    if hour is None:
        hour = HOUR_SECONDS
    overhead = jnp.broadcast_to(jnp.asarray(overhead, jnp.float32), (1, 1))
    hour = jnp.broadcast_to(jnp.asarray(hour, jnp.float32), (1, 1))

    k, v, m = sizes.shape
    block_k = min(block_k, k)
    if k % block_k != 0:
        raise ValueError(f"K={k} must be a multiple of block_k={block_k}")
    grid = (k // block_k,)

    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kvm_spec = pl.BlockSpec((block_k, v, m), lambda i: (i, 0, 0))
    kv_spec = pl.BlockSpec((block_k, v), lambda i: (i, 0))
    k_spec = pl.BlockSpec((block_k,), lambda i: (i,))

    return pl.pallas_call(
        _plan_eval_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, kvm_spec, kvm_spec, kv_spec,
                  kv_spec],
        out_specs=[kv_spec, k_spec, k_spec],
        out_shape=[
            jax.ShapeDtypeStruct((k, v), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(overhead, hour, sizes, perf, rate, active)
