"""Pure-jnp correctness oracles for the L1 kernels.

These are the ground-truth implementations of the paper's Section III cost
model, written with plain ``jax.numpy`` only (no pallas).  The pytest /
hypothesis suites assert the pallas kernels in ``plan_eval.py`` match these
(allclose) across shapes and dtypes, and the rust ``NativeEvaluator`` is
differentially tested against the AOT artifact that embeds the pallas
version.

Model recap (paper eq. 2-8), vectorised over a batch of K candidate plans,
V VM slots and M applications:

    exec[k,v]   = (o + sum_m S[k,v,m] * P[k,v,m]) * active[k,v]
    hours[k,v]  = ceil(exec[k,v] / hour) * active[k,v]
    cost[k]     = sum_v hours[k,v] * rate[k,v]
    makespan[k] = max_v exec[k,v]

``S[k,v,m]`` is the total size of tasks of application m assigned to VM v in
candidate k (lossless: exec is linear in size).  ``active`` masks unused VM
slots (the artifact has static shapes; rust pads).
"""

from __future__ import annotations

import jax.numpy as jnp

# Seconds per billing hour (paper eq. 6 hard-codes 3600).
HOUR_SECONDS = 3600.0


def plan_eval_ref(sizes, perf, rate, active, overhead, hour=HOUR_SECONDS):
    """Reference batched plan evaluation.

    Args:
      sizes:    f32[K, V, M] aggregated task sizes per (candidate, vm, app).
      perf:     f32[K, V, M] seconds-per-unit-size of vm's instance type for
                each app (rows gathered by the caller; padding rows are 0).
      rate:     f32[K, V]    hourly cost of each vm's instance type.
      active:   f32[K, V]    1.0 where the vm slot exists, 0.0 padding.
      overhead: f32 scalar   VM boot overhead ``o`` in seconds.
      hour:     billing quantum in seconds.

    Returns:
      (exec, cost, makespan): f32[K, V], f32[K], f32[K].
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    perf = jnp.asarray(perf, jnp.float32)
    rate = jnp.asarray(rate, jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    work = jnp.sum(sizes * perf, axis=-1)  # [K, V]
    exec_ = (overhead + work) * active
    hours = jnp.ceil(exec_ / hour) * active
    cost = jnp.sum(hours * rate, axis=-1)  # [K]
    makespan = jnp.max(exec_, axis=-1)  # [K]
    return exec_, cost, makespan


def perf_estim_ref(indicator, size, time, prior, prior_weight):
    """Reference performance-matrix estimation (paper Sec. III-A 'test runs').

    Per-cell weighted least squares of time = P * size through the origin,
    with a ridge-style pull towards ``prior`` weighted by ``prior_weight``
    (cells with no samples return the prior).

    Args:
      indicator:    f32[S, C] one-hot: sample s measured cell c = i*M + j.
      size:         f32[S]    task size of each sampled run.
      time:         f32[S]    observed execution time of each sampled run.
      prior:        f32[C]    prior estimate per cell.
      prior_weight: f32 scalar pseudo-count weight of the prior.

    Returns:
      f32[C] estimated seconds-per-unit-size per (instance, app) cell.
    """
    indicator = jnp.asarray(indicator, jnp.float32)
    size = jnp.asarray(size, jnp.float32)
    time = jnp.asarray(time, jnp.float32)
    prior = jnp.asarray(prior, jnp.float32)
    num = indicator.T @ (size * time) + prior_weight * prior
    den = indicator.T @ (size * size) + prior_weight
    return num / den
