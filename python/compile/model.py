"""L2: the JAX compute graphs that get AOT-compiled into artifacts/.

Two exported entry points, both jitted and lowered by ``aot.py`` at the
static shapes recorded in ``artifacts/meta.json``:

* ``plan_eval_model`` — batched candidate-plan scoring (calls the L1 pallas
  kernel ``kernels.plan_eval``).  This is the rust coordinator's scoring
  hot path: one XLA execution scores K candidate plans.
* ``perf_estim_model`` — performance-matrix estimation from noisy sampled
  runs (the paper's Section III-A "test runs" bootstrap), a per-cell
  weighted least-squares solve expressed as two matvecs; XLA fuses the
  whole thing into a couple of loops, so no pallas kernel is warranted.

Python in this package runs at build time only: ``make artifacts`` lowers
these functions to HLO text once, and the rust binary executes the
artifacts via PJRT with no python on the request path.
"""

from __future__ import annotations

from .kernels import plan_eval as _plan_eval_kernel
from .kernels import ref as _ref

# Static shapes baked into the shipped artifacts.  The rust runtime reads
# these from artifacts/meta.json and pads/masks its batches accordingly.
PLAN_EVAL_K = 64   # candidate plans per execution
PLAN_EVAL_V = 128  # max VM slots per plan
PLAN_EVAL_M = 8    # max applications
PLAN_EVAL_BLOCK_K = 64

# Small-batch variant: the planner's REPLACE step scores ~4-16 candidates
# at a time; padding those to K=64 wastes ~8x compute on the serving path.
# aot.py additionally lowers a K=8 artifact that the rust runtime selects
# for small batches (see EXPERIMENTS.md section Perf).
PLAN_EVAL_SMALL_K = 8

PERF_ESTIM_S = 512  # max sampled runs per estimation call
PERF_ESTIM_C = 64   # max (instance type x application) cells


def plan_eval_model(overhead, hour, sizes, perf, rate, active):
    """Score a batch of candidate plans.  Returns (exec, cost, makespan).

    Thin wrapper around the L1 pallas kernel so the kernel lowers into the
    same HLO module; argument order here fixes the artifact's parameter
    order (overhead, hour, sizes, perf, rate, active).
    """
    return tuple(
        _plan_eval_kernel.plan_eval(
            sizes, perf, rate, active, overhead, hour,
            block_k=PLAN_EVAL_BLOCK_K,
        )
    )


def perf_estim_model(indicator, size, time, prior, prior_weight):
    """Estimate the performance matrix from sampled runs.  Returns (P_hat,)."""
    return (_ref.perf_estim_ref(indicator, size, time, prior,
                                prior_weight[0]),)
