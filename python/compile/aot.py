"""AOT-lower the L2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the published xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits:
  plan_eval.hlo.txt   batched plan scoring (embeds the pallas kernel)
  perf_estim.hlo.txt  performance-matrix estimator
  meta.json           static shapes + parameter order for the rust side
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_plan_eval():
    k, v, m = model.PLAN_EVAL_K, model.PLAN_EVAL_V, model.PLAN_EVAL_M
    f32 = jax.numpy.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.plan_eval_model).lower(
        spec((1, 1), f32),      # overhead
        spec((1, 1), f32),      # hour
        spec((k, v, m), f32),   # sizes
        spec((k, v, m), f32),   # perf
        spec((k, v), f32),      # rate
        spec((k, v), f32),      # active
    )


def lower_plan_eval_small():
    k, v, m = model.PLAN_EVAL_SMALL_K, model.PLAN_EVAL_V, model.PLAN_EVAL_M
    f32 = jax.numpy.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.plan_eval_model).lower(
        spec((1, 1), f32),
        spec((1, 1), f32),
        spec((k, v, m), f32),
        spec((k, v, m), f32),
        spec((k, v), f32),
        spec((k, v), f32),
    )


def lower_perf_estim():
    s, c = model.PERF_ESTIM_S, model.PERF_ESTIM_C
    f32 = jax.numpy.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.perf_estim_model).lower(
        spec((s, c), f32),      # indicator
        spec((s,), f32),        # size
        spec((s,), f32),        # time
        spec((c,), f32),        # prior
        spec((1,), f32),        # prior_weight
    )


ARTIFACTS = {
    "plan_eval": lower_plan_eval,
    "plan_eval_small": lower_plan_eval_small,
    "perf_estim": lower_perf_estim,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file alias; writes artifacts beside it")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "hour_seconds": 3600.0,
        "plan_eval": {
            "file": "plan_eval.hlo.txt",
            "k": model.PLAN_EVAL_K,
            "v": model.PLAN_EVAL_V,
            "m": model.PLAN_EVAL_M,
            "params": ["overhead[1,1]", "hour[1,1]", "sizes[k,v,m]",
                       "perf[k,v,m]", "rate[k,v]", "active[k,v]"],
            "outputs": ["exec[k,v]", "cost[k]", "makespan[k]"],
        },
        "plan_eval_small": {
            "file": "plan_eval_small.hlo.txt",
            "k": model.PLAN_EVAL_SMALL_K,
            "v": model.PLAN_EVAL_V,
            "m": model.PLAN_EVAL_M,
            "params": ["overhead[1,1]", "hour[1,1]", "sizes[k,v,m]",
                       "perf[k,v,m]", "rate[k,v]", "active[k,v]"],
            "outputs": ["exec[k,v]", "cost[k]", "makespan[k]"],
        },
        "perf_estim": {
            "file": "perf_estim.hlo.txt",
            "s": model.PERF_ESTIM_S,
            "c": model.PERF_ESTIM_C,
            "params": ["indicator[s,c]", "size[s]", "time[s]", "prior[c]",
                       "prior_weight[1]"],
            "outputs": ["p_hat[c]"],
        },
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
