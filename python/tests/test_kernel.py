"""L1 correctness: pallas plan_eval kernel vs the pure-jnp oracle.

This is the core correctness signal for the numeric layer: the hypothesis
sweep drives shapes, block sizes, masks and value ranges through the pallas
kernel (interpret mode) and asserts allclose against ``ref.plan_eval_ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.plan_eval import plan_eval
from compile.kernels.ref import HOUR_SECONDS, plan_eval_ref


def _rand_case(rng, k, v, m, density=0.8, size_hi=50.0, perf_hi=25.0):
    sizes = rng.uniform(0.0, size_hi, (k, v, m)).astype(np.float32)
    perf = rng.uniform(1.0, perf_hi, (k, v, m)).astype(np.float32)
    rate = rng.uniform(1.0, 10.0, (k, v)).astype(np.float32)
    active = (rng.random((k, v)) < density).astype(np.float32)
    return sizes, perf, rate, active


def _assert_matches(sizes, perf, rate, active, overhead, hour=HOUR_SECONDS,
                    block_k=8):
    e_k, c_k, s_k = plan_eval(sizes, perf, rate, active, overhead, hour,
                              block_k=block_k)
    e_r, c_r, s_r = plan_eval_ref(sizes, perf, rate, active, overhead, hour)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-3)


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    _assert_matches(*_rand_case(rng, 16, 12, 3), overhead=30.0, block_k=4)


def test_artifact_shapes():
    """The exact static shapes baked into artifacts/plan_eval.hlo.txt."""
    from compile import model
    rng = np.random.default_rng(1)
    case = _rand_case(rng, model.PLAN_EVAL_K, model.PLAN_EVAL_V,
                      model.PLAN_EVAL_M)
    _assert_matches(*case, overhead=42.0, block_k=model.PLAN_EVAL_BLOCK_K)


def test_all_inactive_is_zero():
    k, v, m = 8, 4, 2
    zeros = np.zeros((k, v), np.float32)
    sizes = np.ones((k, v, m), np.float32)
    perf = np.ones((k, v, m), np.float32)
    rate = np.ones((k, v), np.float32)
    e, c, s = plan_eval(sizes, perf, rate, zeros, 100.0)
    assert np.all(np.asarray(e) == 0.0)
    assert np.all(np.asarray(c) == 0.0)
    assert np.all(np.asarray(s) == 0.0)


def test_empty_vm_bills_boot_hour():
    """A provisioned VM with no tasks still bills ceil(o/3600) hours (paper:
    'the overhead is paid for by the user')."""
    k, v, m = 8, 2, 1
    sizes = np.zeros((k, v, m), np.float32)
    perf = np.ones((k, v, m), np.float32)
    rate = np.full((k, v), 5.0, np.float32)
    active = np.ones((k, v), np.float32)
    _, c, _ = plan_eval(sizes, perf, rate, active, 30.0)
    np.testing.assert_allclose(np.asarray(c), 2 * 5.0)  # 1 hour x 2 VMs


def test_hour_boundary_exact():
    """exec exactly on the hour must bill exactly that many hours."""
    k, v, m = 8, 1, 1
    sizes = np.full((k, v, m), 3600.0, np.float32)  # exec = 3600 * 1
    perf = np.ones((k, v, m), np.float32)
    rate = np.ones((k, v), np.float32)
    active = np.ones((k, v), np.float32)
    _, c, _ = plan_eval(sizes, perf, rate, active, 0.0)
    np.testing.assert_allclose(np.asarray(c), 1.0)
    _, c2, _ = plan_eval(sizes, perf, rate, active, 1.0)  # one second over
    np.testing.assert_allclose(np.asarray(c2), 2.0)


def test_block_k_must_divide():
    rng = np.random.default_rng(2)
    case = _rand_case(rng, 6, 3, 2)
    with pytest.raises(ValueError):
        plan_eval(*case, 0.0, block_k=4)


@settings(max_examples=40, deadline=None)
@given(
    k_blocks=st.integers(1, 4),
    block_k=st.sampled_from([1, 2, 4, 8]),
    v=st.integers(1, 24),
    m=st.integers(1, 6),
    overhead=st.floats(0.0, 500.0),
    hour=st.sampled_from([60.0, 900.0, 3600.0]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(k_blocks, block_k, v, m, overhead, hour, density,
                          seed):
    """Shape/mask/value sweep: pallas kernel == oracle everywhere."""
    rng = np.random.default_rng(seed)
    k = k_blocks * block_k
    case = _rand_case(rng, k, v, m, density=density)
    _assert_matches(*case, overhead=overhead, hour=hour, block_k=block_k)


@settings(max_examples=15, deadline=None)
@given(
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_value_range_sweep(scale, seed):
    """Magnitude sweep: tiny and large sizes behave identically to ref."""
    rng = np.random.default_rng(seed)
    sizes, perf, rate, active = _rand_case(rng, 8, 8, 3, size_hi=50.0 * scale)
    _assert_matches(sizes, perf, rate, active, overhead=10.0)
