"""AOT pipeline: lowered HLO text is parseable-shaped and meta is consistent."""

import json
import os

from compile import aot, model


def test_lower_plan_eval_hlo_text():
    text = aot.to_hlo_text(aot.lower_plan_eval())
    assert text.startswith("HloModule")
    k, v, m = model.PLAN_EVAL_K, model.PLAN_EVAL_V, model.PLAN_EVAL_M
    assert f"f32[{k},{v},{m}]" in text
    assert f"f32[{k},{v}]" in text
    assert f"f32[{k}]" in text


def test_lower_plan_eval_small_hlo_text():
    text = aot.to_hlo_text(aot.lower_plan_eval_small())
    assert text.startswith("HloModule")
    k, v, m = model.PLAN_EVAL_SMALL_K, model.PLAN_EVAL_V, model.PLAN_EVAL_M
    assert f"f32[{k},{v},{m}]" in text


def test_lower_perf_estim_hlo_text():
    text = aot.to_hlo_text(aot.lower_perf_estim())
    assert text.startswith("HloModule")
    s, c = model.PERF_ESTIM_S, model.PERF_ESTIM_C
    assert f"f32[{s},{c}]" in text


def test_artifacts_dir_consistent_if_built():
    """If `make artifacts` has run, meta.json must match the compiled shapes."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    meta_path = os.path.join(root, "meta.json")
    if not os.path.exists(meta_path):
        return  # artifacts not built in this checkout; covered by make test
    meta = json.load(open(meta_path))
    pe = meta["plan_eval"]
    assert (pe["k"], pe["v"], pe["m"]) == (
        model.PLAN_EVAL_K, model.PLAN_EVAL_V, model.PLAN_EVAL_M)
    assert os.path.exists(os.path.join(root, pe["file"]))
    small = meta["plan_eval_small"]
    assert small["k"] == model.PLAN_EVAL_SMALL_K
    assert os.path.exists(os.path.join(root, small["file"]))
    assert meta["hour_seconds"] == 3600.0
