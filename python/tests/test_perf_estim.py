"""L2 correctness: perf-matrix estimator recovers P from sampled runs."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import perf_estim_ref


def _one_hot(cells, c):
    ind = np.zeros((len(cells), c), np.float32)
    ind[np.arange(len(cells)), cells] = 1.0
    return ind


def test_exact_recovery_noiseless():
    """With noiseless samples and zero prior weight, P_hat == P exactly."""
    rng = np.random.default_rng(0)
    n_cells = 12
    p_true = rng.uniform(5.0, 25.0, n_cells).astype(np.float32)
    cells = rng.integers(0, n_cells, 200)
    size = rng.uniform(1.0, 5.0, 200).astype(np.float32)
    time = (p_true[cells] * size).astype(np.float32)
    p_hat = np.asarray(perf_estim_ref(_one_hot(cells, n_cells), size, time,
                                      np.zeros(n_cells, np.float32), 0.0))
    # every cell sampled at least once with prob ~1; guard anyway
    sampled = np.bincount(cells, minlength=n_cells) > 0
    np.testing.assert_allclose(p_hat[sampled], p_true[sampled], rtol=1e-4)


def test_unsampled_cells_return_prior():
    n_cells = 8
    prior = np.full(n_cells, 7.0, np.float32)
    ind = np.zeros((4, n_cells), np.float32)
    ind[:, 0] = 1.0  # only cell 0 sampled
    size = np.ones(4, np.float32)
    time = np.full(4, 3.0, np.float32)
    p_hat = np.asarray(perf_estim_ref(ind, size, time, prior, 1.0))
    np.testing.assert_allclose(p_hat[1:], prior[1:])
    assert 2.0 < p_hat[0] < 7.0  # pulled between data (3.0) and prior (7.0)


@settings(max_examples=25, deadline=None)
@given(
    n_cells=st.integers(1, 32),
    n_samples=st.integers(1, 300),
    noise=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_noisy_recovery_within_noise(n_cells, n_samples, noise, seed):
    """Relative error of well-sampled cells is bounded by the noise level."""
    rng = np.random.default_rng(seed)
    p_true = rng.uniform(5.0, 25.0, n_cells).astype(np.float32)
    cells = rng.integers(0, n_cells, n_samples)
    size = rng.uniform(1.0, 5.0, n_samples).astype(np.float32)
    time = (p_true[cells] * size *
            (1.0 + rng.normal(0.0, noise, n_samples))).astype(np.float32)
    p_hat = np.asarray(perf_estim_ref(_one_hot(cells, n_cells), size, time,
                                      p_true, 1e-6))
    counts = np.bincount(cells, minlength=n_cells)
    well = counts >= 10
    if well.any():
        rel = np.abs(p_hat[well] - p_true[well]) / p_true[well]
        assert np.all(rel < max(4 * noise, 1e-4) + 3e-2)
