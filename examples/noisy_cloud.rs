//! Robustness on an unreliable cloud: jitter, VM failures, dynamic
//! re-planning and non-clairvoyant execution.
//!
//! ```bash
//! cargo run --release --example noisy_cloud
//! ```
//!
//! Four scenarios over the paper workload:
//!   A. clean cloud      — simulation must match the plan exactly;
//!   B. jittery cloud    — 10% multiplicative task noise;
//!   C. failing cloud    — exponential VM lifetimes + closed-loop
//!                         re-planning campaigns (Sec. VI "dynamic");
//!   D. non-clairvoyant  — sizes unknown; plan on sampled estimates,
//!                         dispatch online (Sec. VI "non-clairvoyant").

use botsched::cloudsim::{
    run_campaign, CampaignSpec, NoiseModel, SimConfig, Simulator,
};
use botsched::scheduler::nonclairvoyant::OnlineDispatcher;
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::workload::paper::table1_system;

fn main() -> anyhow::Result<()> {
    let sys = table1_system(0.0);
    let budget = 80.0;
    let registry = PolicyRegistry::builtin();
    let plan = registry.solve("budget-heuristic", &sys, &SolveRequest::new(budget))?;
    println!(
        "plan @ budget {budget}: makespan {:.1}s cost {} on {} VMs\n",
        plan.score.makespan,
        plan.score.cost,
        plan.plan.n_vms()
    );

    // --- A: clean cloud --------------------------------------------------
    let clean = Simulator::run_plan(&sys, &plan.plan, &SimConfig::default());
    println!(
        "A clean    : makespan {:>7.1}s cost {:>3} (drift {:+.4}%)",
        clean.makespan,
        clean.cost,
        (clean.makespan / plan.score.makespan - 1.0) * 100.0
    );

    // --- B: jitter -------------------------------------------------------
    for seed in [1u64, 2, 3] {
        let cfg = SimConfig { noise: NoiseModel::jitter(0.10), seed };
        let sim = Simulator::run_plan(&sys, &plan.plan, &cfg);
        assert!(sim.all_done());
        println!(
            "B jitter#{seed}: makespan {:>7.1}s cost {:>3} (drift {:+.2}%)",
            sim.makespan,
            sim.cost,
            (sim.makespan / plan.score.makespan - 1.0) * 100.0
        );
    }

    // --- C: failures + closed-loop campaign ------------------------------
    println!();
    // Failures waste billed hours and jitter can push VMs over hour
    // boundaries, so recovery needs slack beyond the clean-cloud cost
    // (80).  Best-effort mode always finishes the workload (and may
    // overshoot); strict mode never overshoots (and may stop early).
    for (lifetime, reserve, strict) in
        [(4000.0, 0.3, false), (2000.0, 0.5, false), (2000.0, 0.5, true)]
    {
        let mut spec = CampaignSpec::new(240.0).with_reserve(reserve);
        if strict {
            spec = spec.strict();
        }
        spec.sim.noise = NoiseModel::with_failures(0.05, lifetime);
        spec.sim.seed = 11;
        let out = run_campaign(&sys, &spec);
        println!(
            "C fail(mean {lifetime:>5.0}s, reserve {reserve}, {}): rounds {} \
             wall {:>8.1}s spent {:>5.1} complete {} within_budget {}",
            if strict { "strict     " } else { "best-effort" },
            out.rounds.len(),
            out.wall_clock,
            out.spent,
            out.complete,
            out.within_budget
        );
    }

    // --- D: non-clairvoyant ----------------------------------------------
    // Plan the fleet on a 10%-sample surrogate (the "nonclairvoyant"
    // policy), then dispatch online.
    println!();
    let nc_req = SolveRequest::new(budget).with_sample_frac(0.10).with_seed(7);
    let fleet_plan = registry.solve("nonclairvoyant", &sys, &nc_req)?;
    let fleet: Vec<_> = fleet_plan.plan.vms.iter().map(|vm| vm.it).collect();
    let dispatcher = OnlineDispatcher::new(&sys);
    let sim = Simulator::run_online(&sys, &fleet, dispatcher, &SimConfig::default());
    assert!(sim.all_done());
    println!(
        "D nonclair : fleet of {} VMs from sampled estimates; online dispatch \
         makespan {:>7.1}s cost {:>3} (clairvoyant pinned: {:>7.1}s)",
        fleet.len(),
        sim.makespan,
        sim.cost,
        plan.score.makespan
    );
    let overhead_pct = (sim.makespan / plan.score.makespan - 1.0) * 100.0;
    println!(
        "             non-clairvoyance overhead: {overhead_pct:+.1}% \
         (online self-scheduling recovers most of the gap)"
    );
    Ok(())
}
