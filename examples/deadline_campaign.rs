//! Deadline-constrained cost minimisation (the paper's Sec. VI future
//! work), driven through the unified `Policy` API: the `"deadline"`
//! policy from the registry.
//!
//! ```bash
//! cargo run --release --example deadline_campaign
//! ```
//!
//! A research group must finish its analysis campaign before a
//! reporting deadline and wants to spend as little as possible.  For
//! each deadline the policy's bisection search finds the cheapest
//! heuristic plan meeting it; the plan is then executed on the simulated
//! cloud to confirm the deadline holds end-to-end.  (The cheapest
//! feasible plan for this workload already runs in ~58 min, so the
//! interesting deadlines are below one hour.)

use botsched::cloudsim::{SimConfig, Simulator};
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::workload::paper::table1_system;

fn main() -> anyhow::Result<()> {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    println!("workload: 3 apps x 250 tasks (paper Table I catalogue)\n");
    println!("{:>9} {:>10} {:>10} {:>10} {:>8} {:>7}",
        "deadline", "budget", "cost", "makespan", "vms", "probes");

    for hours in [1.0, 0.75, 0.55] {
        let deadline = hours * 3600.0;
        // The request's budget is the spending cap the search may not
        // exceed; `effective_budget` reports what the plan actually needed.
        let req = SolveRequest::new(300.0).with_deadline(deadline);
        let out = registry.solve("deadline", &sys, &req)?;
        if !out.feasible {
            println!("{:>8.1}h {:>10}", hours, "impossible");
            continue;
        }
        // Confirm on the simulator.
        let sim = Simulator::run_plan(&sys, &out.plan, &SimConfig::default());
        assert!(sim.all_done());
        assert!(
            sim.makespan <= deadline + 1e-6,
            "simulated {:.1}s blew the {:.1}s deadline",
            sim.makespan,
            deadline
        );
        println!(
            "{:>8.1}h {:>10.2} {:>10} {:>9.1}s {:>8} {:>7}",
            hours,
            out.effective_budget,
            out.score.cost,
            sim.makespan,
            out.plan.n_vms(),
            out.probes
        );
    }

    println!(
        "\nLooser deadlines cost less: the search trades completion time \
         for money exactly as Sec. VI envisions."
    );
    Ok(())
}
