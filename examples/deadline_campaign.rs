//! Deadline-constrained cost minimisation (the paper's Sec. VI future
//! work, implemented in `scheduler::deadline`).
//!
//! ```bash
//! cargo run --release --example deadline_campaign
//! ```
//!
//! A research group must finish its analysis campaign before a
//! reporting deadline and wants to spend as little as possible.  For
//! each deadline the bisection search finds the cheapest heuristic plan
//! meeting it; the plan is then executed on the simulated cloud to
//! confirm the deadline holds end-to-end.  (The cheapest feasible plan
//! for this workload already runs in ~58 min, so the interesting
//! deadlines are below one hour.)

use botsched::cloudsim::{SimConfig, Simulator};
use botsched::scheduler::deadline::min_cost_for_deadline;
use botsched::workload::paper::table1_system;

fn main() -> anyhow::Result<()> {
    let sys = table1_system(0.0);
    println!("workload: 3 apps x 250 tasks (paper Table I catalogue)\n");
    println!("{:>9} {:>10} {:>10} {:>10} {:>8} {:>7}",
        "deadline", "budget", "cost", "makespan", "vms", "probes");

    for hours in [1.0, 0.75, 0.55] {
        let deadline = hours * 3600.0;
        let search = min_cost_for_deadline(&sys, deadline, 300.0);
        match &search.report {
            None => println!("{:>8.1}h {:>10}", hours, "impossible"),
            Some(r) => {
                // Confirm on the simulator.
                let sim = Simulator::run_plan(&sys, &r.plan, &SimConfig::default());
                assert!(sim.all_done());
                assert!(
                    sim.makespan <= deadline + 1e-6,
                    "simulated {:.1}s blew the {:.1}s deadline",
                    sim.makespan,
                    deadline
                );
                println!(
                    "{:>8.1}h {:>10.2} {:>10} {:>9.1}s {:>8} {:>7}",
                    hours,
                    search.budget,
                    r.score.cost,
                    sim.makespan,
                    r.plan.n_vms(),
                    search.probes
                );
            }
        }
    }

    println!(
        "\nLooser deadlines cost less: the search trades completion time \
         for money exactly as Sec. VI envisions."
    );
    Ok(())
}
