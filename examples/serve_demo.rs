//! Coordinator demo: start the leader, drive it with concurrent clients
//! over the JSON-line TCP protocol, print the metrics, shut down.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```
//!
//! This is the serving deployment in miniature.  Connections land on a
//! small fixed pool of readiness-driven workers (non-blocking sockets
//! over `poll(2)` — idle clients cost no threads), requests execute on
//! a bounded executor pool, and every job flows through the sharded
//! engine's *bounded priority queues*: `submit` (and sync
//! campaign/sweep) may carry `"priority"` (0..=9) and `"deadline_ms"`,
//! and a shard at its `--max-backlog` bound answers
//! `{"ok":false,"error":"busy","shard":…,"backlog":…}` instead of
//! queueing without limit.  The XLA artifact (when built) scores every
//! candidate plan and the dynamic batcher coalesces scoring traffic
//! from concurrent planning requests; the protocol surface covers
//! plan / sweep / simulate / campaign / estimate plus the async job ops.

use std::time::Duration;

use botsched::coordinator::server::request;
use botsched::coordinator::{Coordinator, CoordinatorConfig};

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: true,
        batching: true,
        batch_wait: Duration::from_millis(2),
        ..CoordinatorConfig::default()
    })?;
    let addr = coord.local_addr;
    println!("coordinator up on {addr}\n");

    // Discover the policy surface first: anything listed here can be
    // named in a "policy" field on plan/simulate/campaign requests.
    let pols = request(&addr, r#"{"op":"list_policies"}"#)?;
    let names: Vec<&str> = pols
        .get("policies")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|p| p.get("name").and_then(|n| n.as_str()))
        .collect();
    println!("policies: {}\n", names.join(", "));

    // Concurrent planning clients (a campaign team sweeping budgets).
    let mut handles = Vec::new();
    for budget in [60, 65, 70, 75, 80, 85] {
        handles.push(std::thread::spawn(move || {
            let line =
                format!(r#"{{"op":"plan","budget":{budget},"policy":"budget-heuristic"}}"#);
            (budget, request(&addr, &line).expect("plan reply"))
        }));
    }
    for h in handles {
        let (budget, reply) = h.join().unwrap();
        println!(
            "plan @ {budget}: makespan {:>7.1}s cost {:>5} feasible {} vms {}",
            reply.get("makespan").unwrap().as_f64().unwrap(),
            reply.get("cost").unwrap().as_f64().unwrap(),
            reply.get("feasible").unwrap().as_bool().unwrap(),
            reply.get("n_vms").unwrap().as_f64().unwrap(),
        );
    }

    // Any registered policy is one "policy" field away — here the
    // deadline search (cheapest plan finishing within an hour).
    let dl = request(
        &addr,
        r#"{"op":"plan","budget":300,"policy":"deadline","deadline":3600}"#,
    )?;
    println!(
        "\ndeadline 1h: cost {} makespan {:.1}s (effective budget {:.2})",
        dl.get("cost").unwrap().as_f64().unwrap(),
        dl.get("makespan").unwrap().as_f64().unwrap(),
        dl.get("effective_budget").unwrap().as_f64().unwrap(),
    );

    // One simulation and one failure campaign through the same socket.
    let sim = request(
        &addr,
        r#"{"op":"simulate","budget":80,"noise":{"task_sigma":0.08},"seed":5}"#,
    )?;
    println!(
        "\nsimulate @ 80 (jitter 8%): makespan {:.1}s cost {} completed {}",
        sim.get("makespan").unwrap().as_f64().unwrap(),
        sim.get("cost").unwrap().as_f64().unwrap(),
        sim.get("completed").unwrap().as_f64().unwrap(),
    );
    let camp = request(
        &addr,
        r#"{"op":"campaign","budget":200,"noise":{"mean_lifetime":3000},"seed":2,"max_rounds":6}"#,
    )?;
    println!(
        "campaign @ 200 (failing cloud): rounds {} wall {:.1}s spent {} complete {}",
        camp.get("rounds").unwrap().as_f64().unwrap(),
        camp.get("wall_clock").unwrap().as_f64().unwrap(),
        camp.get("spent").unwrap().as_f64().unwrap(),
        camp.get("complete").unwrap().as_bool().unwrap(),
    );

    // Estimate op exercises the perf_estim artifact.
    let est = request(&addr, r#"{"op":"estimate_perf","per_cell":15,"noise":{"task_sigma":0.05}}"#)?;
    println!(
        "estimate_perf: {} samples, max rel err {:.2}%",
        est.get("samples").unwrap().as_f64().unwrap(),
        est.get("max_rel_error").unwrap().as_f64().unwrap() * 100.0,
    );

    // Async job flow: submit a campaign with an explicit queue
    // placement (priority 0..=9 plus a relative deadline_ms; both ride
    // on the outer submit object) and poll it to completion.  Under
    // saturation this submit would come back as
    // {"ok":false,"error":"busy","shard":…,"backlog":…} instead.
    let sub = request(
        &addr,
        r#"{"op":"submit","priority":7,"deadline_ms":30000,"job":{"op":"campaign","budget":220,"noise":{"mean_lifetime":2500},"seed":9,"max_rounds":6}}"#,
    )?;
    let job_id = sub.get("job_id").unwrap().as_str().unwrap().to_string();
    println!("
submitted campaign as {job_id}");
    loop {
        let st = request(&addr, &format!(r#"{{"op":"status","job_id":"{job_id}"}}"#))?;
        let state = st.path(&["job", "state"]).unwrap().as_str().unwrap().to_string();
        if state == "done" {
            let result = st.path(&["job", "result"]).unwrap();
            println!(
                "job {job_id} done: rounds {} complete {}",
                result.get("rounds").unwrap().as_f64().unwrap(),
                result.get("complete").unwrap().as_bool().unwrap(),
            );
            break;
        }
        if state == "failed" {
            println!("job failed: {}", st.path(&["job", "error"]).unwrap());
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // Metrics + shutdown: stats now carries per-shard queue gauges
    // (depth / high_water / rejected) and queue-wait percentiles next
    // to the request counters.
    let stats = request(&addr, r#"{"op":"stats"}"#)?;
    println!("\ncoordinator stats: {}", stats.get("stats").unwrap());
    println!("engine gauges: {}", stats.get("engine").unwrap());
    request(&addr, r#"{"op":"shutdown"}"#)?;
    coord.wait();
    println!("coordinator stopped cleanly");
    Ok(())
}
