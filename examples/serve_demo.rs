//! Coordinator demo: start the leader, drive it with concurrent typed
//! clients over the v2 wire API, print the metrics, shut down.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```
//!
//! This is the serving deployment in miniature — and the tour of the
//! typed client: every request below is an [`api`] struct encoded by
//! [`Client`], every reply a typed response, and failures (including the
//! admission-control `busy` rejection with its `retry_after_ms` hint)
//! come back as typed `ClientError`s.  Connections land on a small fixed
//! pool of readiness-driven workers (idle clients cost no threads),
//! requests execute on a bounded executor pool, and every job flows
//! through the sharded engine's bounded priority queues.  The XLA
//! artifact (when built) scores every candidate plan; the protocol
//! surface covers plan / sweep / simulate / campaign / estimate plus
//! the async job ops, `list_scenarios` and the v2 `describe` schema.

use std::time::Duration;

use botsched::coordinator::api::{
    CampaignRequest, CampaignResponse, EstimatePerfRequest, NoiseSpec, Placement, PlanRequest,
    Request, SimulateRequest, SystemRef,
};
use botsched::coordinator::{Client, Coordinator, CoordinatorConfig};

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: true,
        batching: true,
        batch_wait: Duration::from_millis(2),
        ..CoordinatorConfig::default()
    })?;
    let addr = coord.local_addr;
    println!("coordinator up on {addr}\n");

    let mut client = Client::connect(&addr)?;

    // Discover the surface first: policies, scenarios, and (v2) the
    // machine-readable op schema.
    let policies = client.list_policies()?;
    let names: Vec<&str> = policies.iter().map(|p| p.name.as_str()).collect();
    println!("policies: {}", names.join(", "));
    let scenarios = client.list_scenarios()?;
    let names: Vec<&str> = scenarios.iter().map(|s| s.name.as_str()).collect();
    println!("scenarios: {}", names.join(", "));
    let schema = client.describe()?;
    println!(
        "describe: {} ops, error codes {}\n",
        schema.get("ops").unwrap().as_arr().unwrap().len(),
        schema.get("error_codes").unwrap(),
    );

    // Concurrent planning clients (a campaign team sweeping budgets).
    let mut handles = Vec::new();
    for budget in [60.0, 65.0, 70.0, 75.0, 80.0, 85.0] {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).expect("connect");
            let plan = c
                .plan(&PlanRequest::new(budget).with_policy("budget-heuristic"))
                .expect("plan reply");
            (budget, plan)
        }));
    }
    for h in handles {
        let (budget, plan) = h.join().unwrap();
        println!(
            "plan @ {budget}: makespan {:>7.1}s cost {:>5} feasible {} vms {}",
            plan.makespan,
            plan.cost,
            plan.feasible,
            plan.vms.len(),
        );
    }

    // Any registered policy is one typed field away — here the deadline
    // search (cheapest plan finishing within an hour).
    let dl = client.plan(&PlanRequest::new(300.0).with_policy("deadline").with_deadline(3600.0))?;
    println!(
        "\ndeadline 1h: cost {} makespan {:.1}s (effective budget {:.2})",
        dl.cost, dl.makespan, dl.effective_budget,
    );

    // A named scenario replaces an inline system object.
    let ht = client.plan(
        &PlanRequest::new(500.0).with_target(SystemRef::scenario("heavy-tail")),
    )?;
    println!(
        "scenario heavy-tail @ 500: makespan {:.1}s over {} VMs",
        ht.makespan,
        ht.vms.len()
    );

    // One simulation and one failure campaign through the same socket.
    let sim = client.simulate(
        &SimulateRequest::new(80.0)
            .with_noise(NoiseSpec { task_sigma: Some(0.08), ..NoiseSpec::default() })
            .with_seed(5),
    )?;
    println!(
        "\nsimulate @ 80 (jitter 8%): makespan {:.1}s cost {} completed {}",
        sim.makespan, sim.cost, sim.completed,
    );
    let camp = client.campaign(
        &CampaignRequest::new(200.0)
            .with_noise(NoiseSpec { mean_lifetime: Some(3000.0), ..NoiseSpec::default() })
            .with_seed(2)
            .with_max_rounds(6),
    )?;
    if let CampaignResponse::Single { rounds, wall_clock, spent, complete, .. } = camp {
        println!(
            "campaign @ 200 (failing cloud): rounds {rounds} wall {wall_clock:.1}s \
             spent {spent} complete {complete}"
        );
    }

    // Estimate op exercises the perf_estim artifact.
    let est = client.estimate_perf(&EstimatePerfRequest {
        per_cell: Some(15),
        noise: Some(NoiseSpec { task_sigma: Some(0.05), ..NoiseSpec::default() }),
        ..EstimatePerfRequest::default()
    })?;
    println!(
        "estimate_perf: {} samples, max rel err {:.2}%",
        est.samples,
        est.max_rel_error * 100.0,
    );

    // Async job flow: submit a campaign with an explicit queue placement
    // (priority 0..=9 plus a relative deadline_ms) and poll it to
    // completion.  Under saturation submit_with_retry would sleep the
    // server's retry_after_ms hint and try again.
    let job = Request::Campaign(
        CampaignRequest::new(220.0)
            .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
            .with_seed(9)
            .with_max_rounds(6),
    );
    let placement = Placement { priority: Some(7), deadline_ms: Some(30_000) };
    let job_id = client.submit_with_retry(&job, placement, 3)?;
    println!("\nsubmitted campaign as {job_id}");
    let status = client.wait_job(&job_id, Duration::from_millis(20), Duration::from_secs(300))?;
    match status.state.as_str() {
        "done" => {
            let result = status.result.expect("done jobs carry their reply");
            let camp = CampaignResponse::decode(&result).expect("campaign body");
            if let CampaignResponse::Single { rounds, complete, .. } = camp {
                println!("job {job_id} done: rounds {rounds} complete {complete}");
            }
        }
        other => println!("job {job_id} ended as {other}: {:?}", status.error),
    }

    // Metrics + shutdown: stats carries per-shard queue gauges
    // (depth / high_water / rejected) and queue-wait percentiles next
    // to the request counters.
    let stats = client.stats()?;
    println!("\ncoordinator stats: {}", stats.stats);
    println!(
        "engine gauges: {} shards, backlog bound {}, per-shard {:?}",
        stats.engine.shards,
        stats.engine.max_backlog,
        stats
            .engine
            .shard_stats
            .iter()
            .map(|s| (s.depth, s.high_water, s.rejected))
            .collect::<Vec<_>>(),
    );
    client.shutdown()?;
    coord.wait();
    println!("coordinator stopped cleanly");
    Ok(())
}
