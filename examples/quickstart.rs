//! Quickstart: plan a multi-BoT workload under a budget in ~40 lines —
//! the canonical usage sample for the unified `Policy` API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The flow is always the same three steps:
//!   1. describe the problem with a [`SolveRequest`] builder,
//!   2. resolve a policy by name from the [`PolicyRegistry`],
//!   3. read the unified [`SolveOutcome`] (plan, makespan, cost,
//!      feasibility) — identical shape for every policy.

use botsched::cloudsim::{SimConfig, Simulator};
use botsched::model::SystemBuilder;
use botsched::scheduler::{PolicyRegistry, SolveRequest};

fn main() -> anyhow::Result<()> {
    // A "video transcode" app (CPU-hungry) and a "genome index" app
    // (memory-hungry), and a three-type cloud catalogue.
    let sys = SystemBuilder::new()
        .app("transcode", (1..=60).map(|i| 1.0 + (i % 5) as f64).collect())
        .app("genome-index", (1..=40).map(|i| 2.0 + (i % 3) as f64).collect())
        .instance_type("small", 4.0, vec![30.0, 34.0])
        .instance_type("cpu-opt", 9.0, vec![11.0, 21.0])
        .instance_type("mem-opt", 9.0, vec![16.0, 9.0])
        .overhead(45.0) // 45s boot time
        .build()?;

    let registry = PolicyRegistry::builtin();

    for budget in [25.0, 60.0] {
        println!("=== budget ${budget} ===");
        // One request serves every policy; knobs a policy does not use
        // are ignored by it.
        let req = SolveRequest::new(budget).with_seed(7);

        let mut ours = None;
        for name in ["budget-heuristic", "mi", "mp", "multistart"] {
            let out = registry.solve(name, &sys, &req)?;
            println!(
                "{name:<16}: makespan {:>7.1}s  cost {:>5}  feasible {}",
                out.score.makespan, out.score.cost, out.feasible
            );
            if name == "budget-heuristic" {
                ours = Some(out);
            }
        }

        // Execute the heuristic plan on the simulated cloud.
        let ours = ours.expect("heuristic ran above");
        let sim = Simulator::run_plan(&sys, &ours.plan, &SimConfig::default());
        assert!(sim.all_done());
        println!(
            "simulated       : makespan {:>7.1}s  cost {:>5}  ({} tasks on {} VMs)\n",
            sim.makespan,
            sim.cost,
            sim.completed.len(),
            ours.plan.n_vms()
        );
    }
    Ok(())
}
