//! Quickstart: plan a multi-BoT workload under a budget in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a small two-application system, plans it with the paper's
//! heuristic at two budgets, compares against the MI/MP baselines, and
//! executes the chosen plan on the simulated cloud.

use botsched::cloudsim::{SimConfig, Simulator};
use botsched::model::SystemBuilder;
use botsched::scheduler::{maximise_parallelism, minimise_individual, Planner};

fn main() -> anyhow::Result<()> {
    // A "video transcode" app (CPU-hungry) and a "genome index" app
    // (memory-hungry), and a three-type cloud catalogue.
    let sys = SystemBuilder::new()
        .app("transcode", (1..=60).map(|i| 1.0 + (i % 5) as f64).collect())
        .app("genome-index", (1..=40).map(|i| 2.0 + (i % 3) as f64).collect())
        .instance_type("small", 4.0, vec![30.0, 34.0])
        .instance_type("cpu-opt", 9.0, vec![11.0, 21.0])
        .instance_type("mem-opt", 9.0, vec![16.0, 9.0])
        .overhead(45.0) // 45s boot time
        .build()?;

    for budget in [25.0, 60.0] {
        println!("=== budget ${budget} ===");
        let ours = Planner::new(&sys).find(budget);
        println!(
            "heuristic: makespan {:>7.1}s  cost {:>5}  feasible {}",
            ours.score.makespan, ours.score.cost, ours.feasible
        );
        for (name, plan) in [
            ("MI       ", minimise_individual(&sys, budget)),
            ("MP       ", maximise_parallelism(&sys, budget)),
        ] {
            let s = plan.score(&sys);
            println!(
                "{name}: makespan {:>7.1}s  cost {:>5}  feasible {}",
                s.makespan,
                s.cost,
                s.satisfies(budget)
            );
        }

        // Execute the heuristic plan on the simulated cloud.
        let sim = Simulator::run_plan(&sys, &ours.plan, &SimConfig::default());
        assert!(sim.all_done());
        println!(
            "simulated: makespan {:>7.1}s  cost {:>5}  ({} tasks on {} VMs)\n",
            sim.makespan,
            sim.cost,
            sim.completed.len(),
            ours.plan.n_vms()
        );
    }
    Ok(())
}
