//! END-TO-END DRIVER: full reproduction of the paper's evaluation
//! (Section V) through every layer of the system.
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_repro
//! ```
//!
//! 1. loads the AOT-compiled XLA plan evaluator (pallas kernel inside)
//!    and wraps it in the coordinator's dynamic batcher;
//! 2. bootstraps the performance matrix from simulated "test runs"
//!    through the perf_estim artifact (Sec. III-A's suggestion);
//! 3. runs the full Fig. 1 / Fig. 2 budget sweep (heuristic vs MI vs MP)
//!    with all candidate scoring going through XLA;
//! 4. executes every feasible plan on the discrete-event cloud simulator
//!    and verifies the analytic prediction;
//! 5. prints Table I, Fig. 1, Fig. 2, the headline claims, and the
//!    planned-vs-simulated drift — the numbers recorded in
//!    EXPERIMENTS.md.

use std::sync::Arc;
use std::time::Duration;

use botsched::analysis::report::run_sweep;
use botsched::analysis::{fractional_cost_floor, makespan_floor};
use botsched::cloudsim::{sample_runs, NoiseModel, SimConfig, Simulator};
use botsched::coordinator::{BatchingEvaluator, Metrics};
use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::workload::paper::{table1_system, table1_text, BUDGETS};

fn main() -> anyhow::Result<()> {
    let sys = table1_system(0.0);

    // ---- layer check: XLA artifact + batcher --------------------------
    let metrics = Arc::new(Metrics::new());
    let base: Arc<dyn PlanEvaluator> = match botsched::runtime::XlaEvaluator::load() {
        Ok(x) => {
            println!(
                "[runtime] plan_eval artifact loaded (K={} V={} M={})",
                x.meta().k,
                x.meta().v,
                x.meta().m
            );
            Arc::new(x)
        }
        Err(e) => {
            println!("[runtime] XLA artifacts unavailable ({e:#}); native fallback");
            Arc::new(NativeEvaluator)
        }
    };
    let evaluator = BatchingEvaluator::new(
        Arc::clone(&base),
        64,
        Duration::from_millis(1),
        Arc::clone(&metrics),
    );

    // ---- Sec. III-A bootstrap: estimate P from test runs ---------------
    let obs = sample_runs(&sys, 25, &NoiseModel::jitter(0.03), 2026);
    let prior = vec![15.0; 12];
    let est = match botsched::runtime::XlaPerfEstimator::load() {
        Ok(e) => e.estimate(&sys, &obs, &prior, 1e-6)?,
        Err(_) => botsched::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-6),
    };
    let mut max_rel: f64 = 0.0;
    for it in &sys.instance_types {
        for app in &sys.apps {
            let truth = sys.perf.get(it.id, app.id);
            let got = est[it.id.index() * 3 + app.id.index()];
            max_rel = max_rel.max((got - truth).abs() / truth);
        }
    }
    println!(
        "[estimate] P recovered from {} noisy test runs, max rel err {:.2}%\n",
        obs.len(),
        max_rel * 100.0
    );

    // ---- Table I + bounds ----------------------------------------------
    println!("{}", table1_text());
    println!(
        "LP cost floor {:.1} (min money to run the workload at all; \
         explains why budgets below ~60 are infeasible — see EXPERIMENTS.md)\n",
        fractional_cost_floor(&sys)
    );

    // ---- Fig. 1 / Fig. 2 sweep through the batched XLA evaluator -------
    let t0 = std::time::Instant::now();
    let report = run_sweep(&sys, BUDGETS, &evaluator);
    let sweep_time = t0.elapsed();
    print!("{}", report.fig1_text());
    println!();
    print!("{}", report.headline().text());
    println!();
    print!("{}", report.fig2_text(&sys));

    // ---- execute every feasible heuristic plan on the simulator --------
    println!("\nPlanned vs simulated (feasible heuristic plans):");
    let registry = PolicyRegistry::builtin();
    let mut worst_drift: f64 = 0.0;
    for &b in BUDGETS {
        let r = registry.solve(
            "budget-heuristic",
            &sys,
            &SolveRequest::new(b).with_evaluator(&evaluator),
        )?;
        if !r.feasible {
            continue;
        }
        let sim = Simulator::run_plan(&sys, &r.plan, &SimConfig::default());
        assert!(sim.all_done(), "stranded tasks on a clean cloud");
        let drift = (sim.makespan - r.score.makespan).abs() / r.score.makespan;
        worst_drift = worst_drift.max(drift);
        println!(
            "  budget {b:>3}: planned {:>7.1}s simulated {:>7.1}s (drift {:.3}%)  floor {:>7.1}s",
            r.score.makespan,
            sim.makespan,
            drift * 100.0,
            makespan_floor(&sys, b)
        );
    }
    println!("worst planned-vs-simulated drift: {:.4}%", worst_drift * 100.0);

    // ---- coordinator metrics --------------------------------------------
    println!(
        "\n[metrics] sweep took {sweep_time:?}; evaluator stats: {}",
        metrics.snapshot()
    );
    Ok(())
}
