//! Bench: evaluator throughput — native rust vs the AOT-compiled XLA
//! artifact, across batch sizes, plus the dynamic batcher's overhead
//! (experiment A2 in DESIGN.md and the §Perf L2/L3 numbers).
//!
//! The XLA path pays per-execution overhead (literal staging, PJRT
//! dispatch) amortised over K=64 candidates; the native path is a tight
//! f64 loop.  The crossover and per-candidate costs recorded here drive
//! the coordinator's batching policy.

use std::sync::Arc;
use std::time::Duration;

use botsched::benchkit::Bench;
use botsched::coordinator::{BatchingEvaluator, Metrics};
use botsched::eval::{EvalBatch, NativeEvaluator, PlanEvaluator};
use botsched::scheduler::Planner;
use botsched::workload::paper::table1_system;

fn main() {
    let sys = table1_system(0.0);

    // A representative candidate pool: heuristic plans at several budgets.
    let plans: Vec<_> = (0..64)
        .map(|i| Planner::new(&sys).find(60.0 + (i % 6) as f64 * 5.0).plan)
        .collect();

    let batch_sizes = [1usize, 8, 64, 256];
    let mut bench = Bench::new("runtime-eval/throughput")
        .with_budget(Duration::from_millis(150), Duration::from_millis(900));

    // ---- native --------------------------------------------------------
    for &n in &batch_sizes {
        let refs: Vec<&botsched::model::Plan> =
            (0..n).map(|i| &plans[i % plans.len()]).collect();
        let batch = EvalBatch::from_plans(&sys, &refs);
        bench.run_with_items(&format!("native/batch{n}"), Some(n as f64), || {
            std::hint::black_box(NativeEvaluator.eval_batch(&batch));
        });
    }

    // ---- xla artifact ----------------------------------------------------
    match botsched::runtime::XlaEvaluator::load() {
        Err(e) => println!("(xla artifact unavailable: {e:#} — run `make artifacts`)"),
        Ok(xla) => {
            for &n in &batch_sizes {
                let refs: Vec<&botsched::model::Plan> =
                    (0..n).map(|i| &plans[i % plans.len()]).collect();
                let batch = EvalBatch::from_plans(&sys, &refs);
                bench.run_with_items(&format!("xla/batch{n}"), Some(n as f64), || {
                    std::hint::black_box(xla.eval_batch(&batch));
                });
            }

            // ---- batcher overhead (single-threaded worst case) ----------
            let metrics = Arc::new(Metrics::new());
            let batched = BatchingEvaluator::new(
                Arc::new(NativeEvaluator),
                64,
                Duration::ZERO,
                Arc::clone(&metrics),
            );
            let refs: Vec<&botsched::model::Plan> = plans.iter().take(8).collect();
            let batch = EvalBatch::from_plans(&sys, &refs);
            bench.run_with_items("batcher(native)/batch8", Some(8.0), || {
                std::hint::black_box(batched.eval_batch(&batch));
            });

            // ---- planner end-to-end with each evaluator -------------------
            bench.run("planner-find@80/native", || {
                std::hint::black_box(Planner::new(&sys).find(80.0));
            });
            bench.run("planner-find@80/xla", || {
                std::hint::black_box(Planner::with_evaluator(&sys, &xla).find(80.0));
            });
        }
    }
    bench.report();
    println!(
        "\nnote: the planner's inner phase moves use exact native scoring; the\n\
         evaluator trait is on the accept/REPLACE path, so the xla column\n\
         measures artifact dispatch + f32 scoring of K-padded batches."
    );
}
