//! Bench: planner scaling (experiment A3 in DESIGN.md) — wall time and
//! plan quality versus workload size, catalogue size and thread count.
//!
//! The paper evaluates a fixed 750-task / 4-type setup; a production
//! scheduler must hold up as both grow.  Sweeps tasks-per-app
//! (125..2000) at 4 types, and instance types (2..16) at 750 tasks, plus
//! the simulator's event throughput on the resulting plans.  The
//! headline `scaling` group runs multistart (8 perturbed restarts) on a
//! 5-app / 6000-task / 6-type workload at 1, 2 and 4 worker threads —
//! results are bit-identical across thread counts (see the `perf_parity`
//! tests), so the speedup is pure wall-clock.  The `scaling/serve`
//! group drives connect–request–disconnect churn through a live
//! coordinator while hundreds of idle spectator connections sit on the
//! poll set, covering the non-blocking connection layer.  The
//! `scaling/loadgen` group replays a pre-generated open-loop traffic
//! tape (`botsched::loadgen`) against a live coordinator — end-to-end
//! request throughput through the pipelined client path.
//!
//! Set `BENCH_SMOKE=1` to shrink every workload to a seconds-long CI
//! smoke run; set `BENCH_JSON=1` to snapshot `BENCH_<group>.json` files
//! (the repo's perf trajectory; see `botsched::benchkit`).

use std::sync::Arc;
use std::time::Duration;

use botsched::benchkit::Bench;
use botsched::cloudsim::{SimConfig, Simulator};
use botsched::coordinator::{Client, Coordinator, CoordinatorConfig, JobEngine, Metrics};
use botsched::loadgen::{self, ArrivalProcess, ExecOptions, LoadConfig, MixSpec};
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::util::Json;
use botsched::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let registry = PolicyRegistry::builtin();
    let heuristic = registry.get("budget-heuristic").expect("builtin");

    // ---- parallel multistart (the headline scaling case) -------------------
    // >= 5 apps, >= 2000 tasks, >= 6 instance types.
    let (tasks_per_app, n_starts) = if smoke { (40, 2) } else { (1200, 8) };
    let spec = WorkloadSpec {
        n_apps: 5,
        n_types: 6,
        tasks_per_app,
        sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
        ..Default::default()
    };
    let sys = WorkloadGenerator::new(41).system(&spec);
    let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
    let multistart = registry.get("multistart").expect("builtin");
    let mut bench = Bench::new("scaling")
        .with_budget(Duration::from_millis(50), Duration::from_millis(if smoke { 200 } else { 2500 }));
    for threads in [1usize, 2, 4] {
        let req = SolveRequest::new(budget)
            .with_starts(n_starts)
            .with_seed(7)
            .with_threads(threads);
        bench.run(
            &format!("multistart{n_starts}/{}tasks/{threads}threads", tasks_per_app * 5),
            || {
                std::hint::black_box(multistart.solve(&sys, &req));
            },
        );
    }
    bench.report();

    // ---- tasks sweep ------------------------------------------------------
    let task_sizes: &[usize] = if smoke { &[50] } else { &[125, 250, 500, 1000, 2000] };
    let mut bench = Bench::new("scaling/tasks")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1200));
    for &tasks_per_app in task_sizes {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types: 4,
            tasks_per_app,
            sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(42).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        let total = (tasks_per_app * 3) as f64;
        bench.run_with_items(&format!("find/{}tasks", tasks_per_app * 3), Some(total), || {
            std::hint::black_box(heuristic.solve(&sys, &SolveRequest::new(budget)));
        });
    }
    bench.report();

    // ---- instance-type sweep ----------------------------------------------
    let type_sizes: &[usize] = if smoke { &[4] } else { &[2, 4, 8, 16] };
    let mut bench = Bench::new("scaling/instance-types")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1200));
    for &n_types in type_sizes {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types,
            tasks_per_app: if smoke { 50 } else { 250 },
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(43).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        bench.run(&format!("find/{n_types}types"), || {
            std::hint::black_box(heuristic.solve(&sys, &SolveRequest::new(budget)));
        });
    }
    bench.report();

    // ---- job-engine submit→drain throughput --------------------------------
    // Pure pool overhead: N trivial jobs through the sharded queues,
    // submission to last completion, at 1/2/4 shards.
    let engine_jobs = if smoke { 100 } else { 500 };
    let engine_target = Duration::from_millis(if smoke { 200 } else { 800 });
    let mut bench =
        Bench::new("scaling/engine").with_budget(Duration::from_millis(100), engine_target);
    for shards in [1usize, 2, 4] {
        // Backlog above the burst size: this group measures queue/drain
        // overhead, not admission control (which would reject the burst
        // at the default 256-per-shard bound on the 1-shard case).
        let engine = JobEngine::with_backlog(shards, 1024, Arc::new(Metrics::new()));
        bench.run_with_items(
            &format!("submit-drain/{engine_jobs}jobs/{shards}shards"),
            Some(engine_jobs as f64),
            || {
                let ids: Vec<String> = (0..engine_jobs)
                    .map(|i| {
                        engine.submit(
                            "bench",
                            Box::new(move |_| Ok(Json::num(i as f64))),
                        )
                    })
                    .collect();
                for id in &ids {
                    let state = engine
                        .registry()
                        .wait_terminal(id, Duration::from_secs(60))
                        .expect("bench job exists");
                    assert!(state.is_terminal(), "bench job {id} wedged in {:?}", state.as_str());
                }
                std::hint::black_box(ids);
            },
        );
    }
    bench.report();

    // ---- serving: connection churn with idle spectators --------------------
    // The connection layer's whole job: N idle clients must cost
    // nothing while connect→request→disconnect churn flows past them.
    // Fixed thread pools (2 conn workers, 4 executors, 2 shards)
    // regardless of the idle population.
    let idle_n = if smoke { 16 } else { 256 };
    let churn = if smoke { 30 } else { 200 };
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards: 2,
        conn_workers: 2,
        ..CoordinatorConfig::default()
    })
    .expect("bench coordinator starts");
    let addr = coord.local_addr;
    let idle: Vec<std::net::TcpStream> = (0..idle_n)
        .map(|_| std::net::TcpStream::connect(addr).expect("idle connection"))
        .collect();
    let mut bench = Bench::new("scaling/serve")
        .with_budget(Duration::from_millis(100), Duration::from_millis(if smoke { 200 } else { 800 }));
    bench.run_with_items(
        &format!("churn/{churn}conns/{idle_n}idle"),
        Some(churn as f64),
        || {
            for _ in 0..churn {
                // Connect–request–disconnect through the typed client.
                let mut client = Client::connect(&addr).expect("churn connect");
                client.ping().expect("ping reply");
            }
        },
    );
    bench.report();
    drop(idle);
    coord.shutdown();

    // ---- open-loop load generation ------------------------------------------
    // The full loadgen path: a deterministic pre-generated tape played
    // through 4 pipelined clients against a live coordinator.  Tape
    // generation is outside the timed region — this measures serving
    // throughput, not RNG cost.
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards: 2,
        conn_workers: 2,
        ..CoordinatorConfig::default()
    })
    .expect("loadgen bench coordinator starts");
    let addr = coord.local_addr;
    let load_rates: &[f64] = if smoke { &[40.0] } else { &[100.0, 300.0] };
    let load_duration = if smoke { 0.3 } else { 1.0 };
    let mut bench = Bench::new("scaling/loadgen")
        .with_budget(Duration::from_millis(100), Duration::from_millis(if smoke { 400 } else { 2500 }));
    for &rate in load_rates {
        let cfg = LoadConfig {
            rate,
            duration_s: load_duration,
            clients: 4,
            arrival: ArrivalProcess::Poisson,
            mix: MixSpec::plan_only("uniform-small").expect("builtin scenario"),
            seed: 7,
        };
        let trace = loadgen::generate(&cfg).expect("tape generates");
        let n = trace.entries.len() as f64;
        let opts = ExecOptions::default();
        bench.run_with_items(&format!("execute/{rate}rps/4clients"), Some(n), || {
            let report = loadgen::execute(&addr, &trace, &opts).expect("load run");
            assert_eq!(report.sent, n as u64, "open loop must send the whole tape");
            std::hint::black_box(report);
        });
    }
    bench.report();
    coord.shutdown();

    // ---- simulator event throughput ----------------------------------------
    let sim_sizes: &[usize] = if smoke { &[100] } else { &[250, 1000, 4000] };
    let mut bench = Bench::new("scaling/simulator")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1000));
    for &tasks_per_app in sim_sizes {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types: 4,
            tasks_per_app,
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(44).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        let plan = heuristic.solve(&sys, &SolveRequest::new(budget)).plan;
        let total = (tasks_per_app * 3) as f64;
        bench.run_with_items(
            &format!("run_plan/{}tasks", tasks_per_app * 3),
            Some(total),
            || {
                std::hint::black_box(Simulator::run_plan(&sys, &plan, &SimConfig::default()));
            },
        );
    }
    bench.report();
}
