//! Bench: planner scaling (experiment A3 in DESIGN.md) — wall time and
//! plan quality versus workload size and catalogue size.
//!
//! The paper evaluates a fixed 750-task / 4-type setup; a production
//! scheduler must hold up as both grow.  Sweeps tasks-per-app
//! (125..2000) at 4 types, and instance types (2..16) at 750 tasks, plus
//! the simulator's event throughput on the resulting plans.

use std::time::Duration;

use botsched::benchkit::Bench;
use botsched::cloudsim::{SimConfig, Simulator};
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

fn main() {
    let registry = PolicyRegistry::builtin();
    let heuristic = registry.get("budget-heuristic").expect("builtin");
    // ---- tasks sweep ------------------------------------------------------
    let mut bench = Bench::new("scaling/tasks")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1200));
    for tasks_per_app in [125usize, 250, 500, 1000, 2000] {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types: 4,
            tasks_per_app,
            sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(42).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        let total = (tasks_per_app * 3) as f64;
        bench.run_with_items(&format!("find/{}tasks", tasks_per_app * 3), Some(total), || {
            std::hint::black_box(heuristic.solve(&sys, &SolveRequest::new(budget)));
        });
    }
    bench.report();

    // ---- instance-type sweep ----------------------------------------------
    let mut bench = Bench::new("scaling/instance-types")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1200));
    for n_types in [2usize, 4, 8, 16] {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types,
            tasks_per_app: 250,
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(43).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        bench.run(&format!("find/{n_types}types"), || {
            std::hint::black_box(heuristic.solve(&sys, &SolveRequest::new(budget)));
        });
    }
    bench.report();

    // ---- simulator event throughput ----------------------------------------
    let mut bench = Bench::new("scaling/simulator")
        .with_budget(Duration::from_millis(200), Duration::from_millis(1000));
    for tasks_per_app in [250usize, 1000, 4000] {
        let spec = WorkloadSpec {
            n_apps: 3,
            n_types: 4,
            tasks_per_app,
            ..Default::default()
        };
        let sys = WorkloadGenerator::new(44).system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.4);
        let plan = heuristic.solve(&sys, &SolveRequest::new(budget)).plan;
        let total = (tasks_per_app * 3) as f64;
        bench.run_with_items(
            &format!("run_plan/{}tasks", tasks_per_app * 3),
            Some(total),
            || {
                std::hint::black_box(Simulator::run_plan(&sys, &plan, &SimConfig::default()));
            },
        );
    }
    bench.report();
}
