//! Bench: regenerate **Fig. 1** (execution time vs budget for the
//! Heuristic / MI / MP approaches) and time the planner while at it.
//!
//! Paper reference (Sec. V-C): the heuristic always has the lowest
//! execution time; average improvement ~13% vs MI and ~7% vs MP; the
//! heuristic satisfies lower budgets than either baseline.  We reproduce
//! the *shape* (who wins, ordering of feasibility floors) — see
//! EXPERIMENTS.md for the measured-vs-paper discussion, including the
//! Table-I arithmetic that moves the feasibility floor to ~60.

use botsched::analysis::report::{run_sweep, CORE_POLICIES};
use botsched::benchkit::Bench;
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::workload::paper::{table1_system, BUDGETS};

fn main() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();

    // ---- the figure itself ------------------------------------------------
    let report = run_sweep(&sys, BUDGETS, &botsched::eval::NativeEvaluator);
    print!("{}", report.fig1_text());
    print!("{}", report.headline().text());

    // Shape assertions (the reproducible claims).
    let h = report.headline();
    assert!(
        h.avg_improvement_vs_mi_pct > 0.0 && h.avg_improvement_vs_mp_pct > 0.0,
        "heuristic must beat both baselines on average"
    );
    assert!(
        h.min_feasible_budget_heuristic <= h.min_feasible_budget_mi
            && h.min_feasible_budget_heuristic <= h.min_feasible_budget_mp,
        "heuristic must satisfy the lowest budget"
    );
    for &b in BUDGETS {
        let ours = report.row("budget-heuristic", b).unwrap().score.makespan;
        for a in ["mi", "mp"] {
            let other = report.row(a, b).unwrap().score.makespan;
            assert!(ours <= other + 1e-6, "budget {b}: heuristic {ours} vs {a} {other}");
        }
    }
    println!("shape checks: heuristic <= MI, MP at every budget; feasibility floor ordered. OK\n");

    // ---- policy timing across budgets ---------------------------------------
    // Iterates the registry, so a newly registered policy shows up in the
    // timing table without touching this bench.
    let mut bench = Bench::new("fig1/policy-time");
    for &b in &[40.0, 60.0, 85.0] {
        for name in CORE_POLICIES {
            let policy = registry.get(name).expect("core policy");
            bench.run(&format!("{name}@{b}"), || {
                std::hint::black_box(policy.solve(&sys, &SolveRequest::new(b)));
            });
        }
    }
    bench.report();
}
