//! Bench: planner phase micro-benchmarks + the phase ablation study
//! (experiment A1 in DESIGN.md).
//!
//! Times each Section IV phase in isolation on the paper workload, then
//! re-runs the full FIND loop with one phase disabled at a time to show
//! each phase's contribution to plan quality (mean makespan, feasibility
//! cells across the Fig. 1 budget sweep).
//!
//! The `planner_micro` group isolates candidate-scoring throughput —
//! the arena/SoA delta path vs the historical owned-batch path — and
//! snapshots to `BENCH_planner_micro.json` under `BENCH_JSON=1` so the
//! CI bench guard tracks the win.  The `planner_micro/parallel` group
//! (snapshot `BENCH_planner_micro_parallel.json`) covers the
//! deterministic intra-solve parallelism: sequential vs 2/4-thread
//! chunked delta scoring, threaded REPLACE rounds, and the
//! pruned-vs-unpruned REPLACE pair.  Set `BENCH_SMOKE=1` to skip the
//! slow ablation/A4 studies and shrink the measurement budget for CI.

// Plan clones below are bench scaffolding (preparing inputs outside the
// timed region) or the legacy comparison path itself.
#![allow(clippy::disallowed_methods)]

use std::time::Duration;

use botsched::benchkit::Bench;
use botsched::eval::{
    eval_deltas_chunked, DeltaBatch, EvalBatch, NativeEvaluator, PlanArena, PlanEvaluator,
};
use botsched::model::{Plan, TaskId};
use botsched::scheduler::{
    add_vms, assign, balance, balance_arena, initial, reduce, replace, replace_arena,
    replace_arena_opts, split, Planner, PlannerConfig, ReduceMode, ReplaceOpts,
};
use botsched::util::CancelToken;
use botsched::workload::paper::{table1_system, BUDGETS};
use botsched::workload::{build_scenario, WorkloadGenerator};

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let sys = table1_system(0.0);
    let budget = 80.0;
    let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();

    // ---- phase timings ------------------------------------------------
    let mut bench = Bench::new("planner-micro/phases");
    if smoke {
        bench = bench.with_budget(Duration::from_millis(30), Duration::from_millis(150));
    }
    bench.run("initial+assign@80", || {
        std::hint::black_box(initial(&sys, budget));
    });
    let base = initial(&sys, budget);
    bench.run("reduce-local@80", || {
        let mut p = base.clone();
        reduce(&sys, &mut p, budget, ReduceMode::Local);
        std::hint::black_box(p);
    });
    let mut reduced = base.clone();
    reduce(&sys, &mut reduced, budget, ReduceMode::Local);
    bench.run("reduce-global@80", || {
        let mut p = reduced.clone();
        reduce(&sys, &mut p, budget, ReduceMode::Global);
        std::hint::black_box(p);
    });
    bench.run("add@remaining", || {
        let mut p = reduced.clone();
        let cost = p.cost(&sys);
        add_vms(&sys, &mut p, (budget - cost).max(0.0));
        std::hint::black_box(p);
    });
    bench.run("balance@80", || {
        let mut p = reduced.clone();
        balance(&sys, &mut p, budget);
        std::hint::black_box(p);
    });
    bench.run("split@80", || {
        let mut p = reduced.clone();
        split(&sys, &mut p, budget);
        std::hint::black_box(p);
    });
    bench.run("replace@80", || {
        let mut p = reduced.clone();
        replace(&sys, &mut p, budget, 1, &NativeEvaluator);
        std::hint::black_box(p);
    });
    bench.run_with_items("assign-750-tasks", Some(tasks.len() as f64), || {
        let mut p = botsched::model::Plan::new();
        for vm in &base.vms {
            p.add_vm(&sys, vm.it);
        }
        assign(&sys, &mut p, &tasks);
        std::hint::black_box(p);
    });
    bench.run("find-full@80", || {
        std::hint::black_box(Planner::new(&sys).find(budget));
    });
    bench.report();

    // ---- arena vs legacy candidate scoring (the FIND/balance hot loop) -
    //
    // K candidate plans scored per iteration, so throughput is directly
    // candidate-evals/sec.  The legacy path materialises every candidate
    // into the owned EvalBatch tensors; the delta paths score borrowed
    // rows (per-Vm caches / contiguous arena stripes) with zero copies.
    let mut micro = Bench::new("planner_micro");
    if smoke {
        micro = micro.with_budget(Duration::from_millis(30), Duration::from_millis(150));
    }
    let k = 64usize;
    let candidates: Vec<Plan> = (0..k).map(|_| reduced.clone()).collect();
    let cand_refs: Vec<&Plan> = candidates.iter().collect();
    let arenas: Vec<PlanArena> =
        candidates.iter().map(|p| PlanArena::from_plan(&sys, p)).collect();

    micro.run_with_items("score/owned-batch", Some(k as f64), || {
        let batch = EvalBatch::from_plans(&sys, &cand_refs);
        std::hint::black_box(NativeEvaluator.eval_batch(&batch));
    });
    micro.run_with_items("score/plan-delta", Some(k as f64), || {
        for p in &candidates {
            std::hint::black_box(NativeEvaluator.eval_deltas(&DeltaBatch::from_plan(&sys, p)));
        }
    });
    micro.run_with_items("score/arena-delta", Some(k as f64), || {
        let mut batch = DeltaBatch::new(&sys);
        for a in &arenas {
            batch.push(a.delta_candidate(&sys));
        }
        std::hint::black_box(NativeEvaluator.eval_deltas(&batch));
    });

    // BALANCE inner loop: the legacy-shaped wrapper (clone + load +
    // store) vs the arena-resident loop FIND actually runs (reload a
    // persistent arena, no clone, no store).
    let mut persistent = PlanArena::new(&sys);
    micro.run("balance/plan-wrapper@80", || {
        let mut p = reduced.clone();
        std::hint::black_box(balance(&sys, &mut p, budget));
    });
    micro.run("balance/arena@80", || {
        persistent.load_plan(&reduced);
        std::hint::black_box(balance_arena(&sys, &mut persistent, budget));
    });
    micro.run("replace/arena@80", || {
        persistent.load_plan(&reduced);
        std::hint::black_box(replace_arena(
            &sys,
            &mut persistent,
            budget,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
        ));
    });
    micro.run("find-full@80", || {
        std::hint::black_box(Planner::new(&sys).find(budget));
    });
    micro.report();

    // ---- intra-solve parallelism (chunked scoring + threaded REPLACE) -
    //
    // Sequential vs 2/4-thread chunked delta scoring at two batch widths,
    // threaded REPLACE rounds, and the pruned-vs-unpruned REPLACE pair —
    // all on the wide-catalogue scenario (16 types, 600 tasks), where the
    // candidate sets are broad enough for the fan-out and the bound to
    // matter.  Every variant returns bit-identical results (pinned by
    // `parallel_parity`); this group measures the throughput spread.
    let mut par = Bench::new("planner_micro/parallel");
    if smoke {
        par = par.with_budget(Duration::from_millis(30), Duration::from_millis(150));
    }
    let wide = build_scenario("wide-catalogue").expect("wide-catalogue preset");
    let wb = WorkloadGenerator::feasible_budget(&wide, 1.2);
    let mut wide_base = initial(&wide, wb);
    reduce(&wide, &mut wide_base, wb, ReduceMode::Local);
    wide_base.drop_empty_vms();
    let wide_arena = PlanArena::from_plan(&wide, &wide_base);
    let it0 = wide.instance_types[0].id;

    for kk in [64usize, 256] {
        let mut batch = DeltaBatch::new(&wide);
        for i in 0..kk {
            let mut c = wide_arena.delta_candidate(&wide);
            c.push_synth(
                (0..wide.n_apps()).map(|m| 1.0 + (i * (m + 1)) as f64 * 0.25).collect(),
                wide.perf.row(it0),
                wide.rate(it0),
            );
            batch.push(c);
        }
        par.run_with_items(&format!("score/seq@{kk}"), Some(kk as f64), || {
            std::hint::black_box(NativeEvaluator.eval_deltas(&batch));
        });
        for threads in [2usize, 4] {
            par.run_with_items(&format!("score/{threads}t@{kk}"), Some(kk as f64), || {
                std::hint::black_box(eval_deltas_chunked(
                    &NativeEvaluator,
                    &batch,
                    threads,
                    &CancelToken::default(),
                ));
            });
        }
    }

    let mut wide_persistent = PlanArena::new(&wide);
    for threads in [1usize, 2, 4] {
        par.run(&format!("replace/{threads}t"), || {
            wide_persistent.load_plan(&wide_base);
            std::hint::black_box(replace_arena_opts(
                &wide,
                &mut wide_persistent,
                wb,
                2,
                &NativeEvaluator,
                &CancelToken::default(),
                &ReplaceOpts { threads, ..Default::default() },
            ));
        });
    }
    for (label, prune) in [("replace/pruned", true), ("replace/unpruned", false)] {
        par.run(label, || {
            wide_persistent.load_plan(&wide_base);
            std::hint::black_box(replace_arena_opts(
                &wide,
                &mut wide_persistent,
                wb,
                2,
                &NativeEvaluator,
                &CancelToken::default(),
                &ReplaceOpts { prune, ..Default::default() },
            ));
        });
    }
    par.report();

    if smoke {
        println!("\nBENCH_SMOKE set: skipping the ablation and A4 studies.");
        return;
    }

    // ---- ablation study (A1) -------------------------------------------
    println!("\n== ablation: phase contribution across the Fig. 1 sweep ==");
    println!(
        "{:<10} {:>15} {:>10} {:>12}",
        "variant", "mean makespan", "feasible", "vs full"
    );
    #[allow(clippy::type_complexity)]
    let phases: [(&str, fn(&mut PlannerConfig)); 6] = [
        ("full", |_| {}),
        ("-reduce", |c| c.enable_reduce = false),
        ("-add", |c| c.enable_add = false),
        ("-balance", |c| c.enable_balance = false),
        ("-split", |c| c.enable_split = false),
        ("-replace", |c| c.enable_replace = false),
    ];
    let mut full_mean = 0.0f64;
    for (name, tweak) in phases {
        let mut cfg = PlannerConfig::default();
        tweak(&mut cfg);
        let mut spans = Vec::new();
        let mut feasible = 0;
        for &b in BUDGETS {
            let r = Planner::new(&sys).with_config(cfg.clone()).find(b);
            spans.push(r.score.makespan);
            if r.feasible {
                feasible += 1;
            }
        }
        let mean = spans.iter().sum::<f64>() / spans.len() as f64;
        if name == "full" {
            full_mean = mean;
        }
        println!(
            "{:<10} {:>14.1}s {:>7}/{:<2} {:>+11.1}%",
            name,
            mean,
            feasible,
            BUDGETS.len(),
            (mean / full_mean - 1.0) * 100.0
        );
    }
    println!("\n(positive 'vs full' = removing the phase makes plans worse)");

    // ---- A4: multi-start vs single-start -------------------------------
    // Both sides run through the policy registry: same request, two names.
    use botsched::scheduler::{PolicyRegistry, SolveRequest};
    use botsched::workload::WorkloadSpec;
    let registry = PolicyRegistry::builtin();
    println!("\n== A4: multi-start (8 perturbed restarts) vs single-start ==");
    println!("{:<22} {:>12} {:>12} {:>9}", "instance", "single", "multi", "gain");
    let mut wins = 0;
    let mut cases = 0;
    for seed in 0..12u64 {
        let spec = WorkloadSpec {
            n_apps: 2 + (seed % 3) as usize,
            n_types: 3 + (seed % 4) as usize,
            tasks_per_app: 80,
            ..Default::default()
        };
        let sys2 = WorkloadGenerator::new(seed + 100).system(&spec);
        let b = WorkloadGenerator::feasible_budget(&sys2, 1.3);
        let req = SolveRequest::new(b).with_seed(seed).with_starts(8);
        let single = registry.solve("budget-heuristic", &sys2, &req).unwrap();
        let multi = registry.solve("multistart", &sys2, &req).unwrap();
        if !single.feasible {
            continue;
        }
        cases += 1;
        let gain = (single.score.makespan / multi.score.makespan - 1.0) * 100.0;
        if gain > 0.01 {
            wins += 1;
        }
        println!(
            "{:<22} {:>11.1}s {:>11.1}s {:>+8.2}%",
            format!("seed{seed}/{}a{}t", spec.n_apps, spec.n_types),
            single.score.makespan,
            multi.score.makespan,
            gain
        );
    }
    println!("multi-start improved {wins}/{cases} feasible instances (never worse by construction)");
}
