//! Bench: regenerate **Fig. 2** (number of VMs of each instance type per
//! budget, for each approach) and assert its qualitative shape.
//!
//! Paper reference (Sec. V-C): MP buys only the cheapest type (it_1); MI
//! buys the best average performer (it_4) plus an occasional it_1 with
//! leftover budget; the heuristic mixes it_3/it_4 (the per-application
//! best types) and sprinkles it_1 for parallelism at some budgets.

use botsched::analysis::report::run_sweep;
use botsched::eval::NativeEvaluator;
use botsched::workload::paper::{table1_system, BUDGETS};

fn main() {
    let sys = table1_system(0.0);
    let report = run_sweep(&sys, BUDGETS, &NativeEvaluator);
    print!("{}", report.fig2_text(&sys));

    // Shape assertions.
    for &b in BUDGETS {
        let mp = &report.row("mp", b).unwrap().vm_mix;
        assert_eq!(
            mp[1] + mp[2] + mp[3],
            0,
            "budget {b}: MP must use only it_1, got {mp:?}"
        );
        assert!(mp[0] >= 1);

        let mi = &report.row("mi", b).unwrap().vm_mix;
        assert_eq!(mi[1] + mi[2], 0, "budget {b}: MI uses only it_4 (+it_1 remainder), got {mi:?}");
        assert!(mi[3] >= 1, "budget {b}: MI must buy it_4, got {mi:?}");
        assert!(mi[0] <= 1, "budget {b}: MI adds at most one it_1 remainder, got {mi:?}");

        let ours = &report.row("heuristic", b).unwrap().vm_mix;
        assert!(
            ours[2] >= 1 && ours[3] >= 1,
            "budget {b}: heuristic must mix the per-app best types it_3/it_4, got {ours:?}"
        );
    }
    // MP fields strictly more VMs than MI at equal budget (parallelism focus).
    for &b in BUDGETS {
        let mp: usize = report.row("mp", b).unwrap().vm_mix.iter().sum();
        let mi: usize = report.row("mi", b).unwrap().vm_mix.iter().sum();
        assert!(mp >= mi, "budget {b}: MP should field at least as many VMs as MI");
    }
    println!("\nshape checks: MP all-it1, MI it4(+it1), heuristic mixes it3/it4. OK");
}
