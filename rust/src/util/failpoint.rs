//! Process-global failpoint registry: named fault-injection sites for
//! chaos testing the serving stack.
//!
//! An instrumented call site asks [`check`]/[`apply`] whether its named
//! point is armed.  In production nothing is armed and the call is a
//! single relaxed atomic load — no lock, no allocation, no branch on
//! shared mutable state.  Arming happens explicitly: `serve --chaos
//! <spec>` at startup, or the v2 `chaos` op at runtime (gated behind
//! `serve --chaos-allowed`).
//!
//! ## Spec grammar
//!
//! ```text
//! spec   := point (';' point)*
//! point  := name '=' action ['@' prob] ['x' budget]
//! action := 'error' | 'panic' | 'delay(' ms ')' | 'torn_write(' n ')'
//! ```
//!
//! `prob` is the firing probability in `[0, 1]` (default 1 — every
//! hit); `budget` bounds how many times the point fires (default
//! unlimited).  Examples:
//!
//! ```text
//! journal.append=error@0.3          # 30% of journal appends fail
//! engine.worker=delay(50)@0.5x20    # 50ms stall, half the time, 20 fires
//! journal.append=torn_write(7)x1    # one 7-byte torn frame, then clean
//! conn.read=error@0.05;cache.insert=error
//! ```
//!
//! Firing is deterministic for a given arm order and hit sequence (the
//! registry draws from one seeded [`Rng`]).  The action semantics are
//! interpreted by the call site: `delay` sleeps inline, `panic` panics
//! the calling thread (exercising panic isolation), `error` maps to the
//! site's failure path, and `torn_write(n)` truncates a write to its
//! first `n` bytes (only the journal append path tears; other sites
//! treat it as `error`).
//!
//! The instrumented points are listed in `docs/OPERATIONS.md`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use super::rng::Rng;

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailAction {
    /// Fail the instrumented operation with an injected error.
    Error,
    /// Sleep this many milliseconds, then proceed normally.
    Delay(u64),
    /// Panic the calling thread.
    Panic,
    /// Write only the first `n` bytes of the payload, then fail
    /// (journal append path; elsewhere equivalent to `Error`).
    TornWrite(usize),
}

impl std::fmt::Display for FailAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailAction::Error => write!(f, "error"),
            FailAction::Delay(ms) => write!(f, "delay({ms})"),
            FailAction::Panic => write!(f, "panic"),
            FailAction::TornWrite(n) => write!(f, "torn_write({n})"),
        }
    }
}

/// One armed point.
#[derive(Debug, Clone)]
struct Point {
    action: FailAction,
    probability: f64,
    /// Remaining fires; `None` = unlimited.
    remaining: Option<u64>,
    /// Times the point was evaluated (armed site executed).
    hits: u64,
    /// Times the point actually fired.
    fired: u64,
}

/// A snapshot row for the `chaos` op's `list` action.
#[derive(Debug, Clone, PartialEq)]
pub struct PointInfo {
    pub name: String,
    /// Canonical spec rendering, e.g. `error@0.3x5`.
    pub config: String,
    pub hits: u64,
    pub fired: u64,
    pub remaining: Option<u64>,
}

struct Registry {
    points: BTreeMap<String, Point>,
    rng: Rng,
}

/// Fast-path gate: `false` means no point is armed anywhere and every
/// [`check`] returns immediately off this one relaxed load.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn registry() -> MutexGuard<'static, Option<Registry>> {
    // Panic actions fire outside the lock, so poisoning is only
    // reachable through a panicking test assertion — recover, the map
    // itself is never left half-updated.
    match REGISTRY.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Evaluate a failpoint.  Disarmed (the common case) costs one relaxed
/// atomic load.  Armed, the point's probability and fire budget decide
/// whether an action is returned.
#[inline]
pub fn check(name: &str) -> Option<FailAction> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    fire(name)
}

#[cold]
fn fire(name: &str) -> Option<FailAction> {
    let mut guard = registry();
    let Registry { points, rng } = guard.as_mut()?;
    let point = points.get_mut(name)?;
    point.hits += 1;
    if point.remaining == Some(0) {
        return None;
    }
    if point.probability < 1.0 && rng.f64() >= point.probability {
        return None;
    }
    if let Some(r) = &mut point.remaining {
        *r -= 1;
    }
    point.fired += 1;
    Some(point.action.clone())
}

/// [`check`] with the two self-contained actions applied inline:
/// `delay` sleeps here, `panic` panics here.  `error` / `torn_write`
/// are returned for the call site's failure path.
pub fn apply(name: &str) -> Option<FailAction> {
    match check(name)? {
        FailAction::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        FailAction::Panic => panic!("failpoint {name}: injected panic"),
        other => Some(other),
    }
}

/// The injected error an `error`-action site reports.
pub fn injected(name: &str) -> std::io::Error {
    std::io::Error::other(format!("failpoint {name}: injected error"))
}

/// [`apply`] for sites that can only fail wholesale: any surviving
/// action becomes an injected [`std::io::Error`].
pub fn io_error(name: &str) -> std::io::Result<()> {
    match apply(name) {
        None => Ok(()),
        Some(_) => Err(injected(name)),
    }
}

/// Arm every point in a spec string (see the module docs for the
/// grammar).  Re-arming a name replaces its point and resets counters.
pub fn arm(spec: &str) -> Result<(), String> {
    let mut parsed = Vec::new();
    for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, cfg) = part
            .split_once('=')
            .ok_or_else(|| format!("failpoint {part:?}: expected name=action"))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("failpoint {part:?}: empty name"));
        }
        parsed.push((name.to_string(), parse_point(cfg.trim())?));
    }
    if parsed.is_empty() {
        return Err("empty chaos spec".into());
    }
    let mut guard = registry();
    let reg = guard.get_or_insert_with(|| Registry {
        points: BTreeMap::new(),
        // Fixed seed: chaos schedules replay identically for identical
        // arm order + hit sequences.
        rng: Rng::new(0x0c_a0_5c_a0),
    });
    for (name, point) in parsed {
        reg.points.insert(name, point);
    }
    ARMED.store(true, Ordering::Release);
    Ok(())
}

/// Disarm one point (`Some(name)`) or everything (`None`); returns how
/// many points were removed.  The fast path re-closes once the registry
/// is empty.
pub fn disarm(name: Option<&str>) -> usize {
    let mut guard = registry();
    let Some(reg) = guard.as_mut() else { return 0 };
    let removed = match name {
        Some(n) => usize::from(reg.points.remove(n).is_some()),
        None => std::mem::take(&mut reg.points).len(),
    };
    if reg.points.is_empty() {
        ARMED.store(false, Ordering::Release);
    }
    removed
}

/// Snapshot every armed point (name order) with its hit/fire counters.
pub fn list() -> Vec<PointInfo> {
    let guard = registry();
    let Some(reg) = guard.as_ref() else { return Vec::new() };
    reg.points
        .iter()
        .map(|(name, p)| {
            let mut config = p.action.to_string();
            if p.probability < 1.0 {
                config.push_str(&format!("@{}", p.probability));
            }
            if let Some(r) = p.remaining {
                config.push_str(&format!("x{r}"));
            }
            PointInfo {
                name: name.clone(),
                config,
                hits: p.hits,
                fired: p.fired,
                remaining: p.remaining,
            }
        })
        .collect()
}

fn parse_point(cfg: &str) -> Result<Point, String> {
    let mut s = cfg;
    let mut remaining = None;
    if let Some(i) = s.rfind('x') {
        let tail = &s[i + 1..];
        if !tail.is_empty() && tail.bytes().all(|b| b.is_ascii_digit()) {
            remaining =
                Some(tail.parse::<u64>().map_err(|e| format!("failpoint budget {tail:?}: {e}"))?);
            s = &s[..i];
        }
    }
    let mut probability = 1.0;
    if let Some(i) = s.rfind('@') {
        let p: f64 = s[i + 1..]
            .parse()
            .map_err(|e| format!("failpoint probability {:?}: {e}", &s[i + 1..]))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("failpoint probability {p} outside [0, 1]"));
        }
        probability = p;
        s = &s[..i];
    }
    let arg_of = |s: &str, prefix: &str| -> Result<u64, String> {
        s.strip_prefix(prefix)
            .and_then(|r| r.strip_suffix(')'))
            .ok_or_else(|| format!("failpoint action {s:?}: malformed argument"))?
            .parse::<u64>()
            .map_err(|e| format!("failpoint action {s:?}: {e}"))
    };
    let action = match s {
        "error" => FailAction::Error,
        "panic" => FailAction::Panic,
        _ if s.starts_with("delay(") => FailAction::Delay(arg_of(s, "delay(")?),
        _ if s.starts_with("torn_write(") => {
            FailAction::TornWrite(arg_of(s, "torn_write(")? as usize)
        }
        _ => {
            return Err(format!(
                "failpoint action {s:?} (expected error, panic, delay(ms) or torn_write(n))"
            ))
        }
    };
    Ok(Point { action, probability, remaining, hits: 0, fired: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; every test uses its own point
    // names and disarms them on exit so parallel tests never interact.

    #[test]
    fn disarmed_points_cost_nothing_and_return_none() {
        assert_eq!(check("fp.test.unarmed"), None);
        assert_eq!(apply("fp.test.unarmed"), None);
        assert!(io_error("fp.test.unarmed").is_ok());
    }

    #[test]
    fn spec_grammar_roundtrips() {
        arm("fp.test.g1=error@0.25x3; fp.test.g2=delay(40) ; fp.test.g3=torn_write(7)x1")
            .unwrap();
        let rows = list();
        let row = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert_eq!(row("fp.test.g1").config, "error@0.25x3");
        assert_eq!(row("fp.test.g2").config, "delay(40)");
        assert_eq!(row("fp.test.g3").config, "torn_write(7)x1");
        assert_eq!(disarm(Some("fp.test.g1")), 1);
        assert_eq!(disarm(Some("fp.test.g1")), 0);
        disarm(Some("fp.test.g2"));
        disarm(Some("fp.test.g3"));
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "",
            "noequals",
            "n=",
            "n=explode",
            "n=delay(x)",
            "n=torn_write(",
            "n=error@1.5",
            "n=error@zz",
            "=error",
        ] {
            assert!(arm(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn budget_bounds_the_fires_and_counters_track() {
        arm("fp.test.budget=errorx2").unwrap();
        assert_eq!(check("fp.test.budget"), Some(FailAction::Error));
        assert_eq!(check("fp.test.budget"), Some(FailAction::Error));
        assert_eq!(check("fp.test.budget"), None, "budget exhausted");
        let rows = list();
        let row = rows.iter().find(|r| r.name == "fp.test.budget").unwrap();
        assert_eq!((row.hits, row.fired, row.remaining), (3, 2, Some(0)));
        disarm(Some("fp.test.budget"));
    }

    #[test]
    fn probability_zero_never_fires() {
        arm("fp.test.p0=error@0").unwrap();
        for _ in 0..100 {
            assert_eq!(check("fp.test.p0"), None);
        }
        disarm(Some("fp.test.p0"));
    }

    #[test]
    fn io_error_maps_error_actions() {
        arm("fp.test.io=errorx1").unwrap();
        let e = io_error("fp.test.io").unwrap_err();
        assert!(e.to_string().contains("fp.test.io"), "{e}");
        assert!(io_error("fp.test.io").is_ok(), "budget spent");
        disarm(Some("fp.test.io"));
    }

    #[test]
    fn panic_action_panics_the_caller() {
        arm("fp.test.panic=panicx1").unwrap();
        let r = std::panic::catch_unwind(|| apply("fp.test.panic"));
        disarm(Some("fp.test.panic"));
        assert!(r.is_err(), "panic action must panic");
    }

    #[test]
    fn rearming_replaces_and_resets() {
        arm("fp.test.rearm=errorx1").unwrap();
        assert_eq!(check("fp.test.rearm"), Some(FailAction::Error));
        arm("fp.test.rearm=delay(5)").unwrap();
        let rows = list();
        let row = rows.iter().find(|r| r.name == "fp.test.rearm").unwrap();
        assert_eq!(row.config, "delay(5)");
        assert_eq!(row.fired, 0, "re-arm resets counters");
        disarm(Some("fp.test.rearm"));
    }
}
