//! A tiny dependency-free readiness layer over `poll(2)` for the
//! coordinator's non-blocking connection workers.
//!
//! Two pieces:
//!
//! * [`Poller`] — level-triggered readiness over a slice of file
//!   descriptors (`(fd, Interest)` pairs), one `poll(2)` call per wait.
//!   Level-triggering keeps the callers simple: a socket with unread
//!   bytes (even bytes that arrived *before* it was registered) reports
//!   readable on every wait until drained.
//! * [`Waker`] — a self-pipe that makes a blocked [`Poller::wait`]
//!   return immediately from another thread (used to deliver new
//!   connections and finished request results to a connection worker).
//!
//! On non-unix targets both degrade to a timed sleep that reports every
//! source ready — a busy-poll fallback that is correct (callers use
//! non-blocking sockets and tolerate `WouldBlock`) but wasteful; the
//! serving path is only deployed on unix.

use std::io;
use std::time::Duration;

/// What a caller wants to be told about one descriptor.
#[derive(Debug, Clone, Copy, Default)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };

    pub fn read_write() -> Interest {
        Interest { readable: true, writable: true }
    }
}

/// What `poll(2)` reported for one descriptor.  `closed` maps
/// `POLLHUP | POLLERR | POLLNVAL`: the caller should read to observe the
/// EOF/error and drop the connection.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Readiness {
    pub readable: bool,
    pub writable: bool,
    pub closed: bool,
}

impl Readiness {
    pub fn any(&self) -> bool {
        self.readable || self.writable || self.closed
    }
}

#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type Fd = i32;

#[cfg(unix)]
mod sys {
    use super::{Fd, Interest, Readiness};
    use std::io;
    use std::time::Duration;

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    // `nfds_t` is `unsigned long` on Linux and `unsigned int` on the
    // BSDs/macOS; both are passed in a register, but keep the ABI exact.
    #[cfg(target_os = "macos")]
    type NFds = core::ffi::c_uint;
    #[cfg(not(target_os = "macos"))]
    type NFds = core::ffi::c_ulong;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: core::ffi::c_int) -> core::ffi::c_int;
        fn pipe(fds: *mut core::ffi::c_int) -> core::ffi::c_int;
    }

    /// One `poll(2)` call.  `EINTR` reports as zero ready descriptors
    /// (the caller loops anyway); any other failure is a real error.
    pub fn wait(
        fds: &mut Vec<PollFd>,
        sources: &[(Fd, Interest)],
        timeout: Duration,
        out: &mut Vec<Readiness>,
    ) -> io::Result<usize> {
        fds.clear();
        for (fd, interest) in sources {
            let mut events = 0i16;
            if interest.readable {
                events |= POLLIN;
            }
            if interest.writable {
                events |= POLLOUT;
            }
            fds.push(PollFd { fd: *fd, events, revents: 0 });
        }
        let ms = timeout.as_millis().min(i32::MAX as u128) as core::ffi::c_int;
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, ms) };
        out.clear();
        if rc < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                out.resize(sources.len(), Readiness::default());
                return Ok(0);
            }
            return Err(err);
        }
        let mut ready = 0usize;
        for pf in fds.iter() {
            let r = Readiness {
                readable: pf.revents & POLLIN != 0,
                writable: pf.revents & POLLOUT != 0,
                closed: pf.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
            };
            if r.any() {
                ready += 1;
            }
            out.push(r);
        }
        Ok(ready)
    }

    /// A `pipe(2)` pair as blocking `File`s (the writer only ever sends
    /// one byte between drains, so it can never fill the pipe buffer;
    /// the reader only reads after `poll` reported it readable, so it
    /// never blocks).
    pub fn pipe_pair() -> io::Result<(std::fs::File, std::fs::File)> {
        use std::os::unix::io::FromRawFd;
        let mut fds = [0 as core::ffi::c_int; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: both fds were just created by pipe() and are owned
        // exclusively by the returned Files.
        unsafe { Ok((std::fs::File::from_raw_fd(fds[0]), std::fs::File::from_raw_fd(fds[1]))) }
    }
}

/// Reusable readiness poller (the pollfd array is kept across calls).
#[derive(Default)]
pub struct Poller {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
}

impl Poller {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wait up to `timeout` for readiness on `sources`; fills `out` with
    /// one [`Readiness`] per source (same order) and returns how many
    /// reported any event.  A timeout is not an error — it returns
    /// `Ok(0)` with every entry idle.
    #[cfg(unix)]
    pub fn wait(
        &mut self,
        sources: &[(Fd, Interest)],
        timeout: Duration,
        out: &mut Vec<Readiness>,
    ) -> io::Result<usize> {
        sys::wait(&mut self.fds, sources, timeout, out)
    }

    /// Non-unix fallback: sleep a beat and report every source both
    /// readable and writable (callers' non-blocking reads/writes then
    /// see `WouldBlock` when there is nothing to do).
    #[cfg(not(unix))]
    pub fn wait(
        &mut self,
        sources: &[(Fd, Interest)],
        timeout: Duration,
        out: &mut Vec<Readiness>,
    ) -> io::Result<usize> {
        std::thread::sleep(timeout.min(Duration::from_millis(2)));
        out.clear();
        for (_, interest) in sources {
            out.push(Readiness {
                readable: interest.readable,
                writable: interest.writable,
                closed: false,
            });
        }
        Ok(sources.len())
    }
}

/// Cross-thread wakeup for a poller: a self-pipe whose read end joins
/// the poll set.  `wake` is deduplicated through an atomic flag, so the
/// pipe never holds more than one unread byte and neither end needs to
/// be non-blocking.
pub struct Waker {
    #[cfg(unix)]
    reader: std::fs::File,
    #[cfg(unix)]
    writer: std::fs::File,
    pending: std::sync::atomic::AtomicBool,
}

impl Waker {
    #[cfg(unix)]
    pub fn new() -> io::Result<Self> {
        let (reader, writer) = sys::pipe_pair()?;
        Ok(Self { reader, writer, pending: std::sync::atomic::AtomicBool::new(false) })
    }

    #[cfg(not(unix))]
    pub fn new() -> io::Result<Self> {
        Ok(Self { pending: std::sync::atomic::AtomicBool::new(false) })
    }

    /// The descriptor to register with [`Poller::wait`] (readable
    /// interest).  On non-unix targets this is a dummy; the fallback
    /// poller reports everything ready anyway.
    #[cfg(unix)]
    pub fn fd(&self) -> Fd {
        use std::os::unix::io::AsRawFd;
        self.reader.as_raw_fd()
    }

    #[cfg(not(unix))]
    pub fn fd(&self) -> Fd {
        -1
    }

    /// Make the owning poller's current (or next) `wait` return.
    /// Cheap and idempotent between drains.
    pub fn wake(&self) {
        use std::sync::atomic::Ordering;
        if !self.pending.swap(true, Ordering::AcqRel) {
            #[cfg(unix)]
            {
                use std::io::Write;
                let _ = (&self.writer).write_all(&[1u8]);
            }
        }
    }

    /// Consume a wakeup after `wait` reported the waker's fd readable.
    /// Clears the dedup flag *before* reading, so a wake racing the
    /// drain at worst causes one spurious (harmless) extra wakeup and
    /// never a lost one.
    // At most one byte is ever pending (see `wake`), so a short read is
    // impossible and a failed one only costs a spurious wakeup later.
    #[allow(clippy::unused_io_amount)]
    pub fn drain(&self) {
        use std::sync::atomic::Ordering;
        self.pending.store(false, Ordering::Release);
        #[cfg(unix)]
        {
            use std::io::Read;
            let mut buf = [0u8; 8];
            let _ = (&self.reader).read(&mut buf);
        }
    }
}

impl std::fmt::Debug for Waker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Waker").finish()
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn reports_readable_once_data_arrives() {
        let (mut a, b) = pair();
        let mut poller = Poller::new();
        let mut out = Vec::new();
        let sources = [(b.as_raw_fd(), Interest::READ)];
        // Nothing written yet: times out idle.
        let n = poller.wait(&sources, Duration::from_millis(10), &mut out).unwrap();
        assert_eq!(n, 0);
        assert!(!out[0].readable);
        a.write_all(b"hi").unwrap();
        let n = poller.wait(&sources, Duration::from_secs(5), &mut out).unwrap();
        assert_eq!(n, 1);
        assert!(out[0].readable);
        // Level-triggered: still readable until drained.
        let n = poller.wait(&sources, Duration::from_millis(50), &mut out).unwrap();
        assert_eq!(n, 1);
        let mut buf = [0u8; 8];
        let got = (&b).read(&mut buf).unwrap();
        assert_eq!(&buf[..got], b"hi");
    }

    #[test]
    fn reports_closed_or_readable_on_peer_hangup() {
        let (a, b) = pair();
        drop(a);
        let mut poller = Poller::new();
        let mut out = Vec::new();
        let sources = [(b.as_raw_fd(), Interest::READ)];
        poller.wait(&sources, Duration::from_secs(5), &mut out).unwrap();
        // A closed peer surfaces as POLLIN (read -> 0) and/or POLLHUP.
        assert!(out[0].readable || out[0].closed, "{:?}", out[0]);
    }

    #[test]
    fn waker_interrupts_a_blocked_wait() {
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        let w2 = std::sync::Arc::clone(&waker);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w2.wake();
        });
        let mut poller = Poller::new();
        let mut out = Vec::new();
        let sources = [(waker.fd(), Interest::READ)];
        let t0 = std::time::Instant::now();
        let n = poller.wait(&sources, Duration::from_secs(30), &mut out).unwrap();
        assert_eq!(n, 1, "waker must interrupt the wait");
        assert!(t0.elapsed() < Duration::from_secs(10));
        waker.drain();
        t.join().unwrap();
        // Drained: the next wait is idle again.
        let n = poller.wait(&sources, Duration::from_millis(10), &mut out).unwrap();
        assert_eq!(n, 0);
        // Wake twice between drains: one byte, one wakeup, no backlog.
        waker.wake();
        waker.wake();
        let n = poller.wait(&sources, Duration::from_secs(5), &mut out).unwrap();
        assert_eq!(n, 1);
        waker.drain();
        let n = poller.wait(&sources, Duration::from_millis(10), &mut out).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn writable_interest_reports_on_an_open_socket() {
        let (a, _b) = pair();
        let mut poller = Poller::new();
        let mut out = Vec::new();
        let n = poller
            .wait(&[(a.as_raw_fd(), Interest::WRITE)], Duration::from_secs(5), &mut out)
            .unwrap();
        assert_eq!(n, 1);
        assert!(out[0].writable);
        assert!(Interest::read_write().readable && Interest::read_write().writable);
    }
}
