//! Dependency-free scoped-thread worker pool with deterministic ordered
//! merge.
//!
//! The planner's outer loops — multistart restarts, the budget×policy
//! sweep grid, Monte-Carlo campaign replications — are embarrassingly
//! parallel: every job is a pure function of its index, and the merge
//! step only needs the results *in index order*.  This module provides
//! exactly that shape on plain `std::thread::scope`, so the offline
//! build stays free of rayon/crossbeam:
//!
//! * **Work stealing by atomic counter** — workers pull the next index
//!   from a shared `AtomicUsize`, so an expensive cell (one slow planner
//!   run) never stalls the whole batch behind a static partition.
//! * **Deterministic ordered merge** — results are delivered as
//!   `(index, value)` pairs and re-assembled into a `Vec` in index
//!   order.  Callers that fold the vector left-to-right therefore see
//!   results in exactly the order the sequential loop would have
//!   produced them, which is what makes the parallel planner
//!   bit-identical to the sequential one (see
//!   `scheduler::find_multistart`, `analysis::run_policy_sweep`).
//! * **`threads` contract** — `0` means auto-detect
//!   ([`std::thread::available_parallelism`]), `1` runs inline on the
//!   caller's thread with no pool at all (the bit-identical baseline and
//!   the default everywhere), `n > 1` caps the pool at `min(n, jobs)`.
//!
//! Determinism caveat: the *values* must themselves be deterministic.
//! Jobs that consume a shared RNG stream must have their per-job state
//! derived **before** the fan-out (the multistart planner derives each
//! restart's perturbed belief system up front for exactly this reason).
//!
//! **No nested multiplicative spawning.**  Several layers can now fan
//! out — multistart restarts and deadline-search probes on the outside,
//! REPLACE/BALANCE candidate scoring on the inside.  Exactly one layer
//! may be parallel at a time: when an outer fan-out actually runs on
//! more than one worker, every inner level must run with `threads = 1`,
//! otherwise `t` restarts × `t` scoring workers would oversubscribe the
//! machine `t`-fold.  [`nested_inner_threads`] encodes the rule; the
//! callers (`scheduler::find_multistart`, `scheduler::deadline`) route
//! their inner planner thread counts through it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Resolve a requested thread count: `0` = auto-detect, otherwise as
/// given.  Auto-detection falls back to 1 when the platform refuses to
/// answer.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Thread budget for an *inner* parallel level nested under an outer
/// fan-out of `outer_jobs` jobs on `outer_threads` workers.
///
/// When the outer level actually runs in parallel (more than one worker
/// after resolving auto-detection and capping at the job count), the
/// inner level is forced to `1` so the two levels never multiply.  When
/// the outer level degenerates to a sequential loop (one job, or
/// `threads = 1`), the whole budget passes through to the inner level
/// unchanged — including `0` (auto-detect).
///
/// Results are unaffected either way: every parallel path in this crate
/// is bit-identical at any thread count, so this helper is purely about
/// not oversubscribing the machine.
pub fn nested_inner_threads(outer_threads: usize, outer_jobs: usize) -> usize {
    if resolve_threads(outer_threads).min(outer_jobs.max(1)) > 1 {
        1
    } else {
        outer_threads
    }
}

/// Run `f(0), f(1), ..., f(jobs - 1)` on up to `threads` scoped workers
/// and return the results **in index order**.
///
/// `threads` follows the module contract (`0` = auto, `1` = inline
/// sequential, `n` = capped pool).  Workers steal indices dynamically;
/// a panicking job propagates to the caller once the scope joins.
pub fn parallel_map<T, F>(threads: usize, jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = resolve_threads(threads).min(jobs);
    if threads <= 1 {
        return (0..jobs).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(jobs, || None);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs {
                    break;
                }
                // The receiver only disappears if the main thread is
                // already unwinding; stop quietly in that case.
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            slots[i] = Some(v);
        }
    });

    // Reached only if no worker panicked (the scope re-raises panics),
    // in which case every index was delivered exactly once.
    slots
        .into_iter()
        .map(|s| s.expect("parallel_map: worker delivered every index"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_merge_matches_sequential() {
        let seq: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [0, 1, 2, 4, 7] {
            let par = parallel_map(threads, 100, |i| i * i);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn zero_jobs_is_empty() {
        assert!(parallel_map(4, 0, |i| i).is_empty());
    }

    #[test]
    fn one_job_runs_inline() {
        assert_eq!(parallel_map(8, 1, |i| i + 41), vec![41]);
    }

    #[test]
    fn uneven_job_durations_still_ordered() {
        // Early indices sleep longest: with work stealing they finish
        // last, exercising the out-of-order delivery path.
        let out = parallel_map(4, 16, |i| {
            std::thread::sleep(std::time::Duration::from_millis((16 - i) as u64));
            i
        });
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn resolve_threads_contract() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(6), 6);
    }

    #[test]
    fn nested_inner_threads_forces_one_under_a_parallel_outer() {
        // A genuinely parallel outer level always pins the inner to 1.
        assert_eq!(nested_inner_threads(2, 8), 1);
        assert_eq!(nested_inner_threads(4, 2), 1);
        assert_eq!(nested_inner_threads(16, 16), 1);
        // Auto-detect counts as parallel whenever the machine has >1 core
        // and there is >1 job; with 1 job it degenerates to sequential.
        if resolve_threads(0) > 1 {
            assert_eq!(nested_inner_threads(0, 8), 1);
        }
        assert_eq!(nested_inner_threads(0, 1), 0);
        // A sequential outer level passes the budget through unchanged.
        assert_eq!(nested_inner_threads(1, 8), 1);
        assert_eq!(nested_inner_threads(4, 1), 4);
        assert_eq!(nested_inner_threads(4, 0), 4);
    }

    #[test]
    fn non_copy_results_supported() {
        let out = parallel_map(3, 5, |i| vec![i; i]);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.len(), i);
        }
    }
}
