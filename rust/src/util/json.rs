//! A small JSON value model, recursive-descent parser and writer.
//!
//! Used by the config loader, the coordinator's line-delimited wire
//! protocol and the benchmark report files.  Implements RFC 8259 minus
//! `\u` surrogate-pair edge cases beyond the BMP (sufficient for this
//! project's ASCII protocol; the parser still accepts all escapes).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so output is
/// deterministic — important for golden tests and reproducible reports.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors -------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----- accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.field` chained lookup: `j.path(&["config", "budget"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ----- parsing ------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ----- writing ----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": 1e3}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d").unwrap().as_f64(), Some(1000.0));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_structured() {
        let v = Json::obj(vec![
            ("budget", Json::num(42.5)),
            ("name", Json::str("paper")),
            ("flags", Json::arr([Json::Bool(true), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café λ""#).unwrap();
        assert_eq!(v.as_str(), Some("café λ"));
        let round = v.to_string();
        assert_eq!(Json::parse(&round).unwrap(), v);
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 6, "pos {}", e.pos);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[1] junk").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }
}
