//! Deterministic, seedable PRNG: xoshiro256** seeded via SplitMix64.
//!
//! All randomness in the workload generators and the cloud simulator flows
//! from explicit seeds through this generator, so every experiment in
//! EXPERIMENTS.md is bit-reproducible.  (The `rand` crate is not available
//! in this offline build; the algorithms below are the public-domain
//! reference constructions of Blackman & Vigna.)

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically (any u64, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s, spare_normal: None }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method; n must be > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free for our workloads: 128-bit multiply-shift.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let threshold = n.wrapping_neg() % n;
            while lo < threshold {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal deviate (Box-Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (`lambda > 0`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Choose one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Fork a derived, independent stream (for per-VM / per-request RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_approx() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform(0.0, 10.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let x = r.range(1, 5);
            assert!((1..=5).contains(&x));
            lo_seen |= x == 1;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(23);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
