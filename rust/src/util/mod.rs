//! In-tree substrates that a comparable project would take as
//! dependencies; this workspace builds fully offline, so they are
//! implemented from scratch:
//!
//! * [`rng`] — deterministic seedable PRNG (SplitMix64 / xoshiro256**)
//!   with uniform/normal/log-normal sampling, shuffling and choice;
//! * [`json`] — a small JSON value model, parser and writer used by the
//!   config loader, the coordinator wire protocol and the report files;
//! * [`parallel`] — a scoped-thread worker pool with deterministic
//!   ordered merge, driving the multistart/sweep/campaign outer loops;
//! * [`cancel`] — a cooperative cancellation token threaded from the
//!   coordinator's job engine into the long planner/simulator loops;
//! * [`netpoll`] — a dependency-free `poll(2)` wrapper + self-pipe
//!   waker, the readiness substrate of the coordinator's non-blocking
//!   connection workers;
//! * [`failpoint`] — a process-global fault-injection registry (named
//!   error/delay/panic/torn-write points with probability and budget)
//!   that compiles down to one relaxed atomic load when disarmed,
//!   powering the coordinator's chaos-test layer.

pub mod cancel;
pub mod failpoint;
pub mod json;
pub mod netpoll;
pub mod parallel;
pub mod rng;

pub use cancel::CancelToken;
pub use json::Json;
pub use parallel::{nested_inner_threads, parallel_map, resolve_threads};
pub use rng::Rng;
