//! Cooperative cancellation: a cheaply clonable flag threaded from the
//! coordinator's job engine down through [`crate::scheduler::SolveRequest`]
//! into the long-running planner and simulator loops.
//!
//! Cancellation is *cooperative*: setting the token never interrupts a
//! thread.  Each long loop (FIND iterations, multistart restarts,
//! deadline bisection rounds, campaign rounds and replications, sweep
//! cells) polls [`CancelToken::is_cancelled`] at its natural checkpoint
//! and returns the best partial result it has.  A default token is never
//! cancelled, so un-threaded callers pay one relaxed atomic load per
//! checkpoint and nothing else.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A shared cancellation flag.  Clones observe the same flag; the
/// default token can never be cancelled by anyone who does not hold a
/// clone of it.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation.  Idempotent; visible to every clone.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_not_cancelled() {
        assert!(!CancelToken::new().is_cancelled());
    }

    #[test]
    fn clones_share_the_flag() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!c.is_cancelled());
        t.cancel();
        assert!(c.is_cancelled());
        // Idempotent.
        c.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn cross_thread_visibility() {
        let t = CancelToken::new();
        let c = t.clone();
        let h = std::thread::spawn(move || {
            while !c.is_cancelled() {
                std::thread::yield_now();
            }
            true
        });
        t.cancel();
        assert!(h.join().unwrap());
    }
}
