//! `botsched` — the command-line launcher.
//!
//! ```text
//! botsched figures [--fig 1|2] [--overhead o] [--json out.json]
//! botsched scenarios                             # named workload presets
//! botsched plan    --budget B [--system paper|file.json | --scenario <name>]
//!                  [--policy <name>] [--threads T]
//! botsched sweep   [--budgets 40,45,..] [--system ...] [--threads T] [--ablate]
//! botsched simulate --budget B [--sigma s] [--lifetime m] [--seed n]
//! botsched campaign --budget B [--lifetime m] [--reserve f] [--seed n]
//!                  [--replications N] [--threads T]
//! botsched estimate [--per-cell n] [--sigma s] [--seed n]
//! botsched bounds   [--budgets ...]
//! botsched serve   [--addr 127.0.0.1:7077] [--no-xla] [--no-batching] [--shards N]
//!                  [--conn-workers N] [--max-backlog N] [--journal state.journal]
//!                  [--cache-capacity N] [--conn-idle-timeout SECS] [--watchdog-stuck-ms MS]
//!                  [--chaos "point=action[@p][xN];…"] [--chaos-allowed]
//! botsched client  --addr host:port '<json request>'
//! botsched submit  [--priority P] [--deadline-ms D] [--addr host:port] '<json job>'
//! botsched jobs    [--addr host:port]            # list the engine's jobs
//! botsched cancel  --job j-3 [--addr host:port]  # cancel a running job
//! botsched loadgen [--addr host:port] [--rate R] [--arrival poisson|bursty:..|diurnal:..|pareto:..]
//!                  [--clients N] [--duration SECS] [--scenario-mix "a=2,b"] [--policy-mix ...]
//!                  [--priority-mix "0=8,9=2"] [--engine-frac f] [--deadline-frac f]
//!                  [--deadline-ms LO:HI] [--seed S] [--record tape.json] [--replay tape.json]
//!                  [--sweep "50,100,200"] [--json report.json]
//! ```
//!
//! Everything is also available programmatically through the `botsched`
//! library; the CLI is a thin shell over it.

use std::collections::HashMap;
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use botsched::analysis::report::{run_sweep, run_sweep_threads};
use botsched::analysis::{fractional_cost_floor, makespan_floor};
use botsched::cloudsim::{run_campaign, sample_runs, CampaignSpec, NoiseModel, SimConfig, Simulator};
use botsched::coordinator::{Coordinator, CoordinatorConfig};
use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::System;
use botsched::scheduler::{canonical_name, Planner, PlannerConfig, PolicyRegistry, SolveRequest};
use botsched::workload::paper;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal flag parser: `--key value` pairs plus positional arguments.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(args: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // Boolean flags have no value (next token is a flag or end).
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), it.next().unwrap().clone());
                    }
                    _ => {
                        flags.insert(key.to_string(), "true".into());
                    }
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Self { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} {v}")))
            .transpose()
    }

    fn u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{key} {v}")))
            .transpose()
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_sys(a: &Args) -> Result<System> {
    // One resolver for --system/--scenario/--overhead: the same
    // api::SystemRef the wire protocol uses (exclusivity rule, unknown
    // scenario listing, Table I fallback).
    let target = botsched::coordinator::api::SystemRef {
        system: a
            .get("system")
            .map(|s| botsched::coordinator::api::SystemSpec::Named(s.to_string())),
        scenario: a.get("scenario").map(str::to_string),
        overhead: a.f64("overhead")?,
    };
    target.resolve().map_err(|e| {
        if e.message.contains("unknown scenario") {
            anyhow!("{} — see `botsched scenarios`", e.message)
        } else {
            anyhow!("{}", e.message)
        }
    })
}

fn evaluator(a: &Args) -> Box<dyn PlanEvaluator> {
    if a.has("no-xla") {
        return Box::new(NativeEvaluator);
    }
    match botsched::runtime::XlaEvaluator::load() {
        Ok(x) => Box::new(x),
        Err(e) => {
            eprintln!("note: using native evaluator (XLA artifacts unavailable: {e:#})");
            Box::new(NativeEvaluator)
        }
    }
}

fn noise(a: &Args) -> Result<NoiseModel> {
    Ok(NoiseModel {
        task_sigma: a.f64("sigma")?.unwrap_or(0.0),
        boot_sigma: a.f64("sigma")?.unwrap_or(0.0),
        mean_lifetime: a.f64("lifetime")?,
    })
}

fn budgets(a: &Args) -> Result<Vec<f64>> {
    match a.get("budgets") {
        None => Ok(paper::BUDGETS.to_vec()),
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse::<f64>().with_context(|| format!("budget {s}")))
            .collect(),
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_help();
        return Ok(());
    };
    let a = Args::parse(&args[1..])?;
    match cmd.as_str() {
        "figures" => cmd_figures(&a),
        "policies" => cmd_policies(),
        "scenarios" => cmd_scenarios(),
        "plan" => cmd_plan(&a),
        "sweep" => cmd_sweep(&a),
        "simulate" => cmd_simulate(&a),
        "campaign" => cmd_campaign(&a),
        "estimate" => cmd_estimate(&a),
        "bounds" => cmd_bounds(&a),
        "pareto" => cmd_pareto(&a),
        "trace" => cmd_trace(&a),
        "serve" => cmd_serve(&a),
        "client" => cmd_client(&a),
        "submit" => cmd_submit(&a),
        "jobs" => cmd_jobs(&a),
        "cancel" => cmd_cancel(&a),
        "loadgen" => cmd_loadgen(&a),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `botsched help`)"),
    }
}

fn print_help() {
    println!(
        "botsched — budget-constrained multi-BoT scheduling on the cloud\n\
         (reproduction of Thai/Varghese/Barker, IEEE CLOUD 2015)\n\n\
         commands:\n\
         \x20 figures   regenerate Table I, Fig. 1, Fig. 2 and the headline claims\n\
         \x20 policies  list the registered scheduling policies\n\
         \x20 scenarios list the named workload presets (--scenario <name>)\n\
         \x20 plan      plan one budget (--budget B, --policy <name>, --deadline D, --multistart N, --threads T)\n\
         \x20 sweep     full budget sweep (--budgets 40,45,.. --threads T, --ablate for phase ablation)\n\
         \x20 simulate  plan + execute on the simulated cloud (--sigma, --lifetime, --seed)\n\
         \x20 campaign  closed-loop execution with failures + replanning (--reserve, --policy, --deadline,\n\
         \x20           --replications N --threads T for Monte-Carlo replication)\n\
         \x20 estimate  bootstrap the performance matrix from sampled test runs\n\
         \x20 bounds    LP cost floor and budget-capped makespan floor\n\
         \x20 pareto    budget/makespan Pareto frontier + knee\n\
         \x20 trace     gen/replay multi-campaign arrival traces\n\
         \x20 serve     start the coordinator (--addr, --no-xla, --no-batching, --shards N,\n\
         \x20           --conn-workers N, --max-backlog N, --journal <path> for crash-recoverable\n\
         \x20           jobs, --cache-capacity N to cache repeated plan solves,\n\
         \x20           --conn-idle-timeout SECS to evict silent connections,\n\
         \x20           --watchdog-stuck-ms MS to respawn stuck workers,\n\
         \x20           --chaos \"point=action[@p][xN];..\" / --chaos-allowed for fault injection)\n\
         \x20 client    send one JSON request to a coordinator\n\
         \x20 submit    enqueue a job (--priority 0..=9, --deadline-ms D) and print its id\n\
         \x20 jobs      list a coordinator's jobs (state, progress)\n\
         \x20 cancel    cancel a coordinator job (--job j-3)\n\
         \x20 loadgen   open-loop load generator vs a live coordinator (--rate R --arrival <proc>\n\
         \x20           --clients N --duration SECS --scenario-mix \"a=2,b\" --engine-frac f,\n\
         \x20           --record/--replay tape.json for bit-identical traffic tapes,\n\
         \x20           --sweep \"50,100,200\" to find the saturation knee; SLO report via --json)\n\n\
         common flags: --system paper|paper:<overhead>|file.json, --scenario <name>,\n\
         \x20             --overhead o, --no-xla"
    );
}

fn cmd_figures(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let eval = evaluator(a);
    let fig = a.u64("fig")?.unwrap_or(0);
    let report = run_sweep(&sys, &budgets(a)?, eval.as_ref());
    if fig == 0 || fig == 1 {
        println!("{}", paper::table1_text());
        print!("{}", report.fig1_text());
        println!();
        print!("{}", report.headline().text());
    }
    if fig == 0 || fig == 2 {
        print!("{}", report.fig2_text(&sys));
    }
    if let Some(path) = a.get("json") {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_policies() -> Result<()> {
    let registry = PolicyRegistry::builtin();
    println!("registered policies:");
    for p in registry.iter() {
        println!("  {:<16} {}", p.name(), p.description());
    }
    println!("\n(select with --policy <name>; \"heuristic\" is accepted as an alias)");
    Ok(())
}

fn cmd_scenarios() -> Result<()> {
    println!("named workload scenarios:");
    for s in botsched::workload::SCENARIOS {
        println!("  {:<16} {}", s.name, s.description);
    }
    println!("\n(select with --scenario <name>, or \"scenario\" on wire requests)");
    Ok(())
}

fn cmd_plan(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let budget = a.f64("budget")?.ok_or_else(|| anyhow!("--budget required"))?;
    let mut name = a
        .get("policy")
        .or_else(|| a.get("approach"))
        .unwrap_or("budget-heuristic")
        .to_string();
    let eval = evaluator(a);
    let mut req = SolveRequest::new(budget)
        .with_evaluator(eval.as_ref())
        .with_seed(a.u64("seed")?.unwrap_or(0))
        .with_threads(a.u64("threads")?.unwrap_or(1) as usize);
    if let Some(d) = a.f64("deadline")? {
        req = req.with_deadline(d);
        if canonical_name(&name) == "budget-heuristic" {
            name = "deadline".into();
        }
    }
    if let Some(n) = a.u64("multistart")? {
        if n > 1 {
            req = req.with_starts(n as usize);
            if canonical_name(&name) == "budget-heuristic" {
                name = "multistart".into();
            }
        }
    }
    let registry = PolicyRegistry::builtin();
    let t0 = std::time::Instant::now();
    let out = registry.solve(&name, &sys, &req)?;
    let elapsed = t0.elapsed();
    println!(
        "policy={} budget={budget} makespan={:.1}s cost={} feasible={} vms={} \
         iterations={} probes={} planned_in={:?}",
        out.policy,
        out.score.makespan,
        out.score.cost,
        out.feasible,
        out.plan.n_vms(),
        out.iterations,
        out.probes,
        elapsed
    );
    for (i, vm) in out.plan.vms.iter().enumerate() {
        println!(
            "  vm{i:<3} {:<22} tasks={:<4} exec={:>8.1}s cost={}",
            sys.instance_type(vm.it).name,
            vm.len(),
            vm.exec(&sys),
            vm.cost(&sys)
        );
    }
    Ok(())
}

fn cmd_sweep(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let eval = evaluator(a);
    let bs = budgets(a)?;
    if a.has("ablate") {
        // Phase-ablation study: disable one phase at a time.
        println!("ablation over budgets {bs:?} (mean makespan, feasible cells)");
        #[allow(clippy::type_complexity)]
        let phases: [(&str, fn(&mut PlannerConfig)); 6] = [
            ("full", |_| {}),
            ("-reduce", |c| c.enable_reduce = false),
            ("-add", |c| c.enable_add = false),
            ("-balance", |c| c.enable_balance = false),
            ("-split", |c| c.enable_split = false),
            ("-replace", |c| c.enable_replace = false),
        ];
        for (name, tweak) in phases {
            let mut cfg = PlannerConfig::default();
            tweak(&mut cfg);
            let mut spans = Vec::new();
            let mut feasible = 0usize;
            for &b in &bs {
                let r = Planner::with_evaluator(&sys, eval.as_ref())
                    .with_config(cfg.clone())
                    .find(b);
                if r.feasible {
                    feasible += 1;
                }
                spans.push(r.score.makespan);
            }
            let mean = spans.iter().sum::<f64>() / spans.len() as f64;
            println!("  {name:<9} mean_makespan={mean:>9.1}s feasible={feasible}/{}", bs.len());
        }
        return Ok(());
    }
    let threads = a.u64("threads")?.unwrap_or(1) as usize;
    let report = run_sweep_threads(&sys, &bs, eval.as_ref(), threads);
    print!("{}", report.fig1_text());
    print!("{}", report.headline().text());
    if let Some(path) = a.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_simulate(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let budget = a.f64("budget")?.ok_or_else(|| anyhow!("--budget required"))?;
    let name = a.get("policy").or_else(|| a.get("approach")).unwrap_or("budget-heuristic");
    let eval = evaluator(a);
    let req = SolveRequest::new(budget)
        .with_evaluator(eval.as_ref())
        .with_seed(a.u64("seed")?.unwrap_or(0));
    let report = PolicyRegistry::builtin().solve(name, &sys, &req)?;
    let cfg = SimConfig { noise: noise(a)?, seed: a.u64("seed")?.unwrap_or(0) };
    let sim = Simulator::run_plan(&sys, &report.plan, &cfg);
    println!(
        "planned ({}): makespan={:.1}s cost={} feasible={}",
        report.policy, report.score.makespan, report.score.cost, report.feasible
    );
    println!(
        "simulated: makespan={:.1}s cost={} completed={} stranded={} failures={}",
        sim.makespan,
        sim.cost,
        sim.completed.len(),
        sim.stranded.len(),
        sim.failures
    );
    Ok(())
}

fn cmd_campaign(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let budget = a.f64("budget")?.ok_or_else(|| anyhow!("--budget required"))?;
    let mut spec = CampaignSpec::new(budget);
    if let Some(p) = a.get("policy") {
        spec.policy = PolicyRegistry::builtin().resolve_arc(p)?;
    }
    if let Some(d) = a.f64("deadline")? {
        spec.base_request = spec.base_request.with_deadline(d);
    }
    spec.evaluator = Some(std::sync::Arc::from(evaluator(a)));
    spec.sim.noise = noise(a)?;
    spec.sim.seed = a.u64("seed")?.unwrap_or(0);
    if let Some(r) = a.f64("reserve")? {
        spec = spec.with_reserve(r);
    }
    if let Some(m) = a.u64("max-rounds")? {
        spec.max_rounds = m as usize;
    }
    let replications = a.u64("replications")?.unwrap_or(1).max(1) as usize;
    if replications > 1 {
        let threads = a.u64("threads")?.unwrap_or(1) as usize;
        let outs =
            botsched::cloudsim::run_campaign_replications(&sys, &spec, replications, threads);
        let s = botsched::cloudsim::summarise_replications(&outs);
        println!(
            "campaign x{}: complete={}/{} within_budget={}/{} mean_wall={:.1}s mean_spent={:.2}",
            s.replications,
            s.complete,
            s.replications,
            s.within_budget,
            s.replications,
            s.mean_wall_clock,
            s.mean_spent
        );
        for (i, o) in outs.iter().enumerate() {
            println!(
                "  rep {i}: wall={:.1}s spent={} complete={} within_budget={} rounds={}",
                o.wall_clock,
                o.spent,
                o.complete,
                o.within_budget,
                o.rounds.len()
            );
        }
        return Ok(());
    }
    let out = run_campaign(&sys, &spec);
    println!(
        "campaign: wall={:.1}s spent={} complete={} within_budget={} rounds={}",
        out.wall_clock,
        out.spent,
        out.complete,
        out.within_budget,
        out.rounds.len()
    );
    for (i, r) in out.rounds.iter().enumerate() {
        println!(
            "  round {i}: completed={} stranded={} failures={} cost={} makespan={:.1}s",
            r.completed.len(),
            r.stranded.len(),
            r.failures,
            r.cost,
            r.makespan
        );
    }
    Ok(())
}

fn cmd_estimate(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let per_cell = a.u64("per-cell")?.unwrap_or(20) as usize;
    let obs = sample_runs(&sys, per_cell, &noise(a)?, a.u64("seed")?.unwrap_or(0));
    let cells = sys.n_types() * sys.n_apps();
    let prior = vec![0.0; cells];
    let est = match botsched::runtime::XlaPerfEstimator::load() {
        Ok(e) if !a.has("no-xla") => {
            println!("estimator: xla artifact ({} samples)", obs.len());
            e.estimate(&sys, &obs, &prior, 1e-9)?
        }
        _ => {
            println!("estimator: native ({} samples)", obs.len());
            botsched::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-9)
        }
    };
    println!(
        "{:<22}{}",
        "instance type",
        sys.apps.iter().map(|ap| format!("{:>12}", ap.name)).collect::<String>()
    );
    for it in &sys.instance_types {
        let mut row = format!("{:<22}", it.name);
        for app in &sys.apps {
            let got = est[it.id.index() * sys.n_apps() + app.id.index()];
            let truth = sys.perf.get(it.id, app.id);
            row.push_str(&format!("{:>7.2}/{:<4.1}", got, truth));
        }
        println!("{row}");
    }
    println!("(estimated/true seconds per unit size)");
    Ok(())
}

fn cmd_bounds(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    println!("LP cost floor: {:.2}", fractional_cost_floor(&sys));
    for &b in &budgets(a)? {
        let f = makespan_floor(&sys, b);
        println!("budget {b:>7}: makespan floor {f:>9.1}s");
    }
    Ok(())
}

fn cmd_pareto(a: &Args) -> Result<()> {
    let sys = load_sys(a)?;
    let budgets = budgets(a)?;
    let frontier = botsched::analysis::pareto_frontier(&sys, &budgets);
    if frontier.is_empty() {
        println!("no feasible points across budgets {budgets:?}");
        return Ok(());
    }
    println!("{:>10} {:>10} {:>12}", "budget", "cost", "makespan");
    for p in &frontier {
        println!("{:>10} {:>10} {:>11.1}s", p.budget, p.score.cost, p.score.makespan);
    }
    if let Some(k) = botsched::analysis::knee(&frontier) {
        println!("knee: budget {} (cost {}, makespan {:.1}s)", k.budget, k.score.cost, k.score.makespan);
    }
    Ok(())
}

fn cmd_trace(a: &Args) -> Result<()> {
    use botsched::workload::Trace;
    match a.positional.first().map(String::as_str) {
        Some("gen") => {
            let path = a.get("out").unwrap_or("trace.json");
            let t = Trace::synthetic(
                a.u64("seed")?.unwrap_or(0),
                a.u64("campaigns")?.unwrap_or(10) as usize,
                a.f64("mean-gap")?.unwrap_or(600.0),
            );
            t.save(std::path::Path::new(path))?;
            println!("wrote {} campaigns to {path}", t.entries.len());
            Ok(())
        }
        Some("replay") => {
            let path = a.get("in").ok_or_else(|| anyhow!("--in trace.json required"))?;
            let t = Trace::load(std::path::Path::new(path))?;
            let rows = botsched::workload::replay(&t);
            println!(
                "{:>10} {:>8} {:>10} {:>8} {:>10} {:>9}",
                "arrival", "budget", "makespan", "cost", "finish", "feasible"
            );
            for r in &rows {
                println!(
                    "{:>9.1}s {:>8} {:>9.1}s {:>8} {:>9.1}s {:>9}",
                    r.at, r.budget, r.makespan, r.cost, r.finish_at, r.feasible
                );
            }
            let feasible = rows.iter().filter(|r| r.feasible).count();
            println!("{feasible}/{} campaigns feasible", rows.len());
            Ok(())
        }
        _ => bail!("usage: botsched trace gen --out t.json | botsched trace replay --in t.json"),
    }
}

fn cmd_serve(a: &Args) -> Result<()> {
    let cfg = CoordinatorConfig {
        addr: a.get("addr").unwrap_or("127.0.0.1:7077").to_string(),
        use_xla: !a.has("no-xla"),
        batching: !a.has("no-batching"),
        batch_wait: std::time::Duration::from_millis(a.u64("batch-wait-ms")?.unwrap_or(2)),
        shards: a.u64("shards")?.unwrap_or(0) as usize,
        conn_workers: a.u64("conn-workers")?.unwrap_or(0) as usize,
        max_backlog: a.u64("max-backlog")?.unwrap_or(0) as usize,
        journal: a.get("journal").map(Into::into),
        cache_capacity: a.u64("cache-capacity")?.unwrap_or(0) as usize,
        conn_idle_timeout: a
            .u64("conn-idle-timeout")?
            .map(std::time::Duration::from_secs),
        // An inline --chaos spec implies permission to drive the
        // registry over the wire; --chaos-allowed grants it bare.
        chaos_allowed: a.has("chaos-allowed") || a.get("chaos").is_some(),
        chaos_spec: a.get("chaos").map(str::to_string),
        watchdog_stuck: a
            .u64("watchdog-stuck-ms")?
            .map(std::time::Duration::from_millis),
    };
    let c = Coordinator::start(cfg)?;
    println!("coordinator listening on {} (send {{\"op\":\"shutdown\"}} to stop)", c.local_addr);
    c.wait();
    println!("coordinator stopped");
    Ok(())
}

fn cmd_client(a: &Args) -> Result<()> {
    let addr = client_addr(a)?;
    let line = a
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: botsched client --addr host:port '<json>'"))?;
    let reply = botsched::coordinator::server::request(&addr, line)?;
    println!("{reply}");
    Ok(())
}

/// `botsched submit --priority 9 --deadline-ms 5000 '<json job>'`: wrap
/// a request as an async engine job with an explicit queue placement.
/// Prints the job id to poll with `status` — or the typed `busy`
/// rejection (with the server's retry hint) when the target shard's
/// backlog is at its bound.
fn cmd_submit(a: &Args) -> Result<()> {
    let raw = a
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: botsched submit [--priority P] [--deadline-ms D] '<json job>'"))?;
    let job = botsched::util::Json::parse(raw).map_err(|e| anyhow!("bad job json: {e}"))?;
    let placement = botsched::coordinator::api::Placement {
        priority: a.u64("priority")?,
        deadline_ms: a.u64("deadline-ms")?,
    };
    let mut client = botsched::coordinator::Client::connect(&client_addr(a)?)?;
    match client.submit_raw(job, placement) {
        Ok(id) => println!("{id}: submitted (poll with `botsched jobs` or the status op)"),
        Err(botsched::coordinator::ClientError::Busy(b)) => {
            print!(
                "busy: shard {} backlog {} is at its bound — retry later or lower the load",
                b.shard, b.backlog
            );
            match b.retry_after_ms {
                Some(ms) => println!(" (server suggests ~{ms}ms)"),
                None => println!(),
            }
        }
        Err(e) => return Err(e.into()),
    }
    Ok(())
}

fn client_addr(a: &Args) -> Result<std::net::SocketAddr> {
    a.get("addr")
        .unwrap_or("127.0.0.1:7077")
        .parse()
        .context("--addr host:port")
}

/// `botsched jobs`: list the coordinator's jobs with state + progress.
fn cmd_jobs(a: &Args) -> Result<()> {
    let mut client = botsched::coordinator::Client::connect(&client_addr(a)?)?;
    let jobs = client.jobs()?;
    if jobs.is_empty() {
        println!("no jobs");
        return Ok(());
    }
    println!("{:<8} {:<12} {:<10} progress", "id", "op", "state");
    for j in jobs {
        let progress = match j.progress {
            Some((d, t)) => format!("{d}/{t}"),
            None => "-".into(),
        };
        println!("{:<8} {:<12} {:<10} {progress}", j.id, j.op, j.state);
    }
    Ok(())
}

/// `botsched cancel --job j-3`: fire a job's cancel token.  The typed
/// client encodes the request, so a hostile job id cannot inject fields
/// into the wire line.
fn cmd_cancel(a: &Args) -> Result<()> {
    let job = a.get("job").ok_or_else(|| anyhow!("--job <job_id> required"))?;
    let mut client = botsched::coordinator::Client::connect(&client_addr(a)?)?;
    if client.cancel(job)? {
        println!("{job}: cancellation requested (work stops at its next checkpoint)");
    } else {
        println!("{job}: not cancellable (already finished or unknown)");
    }
    Ok(())
}

/// Build the load generator's request mix from CLI flags.
fn loadgen_mix(a: &Args) -> Result<botsched::loadgen::MixSpec> {
    use botsched::loadgen::{mix::parse_weighted, DeadlineMix, MixSpec, Weighted};
    let mut m = MixSpec::new("uniform-small")?;
    if let Some(spec) = a.get("scenario-mix") {
        m.scenarios = MixSpec::parse_scenarios(spec)?;
    }
    if let Some(spec) = a.get("policy-mix") {
        m.policies = Weighted::new(parse_weighted(spec)?)?;
    }
    if let Some(spec) = a.get("priority-mix") {
        let pairs = parse_weighted(spec)?
            .into_iter()
            .map(|(p, w)| Ok((p.parse::<u64>().with_context(|| format!("priority {p:?}"))?, w)))
            .collect::<Result<Vec<_>>>()?;
        m.priorities = Weighted::new(pairs)?;
    }
    if let Some(frac) = a.f64("engine-frac")? {
        m.engine_frac = frac;
    }
    if let Some(prob) = a.f64("deadline-frac")? {
        let (lo_ms, hi_ms) = match a.get("deadline-ms") {
            Some(span) => {
                let (lo, hi) = span
                    .split_once(':')
                    .ok_or_else(|| anyhow!("--deadline-ms wants LO:HI, got {span:?}"))?;
                (
                    lo.parse().with_context(|| format!("--deadline-ms lo {lo:?}"))?,
                    hi.parse().with_context(|| format!("--deadline-ms hi {hi:?}"))?,
                )
            }
            None => (500, 5_000),
        };
        m.deadline = Some(DeadlineMix { prob, lo_ms, hi_ms });
    }
    m.validate()?;
    Ok(m)
}

/// `botsched loadgen`: open-loop load against a live coordinator, with
/// record/replay tapes, an SLO report and a saturation-knee sweep mode.
fn cmd_loadgen(a: &Args) -> Result<()> {
    use botsched::loadgen::{run_load, run_sweep, ArrivalProcess, ExecOptions, LoadConfig};
    use botsched::workload::LoadTrace;

    let addr = client_addr(a)?;
    let mut opts = ExecOptions::default();
    if let Some(s) = a.f64("drain-timeout")? {
        opts.drain_timeout = std::time::Duration::from_secs_f64(s);
    }
    let json_out = |path: Option<&str>, json: &botsched::util::Json| -> Result<()> {
        if let Some(path) = path {
            std::fs::write(path, format!("{json}\n")).with_context(|| format!("writing {path}"))?;
            println!("wrote {path}");
        }
        Ok(())
    };

    // Replay: the tape already pins every request and its schedule.
    if let Some(path) = a.get("replay") {
        let trace = LoadTrace::load(std::path::Path::new(path))?;
        println!(
            "replaying {path}: {} requests, {} clients, {} ({} req/s offered)",
            trace.entries.len(),
            trace.clients,
            trace.arrival,
            trace.offered_rate
        );
        let report = botsched::loadgen::execute(&addr, &trace, &opts)?;
        print!("{}", report.table());
        return json_out(a.get("json"), &report.to_json());
    }

    let cfg = LoadConfig {
        rate: a.f64("rate")?.unwrap_or(50.0),
        duration_s: a.f64("duration")?.unwrap_or(5.0),
        clients: a.u64("clients")?.unwrap_or(4) as usize,
        arrival: ArrivalProcess::parse(a.get("arrival").unwrap_or("poisson"))?,
        mix: loadgen_mix(a)?,
        seed: a.u64("seed")?.unwrap_or(0),
    };

    // Sweep: step the offered rate to find the saturation knee.
    if let Some(list) = a.get("sweep") {
        let rates = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().parse::<f64>().with_context(|| format!("sweep rate {s:?}")))
            .collect::<Result<Vec<_>>>()?;
        let sweep = run_sweep(&addr, &cfg, &rates, &opts)?;
        print!("{}", sweep.table());
        return json_out(a.get("json"), &sweep.to_json());
    }

    let (trace, report) = run_load(&addr, &cfg, &opts)?;
    if let Some(path) = a.get("record") {
        trace.save(std::path::Path::new(path))?;
        println!("recorded {} requests to {path}", trace.entries.len());
    }
    print!("{}", report.table());
    json_out(a.get("json"), &report.to_json())
}
