//! A small criterion-style benchmarking harness (the criterion crate is
//! not available in this offline build).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```no_run
//! use botsched::benchkit::Bench;
//! let mut b = Bench::new("planner");
//! b.run("find@80", || {
//!     // timed closure
//! });
//! b.report();
//! ```
//!
//! Each case is warmed up, then run for a target wall-time budget
//! (adaptive iteration count), and summarised with mean / median / p95 /
//! stddev and derived throughput.  Output goes to stdout in a fixed-width
//! table that `cargo bench` captures into bench_output.txt.
//!
//! **Machine-readable results:** when the `BENCH_JSON` environment
//! variable is set, [`Bench::report`] additionally writes
//! `BENCH_<group>.json` (case name, mean/median/p95/stddev in
//! nanoseconds, iteration count, throughput) next to the stdout table —
//! set `BENCH_JSON=1` for the current directory, or to a directory path.
//! This is how the repo's perf trajectory accumulates across PRs:
//! `BENCH_JSON=1 cargo bench --bench scaling` snapshots the planner's
//! scaling numbers into `BENCH_scaling.json`.

use std::time::{Duration, Instant};

use crate::analysis::stats;
use crate::util::Json;

/// One measured case.
#[derive(Debug, Clone)]
pub struct Case {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub stddev: Duration,
    /// Optional user-provided items-per-iteration (for throughput).
    pub items: Option<f64>,
}

/// A named group of benchmark cases.
pub struct Bench {
    pub group: String,
    warmup: Duration,
    target: Duration,
    max_iters: usize,
    cases: Vec<Case>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            warmup: Duration::from_millis(100),
            target: Duration::from_millis(700),
            max_iters: 10_000,
            cases: Vec::new(),
        }
    }

    /// Override the measurement budget (per case).
    pub fn with_budget(mut self, warmup: Duration, target: Duration) -> Self {
        self.warmup = warmup;
        self.target = target;
        self
    }

    /// Time a closure.  Returns the recorded case.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &Case {
        self.run_with_items(name, None, f)
    }

    /// Time a closure that processes `items` items per iteration
    /// (enables the throughput column).
    pub fn run_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: Option<f64>,
        mut f: F,
    ) -> &Case {
        // Warmup + calibration.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let case = Case {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            median: Duration::from_secs_f64(stats::median(&samples)),
            p95: Duration::from_secs_f64(stats::percentile(&samples, 95.0)),
            stddev: Duration::from_secs_f64(stats::stddev(&samples)),
            items,
        };
        self.cases.push(case);
        self.cases.last().unwrap()
    }

    /// Per-case throughput in items per second (`None` without an item
    /// count or a measurable mean).
    fn throughput(c: &Case) -> Option<f64> {
        match c.items {
            Some(n) if c.mean.as_secs_f64() > 0.0 => Some(n / c.mean.as_secs_f64()),
            _ => None,
        }
    }

    /// Machine-readable form of the group (see the module docs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("group", Json::str(&self.group)),
            (
                "cases",
                Json::arr(self.cases.iter().map(|c| {
                    Json::obj(vec![
                        ("name", Json::str(&c.name)),
                        ("iters", Json::num(c.iters as f64)),
                        ("mean_ns", Json::num(c.mean.as_nanos() as f64)),
                        ("median_ns", Json::num(c.median.as_nanos() as f64)),
                        ("p95_ns", Json::num(c.p95.as_nanos() as f64)),
                        ("stddev_ns", Json::num(c.stddev.as_nanos() as f64)),
                        (
                            "throughput_per_s",
                            match Self::throughput(c) {
                                Some(t) => Json::num(t),
                                None => Json::Null,
                            },
                        ),
                    ])
                })),
            ),
        ])
    }

    /// The `BENCH_<group>.json` path for this group under `dir`
    /// (path separators in the group name become underscores).
    fn json_path(&self, dir: &str) -> String {
        let stem: String = self
            .group
            .chars()
            .map(|ch| if ch == '/' || ch == ' ' { '_' } else { ch })
            .collect();
        format!("{}/BENCH_{stem}.json", dir.trim_end_matches('/'))
    }

    /// Print the group table; with `BENCH_JSON` set, also write the
    /// machine-readable `BENCH_<group>.json` (see the module docs).
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<38} {:>7} {:>12} {:>12} {:>12} {:>12} {:>14}",
            "case", "iters", "mean", "median", "p95", "stddev", "throughput"
        );
        for c in &self.cases {
            let thr = match Self::throughput(c) {
                Some(t) => format!("{t:.0}/s"),
                None => "-".into(),
            };
            println!(
                "{:<38} {:>7} {:>12} {:>12} {:>12} {:>12} {:>14}",
                c.name,
                c.iters,
                fmt_dur(c.mean),
                fmt_dur(c.median),
                fmt_dur(c.p95),
                fmt_dur(c.stddev),
                thr
            );
        }
        if let Ok(dir) = std::env::var("BENCH_JSON") {
            let dir = match dir.as_str() {
                "0" | "false" => return, // explicit opt-out
                "" | "1" | "true" => ".".to_string(),
                other => other.to_string(), // output directory
            };
            let path = self.json_path(&dir);
            match std::fs::write(&path, self.to_json().to_string()) {
                Ok(()) => eprintln!("benchkit: wrote {path}"),
                Err(e) => eprintln!("benchkit: could not write {path}: {e}"),
            }
        }
    }

    pub fn cases(&self) -> &[Case] {
        &self.cases
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new("t").with_budget(Duration::from_millis(5), Duration::from_millis(20));
        let case = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(case.iters >= 5);
        assert!(case.mean.as_nanos() > 0);
        assert!(case.p95 >= case.median);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::new("t").with_budget(Duration::from_millis(5), Duration::from_millis(20));
        let case = b.run_with_items("items", Some(100.0), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(case.items == Some(100.0));
        b.report(); // smoke the printer
    }

    #[test]
    fn json_dump_shape() {
        let mut b = Bench::new("scaling/tasks")
            .with_budget(Duration::from_millis(5), Duration::from_millis(20));
        b.run_with_items("spin", Some(10.0), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let j = b.to_json();
        assert_eq!(j.get("group").unwrap().as_str(), Some("scaling/tasks"));
        let cases = j.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("spin"));
        assert!(cases[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(cases[0].get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
        // Round-trips through the parser.
        assert!(Json::parse(&j.to_string()).is_ok());
        // Group separators are flattened into the file name.
        assert_eq!(b.json_path("out"), "out/BENCH_scaling_tasks.json");
        assert_eq!(b.json_path("./"), "./BENCH_scaling_tasks.json");
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(2)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(2)).ends_with("us"));
        assert!(fmt_dur(Duration::from_nanos(200)).ends_with("ns"));
    }
}
