//! Open-loop load generation and SLO reporting for the coordinator.
//!
//! This is the serving-side analogue of the paper's claim: the planner
//! keeps budgets under pressure, and this subsystem proves the
//! *coordinator* keeps its SLOs under traffic.  It drives a live server
//! through N concurrent pipelined [`crate::coordinator::Client`]s at a
//! configured **offered** rate, independent of how fast the server
//! answers — the open-loop regime where queues genuinely build and
//! admission control, priorities and binding deadlines earn their keep.
//!
//! The pieces (each its own module):
//!
//! * [`arrival`] — pluggable arrival processes (Poisson, bursty on/off,
//!   diurnal sinusoid, heavy-tail Pareto), all seeded and deterministic.
//! * [`mix`] — weighted request mixes over the named
//!   [`crate::workload::scenario`] presets with priority / deadline /
//!   policy distributions and budget factors relative to each
//!   scenario's feasibility floor.
//! * [`run`] — tape generation ([`run::generate`]) and the multi-client
//!   open-loop driver ([`run::execute`]); record-and-replay via
//!   [`crate::workload::LoadTrace`], so any run can be frozen as a
//!   schema-checked JSON tape and replayed bit-identically.
//! * [`report`] — the [`report::SloReport`]: throughput vs offered
//!   load, client-side latency percentiles, served / busy /
//!   deadline-exceeded breakdowns with a server-`stats` reconciliation
//!   delta, and the saturation-knee sweep ([`run::run_sweep`]).
//!
//! CLI: `botsched loadgen` (see `docs/OPERATIONS.md`, "Load testing and
//! SLO reports"); bench: the `scaling/loadgen` group.

pub mod arrival;
pub mod mix;
pub mod report;
pub mod run;

pub use arrival::ArrivalProcess;
pub use mix::{DeadlineMix, MixSpec, ScenarioFloors, Weighted};
pub use report::{Reservoir, ServerDelta, SloReport, SweepReport};
pub use run::{execute, generate, run_load, run_sweep, ExecOptions, LoadConfig};
