//! SLO reports: what an open-loop run measured.
//!
//! Client-side end-to-end latencies land in bounded [`Reservoir`]s (the
//! same fixed-ring percentile scheme as `coordinator/metrics.rs`, so
//! client- and server-side percentiles are methodologically comparable).
//! A [`SloReport`] carries the offered-vs-achieved throughput story, the
//! served / busy / deadline-exceeded / error breakdown, latency and
//! send-lag percentiles, and a reconciliation block of server counters
//! (`stats` deltas) captured around the run.  A [`SweepReport`] strings
//! several of those along an offered-load ramp and marks the saturation
//! knee — the last offered rate the server still kept up with.

use crate::util::Json;

/// Bounded latency reservoir (fixed ring, most recent `CAP` samples).
#[derive(Debug, Clone)]
pub struct Reservoir {
    samples: Vec<u64>,
    pos: usize,
    count: u64,
}

const CAP: usize = 4096;

impl Default for Reservoir {
    fn default() -> Self {
        Self::new()
    }
}

impl Reservoir {
    pub fn new() -> Reservoir {
        Reservoir { samples: Vec::with_capacity(CAP.min(1024)), pos: 0, count: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        if self.samples.len() < CAP {
            self.samples.push(v);
        } else {
            self.samples[self.pos] = v;
            self.pos = (self.pos + 1) % CAP;
        }
    }

    /// Total samples recorded (not just the retained window).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Percentile over the retained window (`p` in `[0, 1]`; floor
    /// index, matching the server's metrics).  0 when empty.
    pub fn pct(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() - 1) as f64 * p) as usize;
        sorted[idx] as f64
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }
}

/// Server `stats` counters captured around a run, for reconciling
/// client-observed outcomes against what the server says it shed.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServerDelta {
    /// Increase in `jobs_rejected` (busy sheds) across the run.
    pub jobs_rejected: u64,
    /// Increase in `jobs_deadline_exceeded` across the run.  Can exceed
    /// the client-observed count: the server also sheds queued work the
    /// sweeper catches after the synchronous caller was answered.
    pub jobs_deadline_exceeded: u64,
    /// Post-run queue-wait percentiles (µs) from the server reservoir.
    pub queue_wait_us_p50: f64,
    pub queue_wait_us_p95: f64,
}

impl ServerDelta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs_rejected", Json::num(self.jobs_rejected as f64)),
            ("jobs_deadline_exceeded", Json::num(self.jobs_deadline_exceeded as f64)),
            ("queue_wait_us_p50", Json::num(self.queue_wait_us_p50)),
            ("queue_wait_us_p95", Json::num(self.queue_wait_us_p95)),
        ])
    }
}

/// The SLO report for one open-loop run.
#[derive(Debug, Clone)]
pub struct SloReport {
    /// The configured arrival rate (requests/second).
    pub offered_rate: f64,
    /// Arrival-process grammar string driving the run.
    pub arrival: String,
    pub duration_s: f64,
    pub clients: usize,
    /// Requests actually put on the wire.
    pub sent: u64,
    pub served: u64,
    /// `busy` admission rejections.
    pub busy: u64,
    /// `deadline_exceeded` replies.
    pub deadline_exceeded: u64,
    /// Everything else: API errors, transport failures, replies still
    /// unanswered when the drain window closed.
    pub errors: u64,
    /// Wall-clock of the measured window (send of first request to last
    /// reply), seconds.
    pub wall_s: f64,
    /// `sent / wall_s` — what the generator actually offered.
    pub achieved_rate: f64,
    /// `served / wall_s` — useful work per second.
    pub goodput: f64,
    /// Client-observed end-to-end latency (µs), served requests only.
    pub latency_us_p50: f64,
    pub latency_us_p95: f64,
    pub latency_us_p99: f64,
    pub latency_us_mean: f64,
    /// How late sends left relative to their schedule (µs, p95) — large
    /// values mean the generator itself could not hold the offered rate.
    pub send_lag_us_p95: f64,
    pub server: Option<ServerDelta>,
}

impl SloReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("offered_rate", Json::num(self.offered_rate)),
            ("arrival", Json::str(&self.arrival)),
            ("duration_s", Json::num(self.duration_s)),
            ("clients", Json::num(self.clients as f64)),
            ("sent", Json::num(self.sent as f64)),
            ("served", Json::num(self.served as f64)),
            ("busy", Json::num(self.busy as f64)),
            ("deadline_exceeded", Json::num(self.deadline_exceeded as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("achieved_rate", Json::num(self.achieved_rate)),
            ("goodput", Json::num(self.goodput)),
            (
                "latency_us",
                Json::obj(vec![
                    ("p50", Json::num(self.latency_us_p50)),
                    ("p95", Json::num(self.latency_us_p95)),
                    ("p99", Json::num(self.latency_us_p99)),
                    ("mean", Json::num(self.latency_us_mean)),
                ]),
            ),
            ("send_lag_us_p95", Json::num(self.send_lag_us_p95)),
        ];
        if let Some(s) = &self.server {
            fields.push(("server", s.to_json()));
        }
        Json::obj(fields)
    }

    /// The human-readable block `botsched loadgen` prints.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "offered {:.1}/s ({})  achieved {:.1}/s  goodput {:.1}/s  wall {:.2}s  clients {}\n",
            self.offered_rate, self.arrival, self.achieved_rate, self.goodput, self.wall_s,
            self.clients
        ));
        out.push_str(&format!(
            "sent {}  served {}  busy {}  deadline_exceeded {}  errors {}\n",
            self.sent, self.served, self.busy, self.deadline_exceeded, self.errors
        ));
        out.push_str(&format!(
            "latency  p50 {:>9.0}us  p95 {:>9.0}us  p99 {:>9.0}us  mean {:>9.0}us\n",
            self.latency_us_p50, self.latency_us_p95, self.latency_us_p99, self.latency_us_mean
        ));
        out.push_str(&format!("send lag p95 {:.0}us\n", self.send_lag_us_p95));
        if let Some(s) = &self.server {
            out.push_str(&format!(
                "server   rejected +{}  deadline_exceeded +{}  queue_wait p50 {:.0}us p95 {:.0}us\n",
                s.jobs_rejected, s.jobs_deadline_exceeded, s.queue_wait_us_p50, s.queue_wait_us_p95
            ));
        }
        out
    }
}

/// A saturation sweep: one [`SloReport`] per offered-load step.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub points: Vec<SloReport>,
    /// The last offered rate the server kept up with (goodput within
    /// [`KNEE_KEEPUP`] of offered); `None` when even the first step
    /// saturated.
    pub knee_rate: Option<f64>,
}

/// Goodput/offered ratio above which a step counts as "keeping up".
pub const KNEE_KEEPUP: f64 = 0.9;

/// Relative goodput gain below which a sweep stops stepping (the curve
/// has flattened — extra offered load is not becoming useful work).
pub const KNEE_FLAT_GAIN: f64 = 0.1;

/// Locate the saturation knee on a ramp of completed steps.
pub fn find_knee(points: &[SloReport]) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.offered_rate > 0.0 && p.goodput >= KNEE_KEEPUP * p.offered_rate)
        .map(|p| p.offered_rate)
        .fold(None, |acc: Option<f64>, r| Some(acc.map_or(r, |a| a.max(r))))
}

impl SweepReport {
    pub fn new(points: Vec<SloReport>) -> SweepReport {
        let knee_rate = find_knee(&points);
        SweepReport { points, knee_rate }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("points", Json::arr(self.points.iter().map(SloReport::to_json))),
            ("knee_rate", self.knee_rate.map_or(Json::Null, Json::num)),
        ])
    }

    pub fn table(&self) -> String {
        let mut out = String::from(
            "offered/s  goodput/s  served    busy  ddl_exc  errors   p50_us   p95_us   p99_us\n",
        );
        for p in &self.points {
            out.push_str(&format!(
                "{:>9.1}  {:>9.1}  {:>6}  {:>6}  {:>7}  {:>6}  {:>7.0}  {:>7.0}  {:>7.0}\n",
                p.offered_rate,
                p.goodput,
                p.served,
                p.busy,
                p.deadline_exceeded,
                p.errors,
                p.latency_us_p50,
                p.latency_us_p95,
                p.latency_us_p99,
            ));
        }
        match self.knee_rate {
            Some(k) => out.push_str(&format!("saturation knee ≈ {k:.1}/s (last rate with goodput ≥ {:.0}% of offered)\n", KNEE_KEEPUP * 100.0)),
            None => out.push_str("saturation knee below the first step (server never kept up)\n"),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_percentiles_match_metrics_scheme() {
        let mut r = Reservoir::new();
        for v in 1..=100u64 {
            r.record(v * 10);
        }
        assert_eq!(r.count(), 100);
        assert_eq!(r.pct(0.50), 500.0);
        assert_eq!(r.pct(0.95), 940.0 + 10.0);
        assert_eq!(r.pct(1.0), 1000.0);
        assert!(r.mean() > 500.0 && r.mean() < 510.0);
        // The ring wraps without losing count.
        for v in 0..(CAP as u64 * 2) {
            r.record(v);
        }
        assert_eq!(r.count(), 100 + CAP as u64 * 2);
    }

    fn point(rate: f64, goodput: f64) -> SloReport {
        SloReport {
            offered_rate: rate,
            arrival: "poisson".into(),
            duration_s: 1.0,
            clients: 1,
            sent: rate as u64,
            served: goodput as u64,
            busy: 0,
            deadline_exceeded: 0,
            errors: 0,
            wall_s: 1.0,
            achieved_rate: rate,
            goodput,
            latency_us_p50: 100.0,
            latency_us_p95: 200.0,
            latency_us_p99: 300.0,
            latency_us_mean: 120.0,
            send_lag_us_p95: 10.0,
            server: None,
        }
    }

    #[test]
    fn knee_is_the_last_kept_up_rate() {
        let points = vec![point(50.0, 50.0), point(100.0, 97.0), point(200.0, 120.0)];
        assert_eq!(find_knee(&points), Some(100.0));
        assert_eq!(find_knee(&[point(50.0, 10.0)]), None);
        let sweep = SweepReport::new(points);
        assert_eq!(sweep.knee_rate, Some(100.0));
        let j = sweep.to_json();
        assert_eq!(j.get("knee_rate").unwrap().as_f64(), Some(100.0));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 3);
        assert!(sweep.table().contains("saturation knee"));
    }

    #[test]
    fn report_json_has_the_slo_fields() {
        let mut p = point(80.0, 75.0);
        p.server = Some(ServerDelta {
            jobs_rejected: 3,
            jobs_deadline_exceeded: 2,
            queue_wait_us_p50: 40.0,
            queue_wait_us_p95: 90.0,
        });
        let j = p.to_json();
        for key in
            ["offered_rate", "sent", "served", "busy", "deadline_exceeded", "errors", "goodput"]
        {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.path(&["latency_us", "p95"]).and_then(Json::as_f64), Some(200.0));
        assert_eq!(j.path(&["server", "jobs_rejected"]).and_then(Json::as_f64), Some(3.0));
        assert!(p.table().contains("deadline_exceeded"));
    }
}
