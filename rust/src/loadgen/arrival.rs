//! Pluggable arrival processes for the open-loop load generator.
//!
//! Each process turns an *offered* average rate (requests/second) and a
//! run duration into a sorted list of arrival instants, sampled
//! deterministically from a seeded [`Rng`].  The offered rate is a
//! long-run mean for every process — what differs is how arrivals clump:
//!
//! * `poisson` — memoryless exponential gaps (the M/G/1 textbook case).
//! * `bursty:on=<s>,off=<s>` — an on/off modulated Poisson process: the
//!   full offered volume is squeezed into the on-windows, so the
//!   instantaneous rate during a burst is `rate * (on+off)/on`.
//! * `diurnal:period=<s>,amp=<f>` — a sinusoidally modulated Poisson
//!   process (rate(t) = rate * (1 + amp·sin(2πt/period))) sampled by
//!   thinning; a day-curve compressed to bench scale.
//! * `pareto:alpha=<f>` — heavy-tailed Pareto inter-arrival gaps with
//!   shape `alpha` (> 1 so the mean exists; smaller = heavier tail),
//!   scaled so the mean gap is `1/rate`.
//!
//! The grammar strings above are what `botsched loadgen --arrival`
//! accepts; [`ArrivalProcess::spec_string`] round-trips through
//! [`ArrivalProcess::parse`] so a recorded tape can echo its process.

use anyhow::{anyhow, bail, Result};

use crate::util::Rng;

/// An arrival process (see the module docs for the grammar).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    Poisson,
    Bursty { on_s: f64, off_s: f64 },
    Diurnal { period_s: f64, amplitude: f64 },
    Pareto { alpha: f64 },
}

impl ArrivalProcess {
    /// Parse the `--arrival` grammar: a process name optionally followed
    /// by `:key=value,...` parameters.
    pub fn parse(spec: &str) -> Result<ArrivalProcess> {
        let (name, params) = match spec.split_once(':') {
            Some((n, p)) => (n.trim(), p),
            None => (spec.trim(), ""),
        };
        let mut kv: Vec<(&str, f64)> = Vec::new();
        for part in params.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("arrival {spec:?}: expected key=value, got {part:?}"))?;
            let v: f64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow!("arrival {spec:?}: {k:?} must be a number, got {v:?}"))?;
            kv.push((k.trim(), v));
        }
        let mut take = |key: &str, default: f64| -> f64 {
            match kv.iter().position(|(k, _)| *k == key) {
                Some(i) => kv.remove(i).1,
                None => default,
            }
        };
        let proc = match name {
            "poisson" => ArrivalProcess::Poisson,
            "bursty" => {
                let on_s = take("on", 2.0);
                let off_s = take("off", 8.0);
                if on_s <= 0.0 || off_s < 0.0 {
                    bail!("arrival {spec:?}: need on > 0 and off >= 0");
                }
                ArrivalProcess::Bursty { on_s, off_s }
            }
            "diurnal" => {
                let period_s = take("period", 60.0);
                let amplitude = take("amp", 0.8);
                if period_s <= 0.0 {
                    bail!("arrival {spec:?}: need period > 0");
                }
                if !(0.0..=1.0).contains(&amplitude) {
                    bail!("arrival {spec:?}: need amp in [0, 1], got {amplitude}");
                }
                ArrivalProcess::Diurnal { period_s, amplitude }
            }
            "pareto" => {
                let alpha = take("alpha", 1.5);
                if alpha <= 1.0 {
                    bail!("arrival {spec:?}: need alpha > 1 (finite mean), got {alpha}");
                }
                ArrivalProcess::Pareto { alpha }
            }
            other => bail!(
                "unknown arrival process {other:?} (known: poisson, bursty, diurnal, pareto)"
            ),
        };
        if let Some((k, _)) = kv.first() {
            bail!("arrival {spec:?}: unknown parameter {k:?}");
        }
        Ok(proc)
    }

    /// The canonical grammar string ([`ArrivalProcess::parse`] inverse).
    pub fn spec_string(&self) -> String {
        match self {
            ArrivalProcess::Poisson => "poisson".into(),
            ArrivalProcess::Bursty { on_s, off_s } => format!("bursty:on={on_s},off={off_s}"),
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                format!("diurnal:period={period_s},amp={amplitude}")
            }
            ArrivalProcess::Pareto { alpha } => format!("pareto:alpha={alpha}"),
        }
    }

    /// Sample arrival instants (seconds, sorted ascending) over
    /// `[0, duration_s)` at a long-run mean of `rate` arrivals/second.
    pub fn schedule(&self, rate: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        assert!(rate > 0.0 && duration_s > 0.0, "need rate > 0 and duration > 0");
        let mut out = Vec::with_capacity((rate * duration_s * 1.5) as usize + 8);
        match *self {
            ArrivalProcess::Poisson => {
                let mut t = rng.exponential(rate);
                while t < duration_s {
                    out.push(t);
                    t += rng.exponential(rate);
                }
            }
            ArrivalProcess::Bursty { on_s, off_s } => {
                // Homogeneous Poisson on "active" time at the boosted
                // in-burst rate, mapped onto wall time by skipping the
                // off-windows — the long-run mean stays `rate`.
                let cycle = on_s + off_s;
                let burst_rate = rate * cycle / on_s;
                let mut active = rng.exponential(burst_rate);
                loop {
                    let t = (active / on_s).floor() * cycle + active % on_s;
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                    active += rng.exponential(burst_rate);
                }
            }
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                // Thinning (Lewis–Shedler): candidates at the peak rate,
                // kept with probability rate(t)/peak.
                let peak = rate * (1.0 + amplitude);
                let mut t = rng.exponential(peak);
                while t < duration_s {
                    let local = rate
                        * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin());
                    if rng.f64() * peak < local {
                        out.push(t);
                    }
                    t += rng.exponential(peak);
                }
            }
            ArrivalProcess::Pareto { alpha } => {
                // Gaps X = xm·(1-U)^(-1/alpha); E[X] = xm·alpha/(alpha-1)
                // = 1/rate with the scale below.  U in [0,1) keeps the
                // power well-defined.
                let xm = (alpha - 1.0) / (alpha * rate);
                let mut t = 0.0;
                loop {
                    t += xm * (1.0 - rng.f64()).powf(-1.0 / alpha);
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all() -> Vec<ArrivalProcess> {
        vec![
            ArrivalProcess::Poisson,
            ArrivalProcess::Bursty { on_s: 1.0, off_s: 3.0 },
            ArrivalProcess::Diurnal { period_s: 40.0, amplitude: 0.8 },
            ArrivalProcess::Pareto { alpha: 1.5 },
        ]
    }

    #[test]
    fn grammar_roundtrips_and_rejects_garbage() {
        for p in all() {
            assert_eq!(ArrivalProcess::parse(&p.spec_string()).unwrap(), p, "{p:?}");
        }
        assert_eq!(ArrivalProcess::parse("poisson").unwrap(), ArrivalProcess::Poisson);
        assert_eq!(
            ArrivalProcess::parse("bursty:on=2,off=8").unwrap(),
            ArrivalProcess::Bursty { on_s: 2.0, off_s: 8.0 }
        );
        // Defaults fill unnamed parameters.
        assert!(matches!(ArrivalProcess::parse("pareto").unwrap(), ArrivalProcess::Pareto { .. }));

        for bad in [
            "uniform",
            "bursty:on=0",
            "bursty:frequency=2",
            "diurnal:amp=1.5",
            "pareto:alpha=1",
            "pareto:alpha=x",
            "poisson:rate",
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "{bad:?} should be rejected");
        }
        let err = ArrivalProcess::parse("bursty:frequency=2").unwrap_err().to_string();
        assert!(err.contains("frequency"), "{err}");
    }

    #[test]
    fn schedules_are_deterministic_under_a_fixed_seed() {
        for p in all() {
            let a = p.schedule(25.0, 20.0, &mut Rng::new(42));
            let b = p.schedule(25.0, 20.0, &mut Rng::new(42));
            assert_eq!(a, b, "{p:?}");
            let c = p.schedule(25.0, 20.0, &mut Rng::new(43));
            assert_ne!(a, c, "{p:?} should vary with the seed");
        }
    }

    #[test]
    fn schedules_are_sorted_and_in_range() {
        for p in all() {
            let times = p.schedule(30.0, 50.0, &mut Rng::new(7));
            assert!(!times.is_empty(), "{p:?}");
            for w in times.windows(2) {
                assert!(w[1] >= w[0], "{p:?} not sorted");
            }
            assert!(times.iter().all(|&t| (0.0..50.0).contains(&t)), "{p:?} out of range");
        }
    }

    #[test]
    fn empirical_rate_matches_offered_rate() {
        // Long-horizon sample means: Poisson/bursty/diurnal concentrate
        // tightly (relative SE well under 2% at ~20k arrivals); the
        // heavy-tail Pareto mean converges slowly, so its band is wide.
        let rate = 50.0;
        let dur = 400.0;
        for (p, lo, hi) in [
            (ArrivalProcess::Poisson, 0.9, 1.1),
            (ArrivalProcess::Bursty { on_s: 2.0, off_s: 6.0 }, 0.9, 1.1),
            (ArrivalProcess::Diurnal { period_s: 60.0, amplitude: 0.8 }, 0.9, 1.1),
            (ArrivalProcess::Pareto { alpha: 1.5 }, 0.6, 1.4),
        ] {
            let n = p.schedule(rate, dur, &mut Rng::new(1234)).len() as f64;
            let ratio = n / (rate * dur);
            assert!((lo..hi).contains(&ratio), "{p:?}: empirical/offered = {ratio:.3}");
        }
    }

    #[test]
    fn bursty_arrivals_stay_inside_on_windows() {
        let (on, off) = (1.5, 4.5);
        let p = ArrivalProcess::Bursty { on_s: on, off_s: off };
        let times = p.schedule(40.0, 60.0, &mut Rng::new(5));
        for &t in &times {
            let phase = t % (on + off);
            assert!(phase <= on + 1e-9, "arrival at {t:.3} (phase {phase:.3}) in an off-window");
        }
    }

    #[test]
    fn diurnal_peaks_beat_troughs() {
        // With amp 0.9 and one full period, the half-period around the
        // sine peak must see far more arrivals than the trough half.
        let period = 100.0;
        let p = ArrivalProcess::Diurnal { period_s: period, amplitude: 0.9 };
        let times = p.schedule(80.0, period, &mut Rng::new(9));
        let peak_half = times.iter().filter(|&&t| t < period / 2.0).count() as f64;
        let trough_half = times.len() as f64 - peak_half;
        assert!(
            peak_half > 1.5 * trough_half,
            "peak half {peak_half} vs trough half {trough_half}"
        );
    }

    #[test]
    fn pareto_gaps_are_heavy_tailed() {
        // The minimum gap is the scale xm, and the max/median ratio is
        // far larger than an exponential's would plausibly produce.
        let rate = 50.0;
        let alpha = 1.5;
        let p = ArrivalProcess::Pareto { alpha };
        let times = p.schedule(rate, 400.0, &mut Rng::new(77));
        let mut gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let xm = (alpha - 1.0) / (alpha * rate);
        assert!(gaps[0] >= xm * 0.999, "min gap {} below the Pareto scale {xm}", gaps[0]);
        let median = gaps[gaps.len() / 2];
        let max = *gaps.last().unwrap();
        assert!(max / median > 20.0, "tail too light: max/median = {}", max / median);
    }
}
