//! Request mixes: what the load generator actually sends.
//!
//! A [`MixSpec`] draws each request from weighted distributions over the
//! named [`crate::workload::scenario`] presets, scheduling policies,
//! queue priorities and binding deadlines.  Two request shapes are
//! produced (both existing wire ops — the generator adds no protocol
//! surface):
//!
//! * **`plan`** — the inline fast path: solved on the connection worker
//!   pool, exercising the solve cache and per-connection pipelining.
//! * **`campaign`** — the engine-bound path: queued on the sharded
//!   [`crate::coordinator::JobEngine`] with queue [`Placement`]
//!   (priority 0..=9 and an optional binding `deadline_ms`), which is
//!   what produces `busy` sheds and `deadline_exceeded` replies under
//!   saturation.  [`MixSpec::engine_frac`] sets the blend.
//!
//! Budgets are drawn relative to each scenario's feasibility floor
//! (`WorkloadGenerator::feasible_budget`), so "tight" and "relaxed"
//! budget regimes mean the same thing across scenarios of very
//! different sizes — the paper's framing of budget pressure.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

use crate::coordinator::api::{self, Placement};
use crate::scheduler::PolicyRegistry;
use crate::util::Rng;
use crate::workload::{build_scenario, scenario_names, WorkloadGenerator};

/// A weighted choice distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct Weighted<T> {
    items: Vec<(T, f64)>,
    total: f64,
}

impl<T> Weighted<T> {
    /// Build from `(item, weight)` pairs; weights must be positive.
    pub fn new(items: Vec<(T, f64)>) -> Result<Weighted<T>> {
        if items.is_empty() {
            bail!("weighted choice needs at least one item");
        }
        if !items.iter().all(|(_, w)| *w > 0.0 && w.is_finite()) {
            bail!("weighted choice weights must be > 0");
        }
        let total = items.iter().map(|(_, w)| w).sum();
        Ok(Weighted { items, total })
    }

    /// A single certain outcome.
    pub fn single(item: T) -> Weighted<T> {
        Weighted { items: vec![(item, 1.0)], total: 1.0 }
    }

    pub fn sample(&self, rng: &mut Rng) -> &T {
        let mut x = rng.f64() * self.total;
        for (item, w) in &self.items {
            if x < *w {
                return item;
            }
            x -= w;
        }
        &self.items.last().unwrap().0
    }

    pub fn items(&self) -> &[(T, f64)] {
        &self.items
    }
}

/// Parse `"a=2,b=1,c"` into `(name, weight)` pairs (bare names weigh 1).
pub fn parse_weighted(spec: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, w) = match part.split_once('=') {
            Some((n, w)) => {
                let w: f64 = w
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("mix {spec:?}: weight for {n:?} must be a number"))?;
                (n.trim(), w)
            }
            None => (part.trim(), 1.0),
        };
        out.push((name.to_string(), w));
    }
    if out.is_empty() {
        bail!("mix {spec:?}: names nothing");
    }
    Ok(out)
}

/// Optional binding-deadline distribution for engine-bound requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineMix {
    /// Probability an engine-bound request carries a deadline.
    pub prob: f64,
    /// Relative deadline drawn uniformly from `[lo_ms, hi_ms]`.
    pub lo_ms: u64,
    pub hi_ms: u64,
}

/// The full request-mix specification (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct MixSpec {
    pub scenarios: Weighted<String>,
    pub policies: Weighted<String>,
    /// Queue priority distribution (0..=9) for engine-bound requests.
    pub priorities: Weighted<u64>,
    pub deadline: Option<DeadlineMix>,
    /// Budgets are `scenario_floor * uniform(lo, hi)`.
    pub budget_factor: (f64, f64),
    /// Fraction of requests sent as engine-bound `campaign`s (the rest
    /// are inline `plan`s).
    pub engine_frac: f64,
}

impl MixSpec {
    /// The default blend: one scenario, the builtin heuristic policy,
    /// priority 0, no deadlines, comfortable budgets, 25% engine-bound.
    pub fn new(scenario: impl Into<String>) -> Result<MixSpec> {
        let spec = MixSpec {
            scenarios: Weighted::single(scenario.into()),
            policies: Weighted::single("budget-heuristic".into()),
            priorities: Weighted::single(0),
            deadline: None,
            budget_factor: (1.2, 2.0),
            engine_frac: 0.25,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Inline `plan` requests only — the cheap mix benches use.
    pub fn plan_only(scenario: impl Into<String>) -> Result<MixSpec> {
        let mut spec = MixSpec::new(scenario)?;
        spec.engine_frac = 0.0;
        Ok(spec)
    }

    /// Parse a `--scenario-mix` style weighted-name string.
    pub fn parse_scenarios(spec: &str) -> Result<Weighted<String>> {
        Weighted::new(parse_weighted(spec)?)
    }

    /// Fail fast on names the server would reject mid-run.
    pub fn validate(&self) -> Result<()> {
        for (name, _) in self.scenarios.items() {
            if build_scenario(name).is_none() {
                bail!("unknown scenario {name:?} (known: {})", scenario_names().join(", "));
            }
        }
        let registry = PolicyRegistry::builtin();
        for (name, _) in self.policies.items() {
            registry
                .resolve_arc(name)
                .map_err(|e| anyhow!("mix policy {name:?}: {e}"))?;
        }
        for (p, _) in self.priorities.items() {
            if *p > 9 {
                bail!("mix priority {p} out of range 0..=9");
            }
        }
        if let Some(d) = self.deadline {
            if !(0.0..=1.0).contains(&d.prob) || d.lo_ms > d.hi_ms || d.lo_ms == 0 {
                bail!(
                    "deadline mix needs prob in [0,1] and 0 < lo_ms <= hi_ms, got {:?}",
                    self.deadline
                );
            }
        }
        let (lo, hi) = self.budget_factor;
        if !(lo > 0.0 && hi >= lo) {
            bail!("budget factor needs 0 < lo <= hi, got ({lo}, {hi})");
        }
        if !(0.0..=1.0).contains(&self.engine_frac) {
            bail!("engine fraction must be in [0, 1], got {}", self.engine_frac);
        }
        Ok(())
    }

    /// Draw one request.  `floors` caches each scenario's feasibility
    /// floor so repeated draws stay cheap and deterministic.
    pub fn sample(&self, rng: &mut Rng, floors: &mut ScenarioFloors) -> Result<api::Request> {
        let scenario = self.scenarios.sample(rng).clone();
        let floor = floors.floor(&scenario)?;
        let budget = (floor * rng.uniform(self.budget_factor.0, self.budget_factor.1)).ceil();
        let policy = self.policies.sample(rng).clone();
        let seed = rng.below(1 << 32);
        let target = api::SystemRef::scenario(&scenario);
        if rng.f64() < self.engine_frac {
            let mut placement = Placement { priority: Some(*self.priorities.sample(rng)), deadline_ms: None };
            if let Some(d) = self.deadline {
                if rng.f64() < d.prob {
                    placement.deadline_ms = Some(d.lo_ms + rng.below(d.hi_ms - d.lo_ms + 1));
                }
            }
            let mut req = api::CampaignRequest::new(budget)
                .with_policy(policy)
                .with_seed(seed)
                .with_max_rounds(2)
                .with_target(target);
            req.placement = placement;
            Ok(api::Request::Campaign(req))
        } else {
            Ok(api::Request::Plan(
                api::PlanRequest::new(budget).with_policy(policy).with_seed(seed).with_target(target),
            ))
        }
    }
}

/// Per-scenario feasibility-floor cache (one planner-side solve of the
/// cheap bound per distinct scenario, reused across every draw).
#[derive(Debug, Default)]
pub struct ScenarioFloors {
    cache: HashMap<String, f64>,
}

impl ScenarioFloors {
    pub fn floor(&mut self, scenario: &str) -> Result<f64> {
        if let Some(f) = self.cache.get(scenario) {
            return Ok(*f);
        }
        let sys = build_scenario(scenario)
            .ok_or_else(|| anyhow!("unknown scenario {scenario:?}"))?;
        let f = WorkloadGenerator::feasible_budget(&sys, 1.0);
        self.cache.insert(scenario.to_string(), f);
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_parsing_and_sampling() {
        let pairs = parse_weighted("uniform-small=3,heavy-tail").unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[0], ("uniform-small".to_string(), 3.0));
        assert_eq!(pairs[1], ("heavy-tail".to_string(), 1.0));
        assert!(parse_weighted("a=x").is_err());
        assert!(parse_weighted("").is_err());
        assert!(Weighted::new(vec![("a".to_string(), 0.0)]).is_err());

        // Weights are roughly respected.
        let w = Weighted::new(vec![("a", 3.0), ("b", 1.0)]).unwrap();
        let mut rng = Rng::new(7);
        let hits = (0..4000).filter(|_| *w.sample(&mut rng) == "a").count();
        assert!((2700..3300).contains(&hits), "a drawn {hits}/4000 at weight 3:1");
    }

    #[test]
    fn mix_validation_rejects_unknown_names() {
        let mut m = MixSpec::new("uniform-small").unwrap();
        m.scenarios = Weighted::single("no-such-scenario".into());
        assert!(m.validate().is_err());

        let mut m = MixSpec::new("uniform-small").unwrap();
        m.policies = Weighted::single("no-such-policy".into());
        assert!(m.validate().is_err());

        let mut m = MixSpec::new("uniform-small").unwrap();
        m.deadline = Some(DeadlineMix { prob: 0.5, lo_ms: 0, hi_ms: 10 });
        assert!(m.validate().is_err(), "zero deadline must be rejected");
    }

    #[test]
    fn sampling_is_deterministic_and_blends_ops() {
        let mut m = MixSpec::new("uniform-small").unwrap();
        m.engine_frac = 0.5;
        m.deadline = Some(DeadlineMix { prob: 1.0, lo_ms: 5, hi_ms: 50 });
        let draw = |seed: u64| -> Vec<String> {
            let mut rng = Rng::new(seed);
            let mut floors = ScenarioFloors::default();
            (0..40)
                .map(|_| m.sample(&mut rng, &mut floors).unwrap().encode().to_string())
                .collect()
        };
        let a = draw(11);
        assert_eq!(a, draw(11), "same seed, same requests");
        assert_ne!(a, draw(12), "different seed, different requests");

        let campaigns = a.iter().filter(|s| s.contains("\"op\":\"campaign\"")).count();
        assert!(campaigns > 5 && campaigns < 35, "engine blend off: {campaigns}/40");
        // Engine-bound requests carry their placement deadline.
        assert!(
            a.iter().filter(|s| s.contains("\"op\":\"campaign\"")).all(|s| s.contains("deadline_ms")),
            "campaign requests should carry deadline_ms at prob 1.0"
        );
        // Every request decodes (they go straight onto the wire).
        for s in &a {
            api::Request::decode(&crate::util::Json::parse(s).unwrap()).unwrap();
        }
    }

    #[test]
    fn budgets_scale_with_the_scenario_floor() {
        let m = MixSpec::plan_only("uniform-small").unwrap();
        let mut rng = Rng::new(3);
        let mut floors = ScenarioFloors::default();
        let floor = floors.floor("uniform-small").unwrap();
        for _ in 0..20 {
            let req = m.sample(&mut rng, &mut floors).unwrap();
            let api::Request::Plan(p) = req else { panic!("plan_only produced a non-plan") };
            assert!(p.params.budget >= floor * 1.2 - 1.0 && p.params.budget <= (floor * 2.0).ceil());
        }
    }
}
