//! The open-loop driver: generate a traffic tape, then play it against
//! a live coordinator.
//!
//! **Open loop** means the send schedule is fixed up front by the
//! arrival process — a slow server does not slow the generator down, it
//! just accumulates in-flight requests (the regime where queues actually
//! build, unlike closed-loop churn that self-throttles).  Generation and
//! execution are deliberately split:
//!
//! 1. [`generate`] turns a [`LoadConfig`] into a [`LoadTrace`] — every
//!    request fully encoded, timestamped and assigned to a client
//!    connection, all from one seeded RNG.  Same config, same tape,
//!    byte-for-byte.
//! 2. [`execute`] plays any tape (fresh or loaded from disk) with one
//!    thread per client over a pipelined [`Client`], draining replies
//!    opportunistically between scheduled sends via
//!    [`Client::recv_within`], and aggregates everything into an
//!    [`SloReport`] with a server `stats` reconciliation delta.
//!
//! [`run_sweep`] steps the offered rate across a ramp to find the
//! saturation knee; it stops early once goodput flattens.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

use crate::coordinator::api::{self, ErrorCode};
use crate::coordinator::{Client, ClientError, ClientOptions};
use crate::util::Rng;
use crate::workload::{LoadEntry, LoadTrace};

use super::arrival::ArrivalProcess;
use super::mix::{MixSpec, ScenarioFloors};
use super::report::{find_knee, Reservoir, ServerDelta, SloReport, SweepReport, KNEE_FLAT_GAIN};

/// Everything that defines one generated run.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Offered arrival rate, requests/second.
    pub rate: f64,
    pub duration_s: f64,
    /// Concurrent client connections the arrivals round-robin across.
    pub clients: usize,
    pub arrival: ArrivalProcess,
    pub mix: MixSpec,
    pub seed: u64,
}

/// Execution knobs (separate from the tape, which they do not affect).
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub connect: ClientOptions,
    /// How long to wait for straggler replies after the last send.
    pub drain_timeout: Duration,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            connect: ClientOptions {
                connect_timeout: Some(Duration::from_secs(5)),
                ..ClientOptions::default()
            },
            drain_timeout: Duration::from_secs(60),
        }
    }
}

/// Generate the deterministic traffic tape for a config.
pub fn generate(cfg: &LoadConfig) -> Result<LoadTrace> {
    if cfg.clients == 0 || cfg.clients > 1024 {
        bail!("clients must be in 1..=1024, got {}", cfg.clients);
    }
    if !(cfg.rate > 0.0 && cfg.rate.is_finite() && cfg.duration_s > 0.0) {
        bail!("need rate > 0 and duration > 0, got rate {} duration {}", cfg.rate, cfg.duration_s);
    }
    cfg.mix.validate()?;
    let mut rng = Rng::new(cfg.seed);
    let mut floors = ScenarioFloors::default();
    let times = cfg.arrival.schedule(cfg.rate, cfg.duration_s, &mut rng);
    let mut entries = Vec::with_capacity(times.len());
    for (i, t) in times.iter().enumerate() {
        let request = cfg.mix.sample(&mut rng, &mut floors)?.encode();
        entries.push(LoadEntry {
            at_us: (t * 1e6) as u64,
            client: (i % cfg.clients) as u32,
            request,
        });
    }
    Ok(LoadTrace {
        seed: cfg.seed,
        offered_rate: cfg.rate,
        duration_s: cfg.duration_s,
        clients: cfg.clients as u32,
        arrival: cfg.arrival.spec_string(),
        entries,
    })
}

/// What one sent request came back as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Served,
    Busy,
    DeadlineExceeded,
    /// A structured API error other than deadline_exceeded.
    ApiErr,
    /// Transport failure (or the reply was lost to one).
    Transport,
    /// Still pending when the drain window closed.
    Unanswered,
}

#[derive(Debug, Clone, Copy)]
struct Sample {
    outcome: Outcome,
    /// Send-to-reply time, when a reply was observed.
    latency_us: Option<u64>,
    /// How late the send left relative to its schedule.
    send_lag_us: u64,
}

/// One client thread's share of the tape: send on schedule, drain
/// replies opportunistically while waiting, then drain the tail.
fn drive_client(
    addr: &SocketAddr,
    opts: &ExecOptions,
    start: Instant,
    entries: &[(u64, api::Request)],
) -> Result<(Vec<Sample>, Instant)> {
    let mut client = Client::connect_with(addr, &opts.connect)
        .with_context(|| format!("connecting load client to {addr}"))?;
    let mut samples: Vec<Sample> = Vec::with_capacity(entries.len());
    // FIFO of (sample index, send instant) awaiting replies, in order.
    let mut inflight: VecDeque<(usize, Instant)> = VecDeque::new();
    let mut last_event = start;

    fn settle(
        samples: &mut [Sample],
        inflight: &mut VecDeque<(usize, Instant)>,
        outcome: Outcome,
        now: Instant,
    ) {
        if let Some((idx, sent_at)) = inflight.pop_front() {
            samples[idx].outcome = outcome;
            samples[idx].latency_us = Some(now.duration_since(sent_at).as_micros() as u64);
        }
    }

    // Receive whatever is ready within `wait`; true while the
    // connection is usable.
    fn drain_one(
        client: &mut Client,
        samples: &mut [Sample],
        inflight: &mut VecDeque<(usize, Instant)>,
        wait: Duration,
        last_event: &mut Instant,
    ) -> bool {
        match client.recv_within(wait) {
            Ok(None) => true,
            Ok(Some(_)) => {
                *last_event = Instant::now();
                settle(samples, inflight, Outcome::Served, *last_event);
                true
            }
            Err(ClientError::Busy(_)) => {
                *last_event = Instant::now();
                settle(samples, inflight, Outcome::Busy, *last_event);
                true
            }
            Err(ClientError::Api(e)) => {
                *last_event = Instant::now();
                let outcome = if e.code == ErrorCode::DeadlineExceeded {
                    Outcome::DeadlineExceeded
                } else {
                    Outcome::ApiErr
                };
                settle(samples, inflight, outcome, *last_event);
                true
            }
            Err(_) => {
                // Transport: every in-flight reply is unattributable.
                *last_event = Instant::now();
                while let Some((idx, _)) = inflight.pop_front() {
                    samples[idx].outcome = Outcome::Transport;
                }
                false
            }
        }
    }

    for (at_us, req) in entries {
        let target = start + Duration::from_micros(*at_us);
        // Hold the schedule, draining replies while there is slack.
        loop {
            let now = Instant::now();
            if now >= target {
                break;
            }
            let slack = target - now;
            if inflight.is_empty() {
                std::thread::sleep(slack.min(Duration::from_millis(2)));
            } else if !drain_one(
                &mut client,
                &mut samples,
                &mut inflight,
                slack.min(Duration::from_millis(5)),
                &mut last_event,
            ) {
                client.reconnect().context("reconnecting load client")?;
            }
        }
        let now = Instant::now();
        let send_lag_us = now.duration_since(target).as_micros() as u64;
        let idx = samples.len();
        samples.push(Sample { outcome: Outcome::Unanswered, latency_us: None, send_lag_us });
        match client.send(req) {
            Ok(()) => {
                inflight.push_back((idx, now));
                last_event = now;
            }
            Err(_) => {
                samples[idx].outcome = Outcome::Transport;
                while let Some((i, _)) = inflight.pop_front() {
                    samples[i].outcome = Outcome::Transport;
                }
                client.reconnect().context("reconnecting load client")?;
            }
        }
    }

    // Tail drain: wait out stragglers up to the drain timeout.
    let drain_deadline = Instant::now() + opts.drain_timeout;
    while !inflight.is_empty() && Instant::now() < drain_deadline {
        if !drain_one(
            &mut client,
            &mut samples,
            &mut inflight,
            Duration::from_millis(50),
            &mut last_event,
        ) {
            break; // transport loss already settled the in-flight tail
        }
    }
    // Anything left is Unanswered (its initial state).
    Ok((samples, last_event))
}

/// Play a tape against a live coordinator and report.
pub fn execute(addr: &SocketAddr, trace: &LoadTrace, opts: &ExecOptions) -> Result<SloReport> {
    // Decode every request up front: a tape that fails schema checks
    // must fail before any traffic is sent.
    let mut per_client: Vec<Vec<(u64, api::Request)>> = vec![Vec::new(); trace.clients as usize];
    for (i, e) in trace.entries.iter().enumerate() {
        let req = api::Request::decode(&e.request)
            .map_err(|err| anyhow!("load trace request {i}: {}", err.message))?;
        let slot = per_client
            .get_mut(e.client as usize)
            .ok_or_else(|| anyhow!("load trace request {i}: client {} out of range", e.client))?;
        slot.push((e.at_us, req));
    }

    // Server counters around the run, for the reconciliation block.
    let mut control = Client::connect_with(addr, &opts.connect)
        .with_context(|| format!("connecting control client to {addr}"))?;
    let before = control.stats().map_err(|e| anyhow!("stats before run: {e}"))?;

    let start = Instant::now() + Duration::from_millis(20);
    let results: Vec<Result<(Vec<Sample>, Instant)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = per_client
            .iter()
            .map(|entries| scope.spawn(move || drive_client(addr, opts, start, entries)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("load client thread panicked")).collect()
    });

    let after = control.stats().map_err(|e| anyhow!("stats after run: {e}"))?;
    let server = ServerDelta {
        jobs_rejected: after.jobs_rejected().saturating_sub(before.jobs_rejected()),
        jobs_deadline_exceeded: after
            .jobs_deadline_exceeded()
            .saturating_sub(before.jobs_deadline_exceeded()),
        queue_wait_us_p50: after.queue_wait_us("p50"),
        queue_wait_us_p95: after.queue_wait_us("p95"),
    };

    let mut samples = Vec::new();
    let mut last_event = start;
    for r in results {
        let (s, t) = r?;
        samples.extend(s);
        if t > last_event {
            last_event = t;
        }
    }

    let mut latency = Reservoir::new();
    let mut send_lag = Reservoir::new();
    let (mut served, mut busy, mut ddl, mut errors) = (0u64, 0u64, 0u64, 0u64);
    for s in &samples {
        send_lag.record(s.send_lag_us);
        match s.outcome {
            Outcome::Served => {
                served += 1;
                if let Some(l) = s.latency_us {
                    latency.record(l);
                }
            }
            Outcome::Busy => busy += 1,
            Outcome::DeadlineExceeded => ddl += 1,
            Outcome::ApiErr | Outcome::Transport | Outcome::Unanswered => errors += 1,
        }
    }
    let wall_s = last_event.duration_since(start).as_secs_f64().max(1e-6);
    let sent = samples.len() as u64;
    Ok(SloReport {
        offered_rate: trace.offered_rate,
        arrival: trace.arrival.clone(),
        duration_s: trace.duration_s,
        clients: trace.clients as usize,
        sent,
        served,
        busy,
        deadline_exceeded: ddl,
        errors,
        wall_s,
        achieved_rate: sent as f64 / wall_s,
        goodput: served as f64 / wall_s,
        latency_us_p50: latency.pct(0.50),
        latency_us_p95: latency.pct(0.95),
        latency_us_p99: latency.pct(0.99),
        latency_us_mean: latency.mean(),
        send_lag_us_p95: send_lag.pct(0.95),
        server: Some(server),
    })
}

/// Generate and execute in one step, returning the tape alongside the
/// report (so callers can `--record` it).
pub fn run_load(
    addr: &SocketAddr,
    cfg: &LoadConfig,
    opts: &ExecOptions,
) -> Result<(LoadTrace, SloReport)> {
    let trace = generate(cfg)?;
    let report = execute(addr, &trace, opts)?;
    Ok((trace, report))
}

/// Step the offered rate across `rates`, stopping early once goodput
/// flattens (relative gain below [`KNEE_FLAT_GAIN`]) — the knee is
/// behind us at that point and further steps only burn time.
pub fn run_sweep(
    addr: &SocketAddr,
    base: &LoadConfig,
    rates: &[f64],
    opts: &ExecOptions,
) -> Result<SweepReport> {
    if rates.is_empty() {
        bail!("sweep needs at least one offered rate");
    }
    let mut points: Vec<SloReport> = Vec::with_capacity(rates.len());
    for &rate in rates {
        let cfg = LoadConfig { rate, ..base.clone() };
        let (_, report) = run_load(addr, &cfg, opts)?;
        let flattened = points.last().is_some_and(|prev: &SloReport| {
            report.goodput < prev.goodput * (1.0 + KNEE_FLAT_GAIN)
        });
        points.push(report);
        if flattened {
            break;
        }
    }
    let knee_rate = find_knee(&points);
    Ok(SweepReport { points, knee_rate })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LoadConfig {
        LoadConfig {
            rate: 40.0,
            duration_s: 0.5,
            clients: 3,
            arrival: ArrivalProcess::Poisson,
            mix: MixSpec::plan_only("uniform-small").unwrap(),
            seed: 99,
        }
    }

    #[test]
    fn generation_is_deterministic_and_round_robin() {
        let a = generate(&tiny_cfg()).unwrap();
        let b = generate(&tiny_cfg()).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(!a.entries.is_empty());
        for (i, e) in a.entries.iter().enumerate() {
            assert_eq!(e.client, (i % 3) as u32, "round-robin client assignment");
        }
        // And the tape passes its own strict schema check.
        let back = LoadTrace::from_json(&a.to_json()).unwrap();
        assert_eq!(back, a);

        let mut other = tiny_cfg();
        other.seed = 100;
        assert_ne!(generate(&other).unwrap(), a, "seed must matter");
    }

    #[test]
    fn generation_rejects_bad_configs() {
        let mut cfg = tiny_cfg();
        cfg.clients = 0;
        assert!(generate(&cfg).is_err());
        let mut cfg = tiny_cfg();
        cfg.rate = 0.0;
        assert!(generate(&cfg).is_err());
        let mut cfg = tiny_cfg();
        cfg.mix.engine_frac = 1.5;
        assert!(generate(&cfg).is_err());
    }
}
