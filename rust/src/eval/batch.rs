use crate::model::{BillingPolicy, Plan, System, Vm};

/// One candidate plan, aggregated losslessly for scoring.
///
/// Because eq. 5 is linear in task size, a VM's execution time depends on
/// its assignment only through the per-application total size
/// `agg[m] = sum of size_t over tasks of app m on this VM`.  A candidate
/// therefore stores, per VM slot: the performance row of its instance type
/// (`perf[m]`, seconds per unit size), its hourly rate, and `agg[m]`.
#[derive(Debug, Clone, Default)]
pub struct Candidate {
    /// Per VM: aggregated sizes per application, `[v][m]`.
    pub sizes: Vec<Vec<f64>>,
    /// Per VM: performance row of the VM's instance type, `[v][m]`.
    pub perf: Vec<Vec<f64>>,
    /// Per VM: hourly rate.
    pub rate: Vec<f64>,
    /// Per VM: whether the slot counts as provisioned even when empty
    /// (false only for slots that should score as absent).
    pub active: Vec<bool>,
}

impl Candidate {
    /// Aggregate a concrete plan.
    pub fn from_plan(sys: &System, plan: &Plan) -> Self {
        let mut c = Candidate::default();
        for vm in &plan.vms {
            c.push_vm(sys, vm);
        }
        c
    }

    /// Append one VM slot from a live VM.
    pub fn push_vm(&mut self, sys: &System, vm: &Vm) {
        self.sizes.push(vm.agg_sizes().to_vec());
        self.perf.push(sys.perf.row(vm.it).to_vec());
        self.rate.push(sys.rate(vm.it));
        // A task-less VM with zero overhead executes for 0s and bills
        // nothing (see Vm::exec); mirror that by deactivating the slot.
        self.active.push(!(vm.is_empty() && sys.overhead == 0.0));
    }

    pub fn n_vms(&self) -> usize {
        self.sizes.len()
    }
}

/// A batch of candidates plus the environment constants they are scored
/// under.  This is the exact information content of one XLA artifact call
/// (`overhead`, `hour`, `sizes[k,v,m]`, `perf[k,v,m]`, `rate[k,v]`,
/// `active[k,v]`), still in exact f64 and ragged form.
#[derive(Debug, Clone)]
pub struct EvalBatch {
    pub candidates: Vec<Candidate>,
    pub overhead: f64,
    pub hour: f64,
    pub billing: BillingPolicy,
    pub n_apps: usize,
}

impl EvalBatch {
    pub fn new(sys: &System) -> Self {
        Self {
            candidates: Vec::new(),
            overhead: sys.overhead,
            hour: sys.hour,
            billing: sys.billing,
            n_apps: sys.n_apps(),
        }
    }

    pub fn from_plans(sys: &System, plans: &[&Plan]) -> Self {
        let mut b = Self::new(sys);
        b.candidates = plans.iter().map(|p| Candidate::from_plan(sys, p)).collect();
        b
    }

    pub fn push(&mut self, candidate: Candidate) {
        self.candidates.push(candidate);
    }

    pub fn len(&self) -> usize {
        self.candidates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.candidates.is_empty()
    }

    /// Largest VM count across candidates (the padded V of a tensor call).
    pub fn max_vms(&self) -> usize {
        self.candidates.iter().map(Candidate::n_vms).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    fn sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0])
            .app("a2", vec![3.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .overhead(30.0)
            .build()
            .unwrap()
    }

    #[test]
    fn aggregation_matches_vm_caches() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(2));
        let c = Candidate::from_plan(&s, &p);
        assert_eq!(c.n_vms(), 1);
        assert_eq!(c.sizes[0], vec![1.0, 3.0]);
        assert_eq!(c.perf[0], vec![20.0, 24.0]);
        assert_eq!(c.rate[0], 5.0);
        assert!(c.active[0]);
    }

    #[test]
    fn empty_vm_active_only_with_overhead() {
        let s = sys(); // overhead 30
        let mut p = Plan::new();
        p.add_vm(&s, InstanceTypeId(0));
        let c = Candidate::from_plan(&s, &p);
        assert!(c.active[0]);

        let s0 = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut p0 = Plan::new();
        p0.add_vm(&s0, InstanceTypeId(0));
        let c0 = Candidate::from_plan(&s0, &p0);
        assert!(!c0.active[0]);
    }

    #[test]
    fn batch_shape() {
        let s = sys();
        let mut p1 = Plan::new();
        p1.add_vm(&s, InstanceTypeId(0));
        let mut p2 = Plan::new();
        p2.add_vm(&s, InstanceTypeId(0));
        p2.add_vm(&s, InstanceTypeId(1));
        let b = EvalBatch::from_plans(&s, &[&p1, &p2]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.max_vms(), 2);
        assert_eq!(b.n_apps, 2);
        assert_eq!(b.overhead, 30.0);
    }
}
