use crate::model::{BillingPolicy, Plan, System, Vm};

/// Aggregated per-application sizes of one VM row: either a borrowed
/// view of a live VM's incrementally maintained cache
/// ([`Vm::agg_sizes`]), or an owned vector synthesised for a VM that
/// exists only hypothetically (e.g. a REPLACE candidate's new VMs).
#[derive(Debug, Clone)]
pub enum AggSizes<'a> {
    Borrowed(&'a [f64]),
    Owned(Vec<f64>),
}

impl AggSizes<'_> {
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        match self {
            AggSizes::Borrowed(s) => s,
            AggSizes::Owned(v) => v,
        }
    }
}

/// One VM row of a partial (delta) candidate.  Perf rows are always
/// borrowed from the [`System`]'s matrix; only the size aggregation may
/// be owned.  Every row counts as provisioned — absent slots are simply
/// not represented (the delta form models a plan with
/// [`Plan::drop_empty_vms`] already applied).
#[derive(Debug, Clone)]
pub struct DeltaRow<'a> {
    pub sizes: AggSizes<'a>,
    /// Performance row of the row's instance type, seconds per unit size.
    pub perf: &'a [f64],
    /// Hourly rate of the row's instance type.
    pub rate: f64,
}

/// One candidate plan expressed as deltas against live state: rows that
/// survive a hypothetical edit borrow their aggregation straight from
/// the base plan's VMs, and only genuinely new rows are synthesised.
/// Scoring-equivalent to a [`Candidate`] built from the materialised
/// plan, without cloning it.
#[derive(Debug, Clone, Default)]
pub struct DeltaCandidate<'a> {
    pub rows: Vec<DeltaRow<'a>>,
}

impl<'a> DeltaCandidate<'a> {
    /// Append a row borrowing a live VM's cached aggregation.  The VM
    /// must be non-empty (empty VMs would have been removed by
    /// `drop_empty_vms` in the materialised plan this models).
    pub fn push_vm(&mut self, sys: &'a System, vm: &'a Vm) {
        debug_assert!(!vm.is_empty(), "delta rows model post-drop_empty_vms plans");
        self.rows.push(DeltaRow {
            sizes: AggSizes::Borrowed(vm.agg_sizes()),
            perf: sys.perf.row(vm.it),
            rate: sys.rate(vm.it),
        });
    }

    /// Append a synthesised row (owned aggregation, borrowed perf row).
    pub fn push_synth(&mut self, sizes: Vec<f64>, perf: &'a [f64], rate: f64) {
        self.rows.push(DeltaRow { sizes: AggSizes::Owned(sizes), perf, rate });
    }

    /// Append a row borrowing an arbitrary aggregation slice — the
    /// arena path, where rows are contiguous stripes of
    /// [`crate::eval::PlanArena`]'s slot-major storage rather than
    /// per-`Vm` caches.  Unlike [`push_vm`](Self::push_vm) this accepts
    /// all-zero rows: a provisioned-but-idle VM still bills its boot
    /// overhead when `o > 0`, so callers include such rows and skip them
    /// only when `o == 0` (where they score as absent anyway).
    pub fn push_row(&mut self, sizes: &'a [f64], perf: &'a [f64], rate: f64) {
        self.rows.push(DeltaRow { sizes: AggSizes::Borrowed(sizes), perf, rate });
    }

    pub fn n_vms(&self) -> usize {
        self.rows.len()
    }

    /// Materialise into the owned [`Candidate`] form (for evaluators
    /// that need contiguous tensors, e.g. the XLA artifact).
    pub fn to_candidate(&self) -> Candidate {
        let mut c = Candidate::default();
        for row in &self.rows {
            c.sizes.push(row.sizes.as_slice().to_vec());
            c.perf.push(row.perf.to_vec());
            c.rate.push(row.rate);
            c.active.push(true);
        }
        c
    }
}

/// A batch of partial candidates plus the scoring constants — the
/// zero-clone sibling of [`EvalBatch`], borrowed from a base plan and a
/// system for the duration of one evaluator call.
#[derive(Debug, Clone)]
pub struct DeltaBatch<'a> {
    pub candidates: Vec<DeltaCandidate<'a>>,
    pub overhead: f64,
    pub hour: f64,
    pub billing: BillingPolicy,
    pub n_apps: usize,
}

impl<'a> DeltaBatch<'a> {
    pub fn new(sys: &System) -> Self {
        Self {
            candidates: Vec::new(),
            overhead: sys.overhead,
            hour: sys.hour,
            billing: sys.billing,
            n_apps: sys.n_apps(),
        }
    }

    /// Wrap one live plan as a single-candidate delta batch — the
    /// zero-clone way to score a whole plan (`eval_deltas(&batch)[0]`),
    /// used by FIND's accept test and multistart's re-scoring.  Rows
    /// borrow each VM's cached aggregation; VMs that would score as
    /// absent (empty with zero overhead, see [`Candidate::push_vm`]'s
    /// `active` flag) are skipped outright, which is score-identical:
    /// an inactive row contributes nothing to either fold.
    pub fn from_plan(sys: &'a System, plan: &'a Plan) -> Self {
        let mut cand = DeltaCandidate::default();
        for vm in &plan.vms {
            if vm.is_empty() && sys.overhead == 0.0 {
                continue;
            }
            cand.push_row(vm.agg_sizes(), sys.perf.row(vm.it), sys.rate(vm.it));
        }
        let mut batch = Self::new(sys);
        batch.push(cand);
        batch
    }

    pub fn push(&mut self, candidate: DeltaCandidate<'a>) {
        self.candidates.push(candidate);
    }

    pub fn len(&self) -> usize {
        self.candidates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.candidates.is_empty()
    }

    /// Materialise the whole batch into the owned [`EvalBatch`] form.
    /// This is the default bridge for evaluators without a native delta
    /// path; [`crate::eval::NativeEvaluator`] scores the borrowed rows
    /// directly and never calls it.
    pub fn to_eval_batch(&self) -> EvalBatch {
        EvalBatch {
            candidates: self.candidates.iter().map(DeltaCandidate::to_candidate).collect(),
            overhead: self.overhead,
            hour: self.hour,
            billing: self.billing,
            n_apps: self.n_apps,
        }
    }
}

/// One candidate plan, aggregated losslessly for scoring.
///
/// Because eq. 5 is linear in task size, a VM's execution time depends on
/// its assignment only through the per-application total size
/// `agg[m] = sum of size_t over tasks of app m on this VM`.  A candidate
/// therefore stores, per VM slot: the performance row of its instance type
/// (`perf[m]`, seconds per unit size), its hourly rate, and `agg[m]`.
#[derive(Debug, Clone, Default)]
pub struct Candidate {
    /// Per VM: aggregated sizes per application, `[v][m]`.
    pub sizes: Vec<Vec<f64>>,
    /// Per VM: performance row of the VM's instance type, `[v][m]`.
    pub perf: Vec<Vec<f64>>,
    /// Per VM: hourly rate.
    pub rate: Vec<f64>,
    /// Per VM: whether the slot counts as provisioned even when empty
    /// (false only for slots that should score as absent).
    pub active: Vec<bool>,
}

impl Candidate {
    /// Aggregate a concrete plan.
    pub fn from_plan(sys: &System, plan: &Plan) -> Self {
        let mut c = Candidate::default();
        for vm in &plan.vms {
            c.push_vm(sys, vm);
        }
        c
    }

    /// Append one VM slot from a live VM.
    pub fn push_vm(&mut self, sys: &System, vm: &Vm) {
        self.sizes.push(vm.agg_sizes().to_vec());
        self.perf.push(sys.perf.row(vm.it).to_vec());
        self.rate.push(sys.rate(vm.it));
        // A task-less VM with zero overhead executes for 0s and bills
        // nothing (see Vm::exec); mirror that by deactivating the slot.
        self.active.push(!(vm.is_empty() && sys.overhead == 0.0));
    }

    pub fn n_vms(&self) -> usize {
        self.sizes.len()
    }
}

/// A batch of candidates plus the environment constants they are scored
/// under.  This is the exact information content of one XLA artifact call
/// (`overhead`, `hour`, `sizes[k,v,m]`, `perf[k,v,m]`, `rate[k,v]`,
/// `active[k,v]`), still in exact f64 and ragged form.
#[derive(Debug, Clone)]
pub struct EvalBatch {
    pub candidates: Vec<Candidate>,
    pub overhead: f64,
    pub hour: f64,
    pub billing: BillingPolicy,
    pub n_apps: usize,
}

impl EvalBatch {
    pub fn new(sys: &System) -> Self {
        Self {
            candidates: Vec::new(),
            overhead: sys.overhead,
            hour: sys.hour,
            billing: sys.billing,
            n_apps: sys.n_apps(),
        }
    }

    pub fn from_plans(sys: &System, plans: &[&Plan]) -> Self {
        let mut b = Self::new(sys);
        b.candidates = plans.iter().map(|p| Candidate::from_plan(sys, p)).collect();
        b
    }

    pub fn push(&mut self, candidate: Candidate) {
        self.candidates.push(candidate);
    }

    pub fn len(&self) -> usize {
        self.candidates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.candidates.is_empty()
    }

    /// Largest VM count across candidates (the padded V of a tensor call).
    pub fn max_vms(&self) -> usize {
        self.candidates.iter().map(Candidate::n_vms).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    fn sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0])
            .app("a2", vec![3.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .overhead(30.0)
            .build()
            .unwrap()
    }

    #[test]
    fn aggregation_matches_vm_caches() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(2));
        let c = Candidate::from_plan(&s, &p);
        assert_eq!(c.n_vms(), 1);
        assert_eq!(c.sizes[0], vec![1.0, 3.0]);
        assert_eq!(c.perf[0], vec![20.0, 24.0]);
        assert_eq!(c.rate[0], 5.0);
        assert!(c.active[0]);
    }

    #[test]
    fn empty_vm_active_only_with_overhead() {
        let s = sys(); // overhead 30
        let mut p = Plan::new();
        p.add_vm(&s, InstanceTypeId(0));
        let c = Candidate::from_plan(&s, &p);
        assert!(c.active[0]);

        let s0 = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut p0 = Plan::new();
        p0.add_vm(&s0, InstanceTypeId(0));
        let c0 = Candidate::from_plan(&s0, &p0);
        assert!(!c0.active[0]);
    }

    #[test]
    fn delta_candidate_matches_owned_candidate() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(2));
        let owned = Candidate::from_plan(&s, &p);

        let mut delta = DeltaCandidate::default();
        delta.push_vm(&s, &p.vms[v0]);
        let materialised = delta.to_candidate();
        assert_eq!(materialised.sizes, owned.sizes);
        assert_eq!(materialised.perf, owned.perf);
        assert_eq!(materialised.rate, owned.rate);
        assert_eq!(materialised.active, vec![true]);
    }

    #[test]
    fn delta_batch_materialises_synth_rows() {
        let s = sys();
        let mut b = DeltaBatch::new(&s);
        let mut c = DeltaCandidate::default();
        c.push_synth(vec![2.0, 0.0], s.perf.row(InstanceTypeId(1)), s.rate(InstanceTypeId(1)));
        assert_eq!(c.n_vms(), 1);
        b.push(c);
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
        let eb = b.to_eval_batch();
        assert_eq!(eb.len(), 1);
        assert_eq!(eb.candidates[0].sizes[0], vec![2.0, 0.0]);
        assert_eq!(eb.candidates[0].rate[0], 10.0);
        assert_eq!(eb.overhead, 30.0);
    }

    #[test]
    fn delta_from_plan_matches_eval_plan_bits() {
        use crate::eval::{NativeEvaluator, PlanEvaluator};
        let s = sys(); // overhead 30: empty VMs stay active
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(0)); // empty, bills its boot hour
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v1].push_task(&s, TaskId(2));
        let direct = NativeEvaluator.eval_plan(&s, &p);
        let delta = NativeEvaluator.eval_deltas(&DeltaBatch::from_plan(&s, &p))[0];
        assert_eq!(direct.makespan.to_bits(), delta.makespan.to_bits());
        assert_eq!(direct.cost.to_bits(), delta.cost.to_bits());

        // Zero overhead: the empty VM is skipped and scores as absent.
        let s0 = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut p0 = Plan::new();
        let w = p0.add_vm(&s0, InstanceTypeId(0));
        p0.add_vm(&s0, InstanceTypeId(0));
        p0.vms[w].push_task(&s0, TaskId(0));
        let b0 = DeltaBatch::from_plan(&s0, &p0);
        assert_eq!(b0.candidates[0].n_vms(), 1);
        let d0 = NativeEvaluator.eval_deltas(&b0)[0];
        let e0 = NativeEvaluator.eval_plan(&s0, &p0);
        assert_eq!(d0.makespan.to_bits(), e0.makespan.to_bits());
        assert_eq!(d0.cost.to_bits(), e0.cost.to_bits());
    }

    #[test]
    fn batch_shape() {
        let s = sys();
        let mut p1 = Plan::new();
        p1.add_vm(&s, InstanceTypeId(0));
        let mut p2 = Plan::new();
        p2.add_vm(&s, InstanceTypeId(0));
        p2.add_vm(&s, InstanceTypeId(1));
        let b = EvalBatch::from_plans(&s, &[&p1, &p2]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.max_vms(), 2);
        assert_eq!(b.n_apps, 2);
        assert_eq!(b.overhead, 30.0);
    }
}
