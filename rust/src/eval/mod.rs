//! Plan evaluation: the scoring abstraction shared by the planner, the
//! coordinator and the benchmarks.
//!
//! The paper's heuristic repeatedly scores candidate execution plans
//! (makespan + billed cost).  This module defines:
//!
//! * [`PlanEvaluator`] — the trait the planner scores through;
//! * [`NativeEvaluator`] — exact pure-rust scoring (reference + fallback);
//! * [`EvalBatch`] / [`Candidate`] — the lossless per-(vm, app) size
//!   aggregation of a batch of candidate plans, i.e. exactly the tensor
//!   layout the AOT-compiled XLA artifact consumes (see
//!   `python/compile/model.py`);
//! * [`DeltaBatch`] / [`DeltaCandidate`] — the borrowing (zero-clone)
//!   sibling of the above: partial candidates whose surviving rows
//!   reference live plan state, scored via
//!   [`PlanEvaluator::eval_deltas`] (the REPLACE hot path).
//!
//! The PJRT-backed implementation lives in [`crate::runtime`]; it is
//! differentially tested against [`NativeEvaluator`].

mod batch;
mod native;

pub use batch::{AggSizes, Candidate, DeltaBatch, DeltaCandidate, DeltaRow, EvalBatch};
pub use native::NativeEvaluator;

use crate::model::{Plan, PlanScore, System};

/// Batch scoring of candidate execution plans.
///
/// Implementations must return one [`PlanScore`] per candidate, in order.
/// Scores follow the paper's model exactly: eq. 5 (boot overhead + task
/// work), eq. 6 (hourly-ceiling billing), eq. 7 (makespan), eq. 8 (total
/// cost).
pub trait PlanEvaluator: Send + Sync {
    /// Score a prepared batch.
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore>;

    /// Score a batch of partial (delta) candidates whose rows borrow
    /// live plan state instead of owning it — the zero-clone hot path
    /// REPLACE scores candidate swaps through.  The default bridges to
    /// [`eval_batch`](Self::eval_batch) by materialising the batch
    /// (evaluators that pad tensors, e.g. the XLA artifact, copy the
    /// rows anyway); [`NativeEvaluator`] overrides it to score the
    /// borrowed rows directly.
    fn eval_deltas(&self, batch: &DeltaBatch<'_>) -> Vec<PlanScore> {
        self.eval_batch(&batch.to_eval_batch())
    }

    /// Implementation name (for metrics / bench labels).
    fn name(&self) -> &'static str;

    /// Convenience: score whole plans against a system.
    fn eval_plans(&self, sys: &System, plans: &[&Plan]) -> Vec<PlanScore> {
        self.eval_batch(&EvalBatch::from_plans(sys, plans))
    }

    /// Convenience: score one plan.
    fn eval_plan(&self, sys: &System, plan: &Plan) -> PlanScore {
        self.eval_plans(sys, &[plan])[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    #[test]
    fn trait_object_scores_plan() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0, 2.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        plan.vms[v].push_task(&sys, TaskId(0));
        plan.vms[v].push_task(&sys, TaskId(1));
        let eval: &dyn PlanEvaluator = &NativeEvaluator;
        let score = eval.eval_plan(&sys, &plan);
        assert_eq!(score.makespan, 30.0);
        assert_eq!(score.cost, 5.0);
    }
}
