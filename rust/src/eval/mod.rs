//! Plan evaluation: the scoring abstraction shared by the planner, the
//! coordinator and the benchmarks.
//!
//! The paper's heuristic repeatedly scores candidate execution plans
//! (makespan + billed cost).  **The delta path is THE evaluation entry
//! point**: every scheduler hot loop (FIND's accept test, BALANCE's move
//! search, REPLACE's swap scoring, multistart's re-scoring) expresses its
//! candidates as [`DeltaBatch`]es of borrowed rows and scores them through
//! [`PlanEvaluator::eval_deltas`] — no plan clones, no per-candidate
//! allocation.  The owned [`EvalBatch`] form survives only as the tensor
//! layout the AOT-compiled XLA artifact consumes and as the default
//! bridge for evaluators without a native delta path.
//!
//! The pieces:
//!
//! * [`PlanArena`] — struct-of-arrays arena holding one plan's state:
//!   all per-VM aggregation rows in a single contiguous `Vec<f64>`
//!   (slot-major, stride `n_apps`), with a free-list so VM churn recycles
//!   rows instead of shifting them.  Scheduler phases mutate the arena in
//!   place and borrow candidate rows straight out of it
//!   ([`PlanArena::delta_candidate`]); [`crate::model::Plan`] remains the
//!   stable public form, with bit-exact `Plan ↔ PlanArena` conversion at
//!   the boundaries.
//! * [`PlanEvaluator`] — the trait the planner scores through;
//!   [`eval_deltas`](PlanEvaluator::eval_deltas) is the hot method,
//!   [`eval_batch`](PlanEvaluator::eval_batch) the owned/tensor form.
//! * [`NativeEvaluator`] — exact pure-rust scoring (reference +
//!   fallback); scores borrowed delta rows directly, no materialisation.
//! * [`DeltaBatch`] / [`DeltaCandidate`] — candidates as rows borrowing
//!   live state: arena stripes ([`DeltaCandidate::push_row`]), per-`Vm`
//!   caches ([`DeltaCandidate::push_vm`]), or synthesised rows for VMs
//!   that exist only hypothetically
//!   ([`DeltaCandidate::push_synth`]).  [`DeltaBatch::from_plan`] wraps a
//!   whole plan as one candidate — the zero-clone `eval_plan`.
//! * [`EvalBatch`] / [`Candidate`] — the lossless owned per-(vm, app)
//!   aggregation, i.e. exactly the padded tensor layout of the XLA
//!   artifact call (see `python/compile/model.py`).
//!
//! The PJRT-backed implementation lives in [`crate::runtime`]; it is
//! differentially tested against [`NativeEvaluator`].  The `arena_parity`
//! integration suite pins the arena path bit-for-bit against the
//! materialising legacy path across every scenario preset.
//!
//! # Threading
//!
//! Evaluators themselves never spawn: every trait method is a pure
//! synchronous function of its batch.  Intra-solve parallelism lives in
//! one place — [`eval_deltas_chunked`] — which splits a [`DeltaBatch`]
//! into contiguous candidate ranges, scores the ranges on the
//! [`crate::util::parallel`] scoped pool via
//! [`PlanEvaluator::eval_delta_range`], and concatenates the per-range
//! scores back in candidate order.  Because each candidate's score is a
//! pure function of that candidate alone (the per-row `sizes · perf`
//! fold never crosses candidates), the merged vector is **bit-for-bit
//! identical at any thread count** — chunk boundaries are a pure
//! performance knob.  Only evaluators that opt in via
//! [`PlanEvaluator::supports_chunked_deltas`] are fanned out (the
//! native evaluator does; the XLA artifact keeps routing whole batches
//! through its tensor batcher).  Cancellation is cooperative: workers
//! poll the token between ranges and the entry point returns `None`,
//! discarding all partial work, so a cancelled caller commits nothing.
//!
//! The callers (REPLACE candidate scoring, and anything else holding a
//! wide `DeltaBatch`) are themselves fanned out at most one level up —
//! see the no-nested-spawning rule in [`crate::util::parallel`].

mod arena;
mod batch;
mod native;

pub use arena::PlanArena;
pub use batch::{AggSizes, Candidate, DeltaBatch, DeltaCandidate, DeltaRow, EvalBatch};
pub use native::NativeEvaluator;

use std::ops::Range;

use crate::model::{Plan, PlanScore, System};
use crate::util::{parallel_map, resolve_threads, CancelToken};

/// Batch scoring of candidate execution plans.
///
/// Implementations must return one [`PlanScore`] per candidate, in order.
/// Scores follow the paper's model exactly: eq. 5 (boot overhead + task
/// work), eq. 6 (hourly-ceiling billing), eq. 7 (makespan), eq. 8 (total
/// cost).
pub trait PlanEvaluator: Send + Sync {
    /// Score a prepared batch.
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore>;

    /// Score a batch of partial (delta) candidates whose rows borrow
    /// live plan state instead of owning it — the zero-clone hot path
    /// REPLACE scores candidate swaps through.  The default bridges to
    /// [`eval_batch`](Self::eval_batch) by materialising the batch
    /// (evaluators that pad tensors, e.g. the XLA artifact, copy the
    /// rows anyway); [`NativeEvaluator`] overrides it to score the
    /// borrowed rows directly.
    fn eval_deltas(&self, batch: &DeltaBatch<'_>) -> Vec<PlanScore> {
        self.eval_batch(&batch.to_eval_batch())
    }

    /// Whether [`eval_delta_range`](Self::eval_delta_range) may be
    /// called concurrently on disjoint ranges of one batch (see
    /// [`eval_deltas_chunked`]).  Defaults to `false`: evaluators that
    /// amortise per-call setup over the whole batch (the XLA artifact
    /// pads one tensor per call) are better off scoring it in one piece,
    /// and evaluators with interior mutability must explicitly vouch for
    /// concurrent range calls.  [`NativeEvaluator`] opts in.
    fn supports_chunked_deltas(&self) -> bool {
        false
    }

    /// Score the candidates `batch.candidates[range]`, returning their
    /// scores in candidate order.  Must be arithmetic-identical to the
    /// corresponding slice of [`eval_deltas`](Self::eval_deltas) — the
    /// chunked parallel path relies on per-candidate purity to merge
    /// range results bit-for-bit.  The default materialises just the
    /// range and bridges to [`eval_batch`](Self::eval_batch).
    fn eval_delta_range(&self, batch: &DeltaBatch<'_>, range: Range<usize>) -> Vec<PlanScore> {
        let sub = EvalBatch {
            candidates: batch.candidates[range]
                .iter()
                .map(DeltaCandidate::to_candidate)
                .collect(),
            overhead: batch.overhead,
            hour: batch.hour,
            billing: batch.billing,
            n_apps: batch.n_apps,
        };
        self.eval_batch(&sub)
    }

    /// Implementation name (for metrics / bench labels).
    fn name(&self) -> &'static str;

    /// Convenience: score whole plans against a system.
    fn eval_plans(&self, sys: &System, plans: &[&Plan]) -> Vec<PlanScore> {
        self.eval_batch(&EvalBatch::from_plans(sys, plans))
    }

    /// Convenience: score one plan.
    fn eval_plan(&self, sys: &System, plan: &Plan) -> PlanScore {
        self.eval_plans(sys, &[plan])[0]
    }
}

/// Below this many candidates the fan-out costs more than it saves and
/// the batch is scored inline.  A pure performance threshold: both paths
/// produce bit-identical scores, so the exact value never changes a plan.
const MIN_CHUNKED_CANDIDATES: usize = 32;

/// Score a delta batch, fanning contiguous candidate ranges across up to
/// `threads` workers ([`crate::util::parallel_map`] semantics: `0` =
/// auto-detect, `1` = inline sequential).
///
/// The scores come back concatenated in candidate order and are
/// **bit-for-bit identical at any thread count**: chunking is by whole
/// candidates, so no float fold ever changes its summation order.  The
/// fan-out engages only when the evaluator opts in
/// ([`PlanEvaluator::supports_chunked_deltas`]) and the batch is large
/// enough to amortise it; otherwise the call degenerates to one
/// [`PlanEvaluator::eval_deltas`].
///
/// Returns `None` iff `cancel` fired: workers poll the token between
/// ranges, already-scored ranges are discarded, and the pool drains
/// normally (no detached threads, no deadlock) — the caller must treat
/// the round as abandoned and commit nothing.
pub fn eval_deltas_chunked(
    evaluator: &dyn PlanEvaluator,
    batch: &DeltaBatch<'_>,
    threads: usize,
    cancel: &CancelToken,
) -> Option<Vec<PlanScore>> {
    if cancel.is_cancelled() {
        return None;
    }
    let n = batch.len();
    let threads = resolve_threads(threads).min(n.max(1));
    if threads <= 1 || n < MIN_CHUNKED_CANDIDATES || !evaluator.supports_chunked_deltas() {
        return Some(evaluator.eval_deltas(batch));
    }
    // ~4 chunks per worker: enough granularity for the atomic-counter
    // work stealing to even out skewed candidate sizes, coarse enough
    // that chunk dispatch stays negligible next to the scoring itself.
    let per = n.div_ceil(threads * 4).max(1);
    let chunks = n.div_ceil(per);
    let chunk_scores = parallel_map(threads, chunks, |ci| {
        if cancel.is_cancelled() {
            return None;
        }
        let lo = ci * per;
        let hi = (lo + per).min(n);
        Some(evaluator.eval_delta_range(batch, lo..hi))
    });
    let mut out = Vec::with_capacity(n);
    for scores in chunk_scores {
        out.extend(scores?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    #[test]
    fn trait_object_scores_plan() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0, 2.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        plan.vms[v].push_task(&sys, TaskId(0));
        plan.vms[v].push_task(&sys, TaskId(1));
        let eval: &dyn PlanEvaluator = &NativeEvaluator;
        let score = eval.eval_plan(&sys, &plan);
        assert_eq!(score.makespan, 30.0);
        assert_eq!(score.cost, 5.0);
    }

    /// A wide batch exercising owned + borrowed rows across many
    /// candidates (enough to clear `MIN_CHUNKED_CANDIDATES`).
    fn wide_batch(sys: &System) -> DeltaBatch<'_> {
        let mut batch = DeltaBatch::new(sys);
        for k in 0..(MIN_CHUNKED_CANDIDATES * 3 + 7) {
            let mut c = DeltaCandidate::default();
            for v in 0..(1 + k % 5) {
                let it = InstanceTypeId(((k + v) % sys.n_types()) as u16);
                c.push_synth(
                    vec![0.5 + (k * 7 + v) as f64 % 11.0, (k % 3) as f64],
                    sys.perf.row(it),
                    sys.rate(it),
                );
            }
            batch.push(c);
        }
        batch
    }

    fn two_app_sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0, 3.0])
            .app("a2", vec![2.0, 4.0])
            .instance_type("x", 5.0, vec![10.0, 12.0])
            .instance_type("y", 9.0, vec![6.0, 7.0])
            .overhead(30.0)
            .build()
            .unwrap()
    }

    #[test]
    fn chunked_scores_bit_identical_at_any_thread_count() {
        let sys = two_app_sys();
        let batch = wide_batch(&sys);
        let seq = NativeEvaluator.eval_deltas(&batch);
        for threads in [1usize, 2, 3, 4, 0] {
            let par =
                eval_deltas_chunked(&NativeEvaluator, &batch, threads, &CancelToken::default())
                    .expect("not cancelled");
            assert_eq!(par.len(), seq.len(), "threads {threads}");
            for (i, (a, b)) in par.iter().zip(&seq).enumerate() {
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "t{threads} c{i}");
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "t{threads} c{i}");
            }
        }
    }

    #[test]
    fn default_range_bridge_matches_delta_scores() {
        // An evaluator that never overrides the range method must still
        // score ranges consistently with its own eval_deltas.
        struct BridgeOnly;
        impl PlanEvaluator for BridgeOnly {
            fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore> {
                NativeEvaluator.eval_batch(batch)
            }
            fn name(&self) -> &'static str {
                "bridge-only"
            }
        }
        let sys = two_app_sys();
        let batch = wide_batch(&sys);
        assert!(!BridgeOnly.supports_chunked_deltas());
        let all = BridgeOnly.eval_deltas(&batch);
        let lo = 3;
        let hi = batch.len() - 2;
        let range = BridgeOnly.eval_delta_range(&batch, lo..hi);
        assert_eq!(range.len(), hi - lo);
        for (i, s) in range.iter().enumerate() {
            assert_eq!(s.makespan.to_bits(), all[lo + i].makespan.to_bits());
            assert_eq!(s.cost.to_bits(), all[lo + i].cost.to_bits());
        }
        // Opted-out evaluators are never fanned out — but still score.
        let via = eval_deltas_chunked(&BridgeOnly, &batch, 4, &CancelToken::default()).unwrap();
        assert_eq!(via.len(), all.len());
    }

    #[test]
    fn cancelled_chunked_scoring_returns_none() {
        let sys = two_app_sys();
        let batch = wide_batch(&sys);
        let cancel = CancelToken::default();
        cancel.cancel();
        assert!(eval_deltas_chunked(&NativeEvaluator, &batch, 4, &cancel).is_none());
        assert!(eval_deltas_chunked(&NativeEvaluator, &batch, 1, &cancel).is_none());
    }
}
