//! Plan evaluation: the scoring abstraction shared by the planner, the
//! coordinator and the benchmarks.
//!
//! The paper's heuristic repeatedly scores candidate execution plans
//! (makespan + billed cost).  **The delta path is THE evaluation entry
//! point**: every scheduler hot loop (FIND's accept test, BALANCE's move
//! search, REPLACE's swap scoring, multistart's re-scoring) expresses its
//! candidates as [`DeltaBatch`]es of borrowed rows and scores them through
//! [`PlanEvaluator::eval_deltas`] — no plan clones, no per-candidate
//! allocation.  The owned [`EvalBatch`] form survives only as the tensor
//! layout the AOT-compiled XLA artifact consumes and as the default
//! bridge for evaluators without a native delta path.
//!
//! The pieces:
//!
//! * [`PlanArena`] — struct-of-arrays arena holding one plan's state:
//!   all per-VM aggregation rows in a single contiguous `Vec<f64>`
//!   (slot-major, stride `n_apps`), with a free-list so VM churn recycles
//!   rows instead of shifting them.  Scheduler phases mutate the arena in
//!   place and borrow candidate rows straight out of it
//!   ([`PlanArena::delta_candidate`]); [`crate::model::Plan`] remains the
//!   stable public form, with bit-exact `Plan ↔ PlanArena` conversion at
//!   the boundaries.
//! * [`PlanEvaluator`] — the trait the planner scores through;
//!   [`eval_deltas`](PlanEvaluator::eval_deltas) is the hot method,
//!   [`eval_batch`](PlanEvaluator::eval_batch) the owned/tensor form.
//! * [`NativeEvaluator`] — exact pure-rust scoring (reference +
//!   fallback); scores borrowed delta rows directly, no materialisation.
//! * [`DeltaBatch`] / [`DeltaCandidate`] — candidates as rows borrowing
//!   live state: arena stripes ([`DeltaCandidate::push_row`]), per-`Vm`
//!   caches ([`DeltaCandidate::push_vm`]), or synthesised rows for VMs
//!   that exist only hypothetically
//!   ([`DeltaCandidate::push_synth`]).  [`DeltaBatch::from_plan`] wraps a
//!   whole plan as one candidate — the zero-clone `eval_plan`.
//! * [`EvalBatch`] / [`Candidate`] — the lossless owned per-(vm, app)
//!   aggregation, i.e. exactly the padded tensor layout of the XLA
//!   artifact call (see `python/compile/model.py`).
//!
//! The PJRT-backed implementation lives in [`crate::runtime`]; it is
//! differentially tested against [`NativeEvaluator`].  The `arena_parity`
//! integration suite pins the arena path bit-for-bit against the
//! materialising legacy path across every scenario preset.

mod arena;
mod batch;
mod native;

pub use arena::PlanArena;
pub use batch::{AggSizes, Candidate, DeltaBatch, DeltaCandidate, DeltaRow, EvalBatch};
pub use native::NativeEvaluator;

use crate::model::{Plan, PlanScore, System};

/// Batch scoring of candidate execution plans.
///
/// Implementations must return one [`PlanScore`] per candidate, in order.
/// Scores follow the paper's model exactly: eq. 5 (boot overhead + task
/// work), eq. 6 (hourly-ceiling billing), eq. 7 (makespan), eq. 8 (total
/// cost).
pub trait PlanEvaluator: Send + Sync {
    /// Score a prepared batch.
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore>;

    /// Score a batch of partial (delta) candidates whose rows borrow
    /// live plan state instead of owning it — the zero-clone hot path
    /// REPLACE scores candidate swaps through.  The default bridges to
    /// [`eval_batch`](Self::eval_batch) by materialising the batch
    /// (evaluators that pad tensors, e.g. the XLA artifact, copy the
    /// rows anyway); [`NativeEvaluator`] overrides it to score the
    /// borrowed rows directly.
    fn eval_deltas(&self, batch: &DeltaBatch<'_>) -> Vec<PlanScore> {
        self.eval_batch(&batch.to_eval_batch())
    }

    /// Implementation name (for metrics / bench labels).
    fn name(&self) -> &'static str;

    /// Convenience: score whole plans against a system.
    fn eval_plans(&self, sys: &System, plans: &[&Plan]) -> Vec<PlanScore> {
        self.eval_batch(&EvalBatch::from_plans(sys, plans))
    }

    /// Convenience: score one plan.
    fn eval_plan(&self, sys: &System, plan: &Plan) -> PlanScore {
        self.eval_plans(sys, &[plan])[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    #[test]
    fn trait_object_scores_plan() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0, 2.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        plan.vms[v].push_task(&sys, TaskId(0));
        plan.vms[v].push_task(&sys, TaskId(1));
        let eval: &dyn PlanEvaluator = &NativeEvaluator;
        let score = eval.eval_plan(&sys, &plan);
        assert_eq!(score.makespan, 30.0);
        assert_eq!(score.cost, 5.0);
    }
}
