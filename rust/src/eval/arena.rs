//! The arena: plan state flattened into struct-of-arrays storage so the
//! planner's inner loops walk contiguous memory instead of chasing
//! `Vec<Vm>` pointers.
//!
//! # Layout
//!
//! A [`PlanArena`] separates **slots** (physical rows in the arrays)
//! from **positions** (logical VM order, what a `Plan` index means):
//!
//! * `agg` — all per-application size aggregations in ONE `Vec<f64>`,
//!   slot-major with stride `n_apps`: slot `s`'s row is
//!   `agg[s*n_apps .. (s+1)*n_apps]`.  This is the array candidate
//!   scoring walks; rows borrow straight into it via
//!   [`PlanArena::delta_candidate`].
//! * `work`, `it` — per-slot cached task work and instance type,
//!   parallel to `agg`'s rows.
//! * `tasks` — per-slot task lists; kept off the scoring path (scores
//!   depend on the assignment only through `agg`, eq. 5 being linear in
//!   task size).
//! * `order` — position → slot.  Defines both plan order and liveness:
//!   a slot not in `order` is dead.
//! * `free` — dead slots, recycled LIFO by [`PlanArena::add_vm`], so
//!   ADD/REMOVE/REPLACE churn neither shifts surviving rows (the
//!   `Vec::remove` cost this replaces) nor grows the arrays.
//!
//! # Bit-exactness contract
//!
//! Every mutator mirrors its `Vm`/`Plan` counterpart operation for
//! operation — same float update order, same negative-residue clamping,
//! same iteration order in [`PlanArena::score`] — and materialisation
//! transfers the cached floats verbatim (`Vm::from_parts`), so
//! `Plan -> PlanArena -> (same edits) -> Plan` is bit-identical to
//! performing the edits on the `Plan` directly.  A freed slot is zeroed
//! on removal (recycling must hand out fresh-`Vm::new` state), but a
//! *live* emptied slot keeps whatever tiny float residue incremental
//! removal left, exactly like a live `Vm`.  The `arena_parity`
//! integration suite pins all of this.

use crate::model::{billed_cost, InstanceTypeId, Plan, PlanScore, System, TaskId, Vm};

use super::{DeltaBatch, DeltaCandidate};

/// Struct-of-arrays arena holding one plan's state (see module docs).
#[derive(Debug, Clone, Default)]
pub struct PlanArena {
    n_apps: usize,
    /// Slot-major aggregation rows, stride `n_apps`.
    agg: Vec<f64>,
    /// Per-slot cached task work (seconds, excludes boot overhead).
    work: Vec<f64>,
    /// Per-slot instance type.
    it: Vec<InstanceTypeId>,
    /// Per-slot task list (not touched by scoring).
    tasks: Vec<Vec<TaskId>>,
    /// Dead slots available for recycling (LIFO).
    free: Vec<u32>,
    /// Position -> slot; the logical VM order of the plan.
    order: Vec<u32>,
}

impl PlanArena {
    /// An empty arena for `sys` (load plans into it with
    /// [`load_plan`](Self::load_plan)).
    pub fn new(sys: &System) -> Self {
        Self { n_apps: sys.n_apps(), ..Self::default() }
    }

    /// Flatten a plan into a fresh arena.
    pub fn from_plan(sys: &System, plan: &Plan) -> Self {
        let mut arena = Self::new(sys);
        arena.load_plan(plan);
        arena
    }

    /// Reload the arena from a plan, reusing the existing allocations
    /// (the per-slot task `Vec`s in particular) — the cheap solve-loop
    /// entry: FIND holds one arena and reloads it each phase instead of
    /// re-allocating.
    pub fn load_plan(&mut self, plan: &Plan) {
        self.order.clear();
        self.free.clear();
        self.it.clear();
        self.work.clear();
        self.agg.clear();
        self.tasks.truncate(plan.n_vms());
        while self.tasks.len() < plan.n_vms() {
            self.tasks.push(Vec::new());
        }
        for (i, vm) in plan.vms.iter().enumerate() {
            self.it.push(vm.it);
            self.work.push(vm.work());
            self.agg.extend_from_slice(vm.agg_sizes());
            self.tasks[i].clear();
            self.tasks[i].extend_from_slice(vm.tasks());
            self.order.push(i as u32);
        }
    }

    /// Materialise the arena's live state into `plan` (cached floats
    /// transferred verbatim; see the module's bit-exactness contract).
    pub fn store_plan(&self, plan: &mut Plan) {
        plan.vms.clear();
        plan.vms.reserve(self.order.len());
        for &s in &self.order {
            let s = s as usize;
            plan.vms.push(Vm::from_parts(
                self.it[s],
                self.tasks[s].clone(),
                self.agg[s * self.n_apps..(s + 1) * self.n_apps].to_vec(),
                self.work[s],
            ));
        }
    }

    /// [`store_plan`](Self::store_plan) into a fresh plan.
    pub fn to_plan(&self) -> Plan {
        let mut plan = Plan::new();
        self.store_plan(&mut plan);
        plan
    }

    // -- geometry ---------------------------------------------------------

    #[inline]
    fn slot(&self, pos: usize) -> usize {
        self.order[pos] as usize
    }

    pub fn n_vms(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Total number of assigned tasks across live VMs.
    pub fn n_assigned(&self) -> usize {
        self.order.iter().map(|&s| self.tasks[s as usize].len()).sum()
    }

    // -- per-position accessors (positions mirror `plan.vms` indices) ----

    #[inline]
    pub fn it_at(&self, pos: usize) -> InstanceTypeId {
        self.it[self.slot(pos)]
    }

    #[inline]
    pub fn work_at(&self, pos: usize) -> f64 {
        self.work[self.slot(pos)]
    }

    #[inline]
    pub fn agg_at(&self, pos: usize) -> &[f64] {
        let s = self.slot(pos);
        &self.agg[s * self.n_apps..(s + 1) * self.n_apps]
    }

    #[inline]
    pub fn tasks_at(&self, pos: usize) -> &[TaskId] {
        &self.tasks[self.slot(pos)]
    }

    #[inline]
    pub fn len_at(&self, pos: usize) -> usize {
        self.tasks[self.slot(pos)].len()
    }

    #[inline]
    pub fn is_empty_at(&self, pos: usize) -> bool {
        self.tasks[self.slot(pos)].is_empty()
    }

    /// eq. 5 for one VM (mirrors [`Vm::exec`]).
    #[inline]
    pub fn exec_at(&self, sys: &System, pos: usize) -> f64 {
        let s = self.slot(pos);
        if self.tasks[s].is_empty() && sys.overhead == 0.0 {
            0.0
        } else {
            sys.overhead + self.work[s]
        }
    }

    /// eq. 6 for one VM (mirrors [`Vm::cost`]).
    #[inline]
    pub fn cost_at(&self, sys: &System, pos: usize) -> f64 {
        billed_cost(self.exec_at(sys, pos), sys.rate(self.it_at(pos)), sys.hour, sys.billing)
    }

    /// eq. 7 makespan (mirrors `Plan::exec`: same fold, position order).
    pub fn exec(&self, sys: &System) -> f64 {
        (0..self.n_vms()).map(|p| self.exec_at(sys, p)).fold(0.0, f64::max)
    }

    /// eq. 8 total cost (mirrors `Plan::cost`: left-to-right sum in
    /// position order).
    pub fn cost(&self, sys: &System) -> f64 {
        (0..self.n_vms()).map(|p| self.cost_at(sys, p)).sum()
    }

    pub fn score(&self, sys: &System) -> PlanScore {
        PlanScore { makespan: self.exec(sys), cost: self.cost(sys) }
    }

    // -- mutations (each mirrors its Vm/Plan counterpart bit-for-bit) ----

    /// Mirror of [`Vm::push_task`]: same cache update order.
    pub fn push_task(&mut self, sys: &System, pos: usize, task: TaskId) {
        let s = self.slot(pos);
        let t = sys.task(task);
        self.work[s] += sys.exec_time(self.it[s], task);
        self.agg[s * self.n_apps + t.app.index()] += t.size;
        self.tasks[s].push(task);
    }

    /// Mirror of [`Vm::remove_task`]: `swap_remove`, subtract, clamp
    /// tiny negative residue to zero.  Returns whether the task was
    /// present.
    pub fn remove_task(&mut self, sys: &System, pos: usize, task: TaskId) -> bool {
        let s = self.slot(pos);
        let Some(idx) = self.tasks[s].iter().position(|t| *t == task) else {
            return false;
        };
        self.tasks[s].swap_remove(idx);
        let t = sys.task(task);
        self.work[s] -= sys.exec_time(self.it[s], task);
        let cell = s * self.n_apps + t.app.index();
        self.agg[cell] -= t.size;
        if self.work[s] < 0.0 {
            self.work[s] = 0.0;
        }
        if self.agg[cell] < 0.0 {
            self.agg[cell] = 0.0;
        }
        true
    }

    /// Mirror of `Plan::move_task`; returns whether the task was found
    /// on `from`.
    pub fn move_task(&mut self, sys: &System, from: usize, to: usize, task: TaskId) -> bool {
        assert_ne!(from, to, "move_task: from == to");
        if !self.remove_task(sys, from, task) {
            return false;
        }
        self.push_task(sys, to, task);
        true
    }

    /// Mirror of [`Vm::drain_tasks`]: zero the caches, take the list.
    pub fn drain_tasks(&mut self, pos: usize) -> Vec<TaskId> {
        let s = self.slot(pos);
        self.work[s] = 0.0;
        self.agg[s * self.n_apps..(s + 1) * self.n_apps].fill(0.0);
        std::mem::take(&mut self.tasks[s])
    }

    /// Provision a fresh empty VM, recycling a freed slot when one is
    /// available; returns its position (`== n_vms() - 1`, matching
    /// `Plan::add_vm`).
    pub fn add_vm(&mut self, it: InstanceTypeId) -> usize {
        let s = match self.free.pop() {
            // Freed slots were zeroed on removal: fresh-Vm state.
            Some(s) => {
                self.it[s as usize] = it;
                s
            }
            None => {
                let s = self.work.len() as u32;
                self.it.push(it);
                self.work.push(0.0);
                self.agg.extend(std::iter::repeat(0.0).take(self.n_apps));
                self.tasks.push(Vec::new());
                s
            }
        };
        self.order.push(s);
        self.order.len() - 1
    }

    /// Deprovision the VM at `pos`: later positions shift down by one
    /// (same index semantics as `Plan::remove_vm`), but only the small
    /// `order` vector moves — the slot's row is zeroed and recycled, no
    /// VM data shifts.
    pub fn remove_vm(&mut self, pos: usize) {
        let s = self.order.remove(pos);
        self.clear_slot(s);
        self.free.push(s);
    }

    /// Deprovision several positions at once (mirror of
    /// `Plan::remove_vms`): one compaction pass over `order`, duplicates
    /// collapse, out-of-range panics.
    pub fn remove_vms(&mut self, victims: &[usize]) {
        if victims.is_empty() {
            return;
        }
        let mut doomed = vec![false; self.order.len()];
        for &v in victims {
            doomed[v] = true;
        }
        let mut write = 0usize;
        for read in 0..self.order.len() {
            let s = self.order[read];
            if doomed[read] {
                self.clear_slot(s);
                self.free.push(s);
            } else {
                self.order[write] = s;
                write += 1;
            }
        }
        self.order.truncate(write);
    }

    /// Mirror of `Plan::drop_empty_vms`: free every task-less position,
    /// preserving survivor order.
    pub fn drop_empty_vms(&mut self) {
        let mut write = 0usize;
        for read in 0..self.order.len() {
            let s = self.order[read];
            if self.tasks[s as usize].is_empty() {
                self.clear_slot(s);
                self.free.push(s);
            } else {
                self.order[write] = s;
                write += 1;
            }
        }
        self.order.truncate(write);
    }

    /// Zero a slot so recycling hands out fresh-`Vm::new` state.
    fn clear_slot(&mut self, s: u32) {
        let s = s as usize;
        self.tasks[s].clear();
        self.work[s] = 0.0;
        self.agg[s * self.n_apps..(s + 1) * self.n_apps].fill(0.0);
    }

    // -- scoring ----------------------------------------------------------

    /// The live plan as one delta candidate: rows borrow the contiguous
    /// `agg` stripes in position order, skipping rows that would score
    /// as absent (empty with zero overhead) — score-identical to
    /// materialising and running `eval_plan`.
    pub fn delta_candidate<'a>(&'a self, sys: &'a System) -> DeltaCandidate<'a> {
        let mut cand = DeltaCandidate::default();
        for pos in 0..self.n_vms() {
            if self.is_empty_at(pos) && sys.overhead == 0.0 {
                continue;
            }
            let it = self.it_at(pos);
            cand.push_row(self.agg_at(pos), sys.perf.row(it), sys.rate(it));
        }
        cand
    }

    /// [`delta_candidate`](Self::delta_candidate) wrapped as a
    /// single-candidate batch for [`PlanEvaluator::eval_deltas`].
    ///
    /// [`PlanEvaluator::eval_deltas`]: super::PlanEvaluator::eval_deltas
    pub fn delta_batch<'a>(&'a self, sys: &'a System) -> DeltaBatch<'a> {
        let mut batch = DeltaBatch::new(sys);
        batch.push(self.delta_candidate(sys));
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{NativeEvaluator, PlanEvaluator};
    use crate::model::SystemBuilder;

    fn sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0, 4.0])
            .app("a2", vec![3.0, 5.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .overhead(30.0)
            .build()
            .unwrap()
    }

    fn seed_plan(s: &System) -> Plan {
        let mut p = Plan::new();
        let v0 = p.add_vm(s, InstanceTypeId(0));
        let v1 = p.add_vm(s, InstanceTypeId(1));
        p.vms[v0].push_task(s, TaskId(0));
        p.vms[v0].push_task(s, TaskId(3));
        p.vms[v1].push_task(s, TaskId(1));
        p.vms[v1].push_task(s, TaskId(2));
        p.vms[v1].push_task(s, TaskId(4));
        p
    }

    fn assert_same(s: &System, plan: &Plan, arena: &PlanArena) {
        assert_eq!(plan.n_vms(), arena.n_vms());
        assert_eq!(plan.n_assigned(), arena.n_assigned());
        for (i, vm) in plan.vms.iter().enumerate() {
            assert_eq!(vm.it, arena.it_at(i), "vm{i} type");
            assert_eq!(vm.tasks(), arena.tasks_at(i), "vm{i} tasks");
            assert_eq!(vm.work().to_bits(), arena.work_at(i).to_bits(), "vm{i} work");
            for (m, (a, b)) in vm.agg_sizes().iter().zip(arena.agg_at(i)).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "vm{i} agg[{m}]");
            }
            assert_eq!(vm.exec(s).to_bits(), arena.exec_at(s, i).to_bits(), "vm{i} exec");
            assert_eq!(vm.cost(s).to_bits(), arena.cost_at(s, i).to_bits(), "vm{i} cost");
        }
        let ps = plan.score(s);
        let ars = arena.score(s);
        assert_eq!(ps.makespan.to_bits(), ars.makespan.to_bits());
        assert_eq!(ps.cost.to_bits(), ars.cost.to_bits());
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let s = sys();
        let p = seed_plan(&s);
        let arena = PlanArena::from_plan(&s, &p);
        assert_same(&s, &p, &arena);
        let back = arena.to_plan();
        assert_same(&s, &back, &arena);
        assert!(back.validate_partition(&s).is_ok());
    }

    #[test]
    fn mutations_mirror_vm_ops() {
        let s = sys();
        let mut p = seed_plan(&s);
        let mut arena = PlanArena::from_plan(&s, &p);

        assert_eq!(
            p.move_task(&s, 1, 0, TaskId(2)),
            arena.move_task(&s, 1, 0, TaskId(2))
        );
        assert_same(&s, &p, &arena);

        // Removing an absent task is a no-op on both sides.
        assert!(!p.vms[0].remove_task(&s, TaskId(1)));
        assert!(!arena.remove_task(&s, 0, TaskId(1)));
        assert_same(&s, &p, &arena);

        assert_eq!(p.vms[1].drain_tasks(), arena.drain_tasks(1));
        assert_same(&s, &p, &arena);

        p.drop_empty_vms();
        arena.drop_empty_vms();
        assert_same(&s, &p, &arena);
    }

    #[test]
    fn free_list_recycles_slots() {
        let s = sys();
        let p = seed_plan(&s);
        let mut arena = PlanArena::from_plan(&s, &p);
        let rows_before = arena.work.len();

        arena.drain_tasks(0);
        arena.remove_vm(0);
        assert_eq!(arena.n_vms(), 1);
        // Re-provision: the freed slot is reused, no array growth.
        let pos = arena.add_vm(InstanceTypeId(0));
        assert_eq!(pos, 1);
        assert_eq!(arena.work.len(), rows_before);
        // Recycled slot is pristine.
        assert!(arena.is_empty_at(pos));
        assert_eq!(arena.work_at(pos), 0.0);
        assert!(arena.agg_at(pos).iter().all(|&x| x == 0.0));
        // Growth only once the free list is exhausted.
        arena.add_vm(InstanceTypeId(1));
        assert_eq!(arena.work.len(), rows_before + 1);
    }

    #[test]
    fn batch_removal_matches_plan() {
        let s = sys();
        let mut p = seed_plan(&s);
        p.add_vm(&s, InstanceTypeId(0));
        let mut arena = PlanArena::from_plan(&s, &p);
        for v in [0usize, 2] {
            p.vms[v].drain_tasks();
            arena.drain_tasks(v);
        }
        p.remove_vms(&[0, 2]);
        arena.remove_vms(&[0, 2]);
        assert_same(&s, &p, &arena);
    }

    #[test]
    fn delta_batch_scores_like_eval_plan() {
        let s = sys();
        let mut p = seed_plan(&s);
        p.add_vm(&s, InstanceTypeId(1)); // empty; bills its boot hour (o = 30)
        let arena = PlanArena::from_plan(&s, &p);
        let direct = NativeEvaluator.eval_plan(&s, &p);
        let via_arena = NativeEvaluator.eval_deltas(&arena.delta_batch(&s))[0];
        assert_eq!(direct.makespan.to_bits(), via_arena.makespan.to_bits());
        assert_eq!(direct.cost.to_bits(), via_arena.cost.to_bits());
    }

    #[test]
    fn load_plan_reuses_arena() {
        let s = sys();
        let p = seed_plan(&s);
        let mut arena = PlanArena::new(&s);
        arena.load_plan(&p);
        assert_same(&s, &p, &arena);
        // Mutate, then reload: the arena snaps back to the plan.
        arena.drain_tasks(0);
        arena.drop_empty_vms();
        arena.load_plan(&p);
        assert_same(&s, &p, &arena);
    }
}
