use std::ops::Range;

use super::{DeltaBatch, DeltaCandidate, EvalBatch, PlanEvaluator};
use crate::model::{billed_cost, PlanScore};

/// Exact pure-rust plan scoring.
///
/// This is the reference implementation of the paper's eq. 5-8 over the
/// aggregated candidate representation; the PJRT-backed
/// [`crate::runtime::XlaEvaluator`] is differentially tested against it,
/// and it serves as the fallback when artifacts are not built.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeEvaluator;

/// Score one delta candidate: the per-row `sizes · perf` dot product and
/// left-to-right cost fold shared verbatim by the whole-batch and
/// range-scoring entry points, so chunk boundaries can never change a
/// single bit of a candidate's score.
#[inline]
fn score_delta(c: &DeltaCandidate<'_>, batch: &DeltaBatch<'_>) -> PlanScore {
    let mut makespan = 0.0f64;
    let mut cost = 0.0f64;
    for row in &c.rows {
        let work: f64 = row.sizes.as_slice().iter().zip(row.perf).map(|(s, p)| s * p).sum();
        let exec = batch.overhead + work;
        makespan = makespan.max(exec);
        cost += billed_cost(exec, row.rate, batch.hour, batch.billing);
    }
    PlanScore { makespan, cost }
}

impl PlanEvaluator for NativeEvaluator {
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore> {
        batch
            .candidates
            .iter()
            .map(|c| {
                let mut makespan = 0.0f64;
                let mut cost = 0.0f64;
                for v in 0..c.n_vms() {
                    if !c.active[v] {
                        continue;
                    }
                    let work: f64 = c.sizes[v]
                        .iter()
                        .zip(&c.perf[v])
                        .map(|(s, p)| s * p)
                        .sum();
                    let exec = batch.overhead + work;
                    makespan = makespan.max(exec);
                    cost += billed_cost(exec, c.rate[v], batch.hour, batch.billing);
                }
                PlanScore { makespan, cost }
            })
            .collect()
    }

    /// Zero-copy delta scoring: identical arithmetic to
    /// [`eval_batch`](PlanEvaluator::eval_batch) (same per-row
    /// `sizes · perf` dot product, same left-to-right cost sum), applied
    /// straight to the borrowed rows — no candidate materialisation.
    fn eval_deltas(&self, batch: &DeltaBatch<'_>) -> Vec<PlanScore> {
        batch.candidates.iter().map(|c| score_delta(c, batch)).collect()
    }

    /// Stateless and pure per candidate, so disjoint ranges of one batch
    /// may be scored concurrently (see
    /// [`eval_deltas_chunked`](super::eval_deltas_chunked)).
    fn supports_chunked_deltas(&self) -> bool {
        true
    }

    /// Zero-copy range scoring: the same [`score_delta`] fold as
    /// [`eval_deltas`](PlanEvaluator::eval_deltas), restricted to the
    /// range — no sub-batch is materialised.
    fn eval_delta_range(&self, batch: &DeltaBatch<'_>, range: Range<usize>) -> Vec<PlanScore> {
        batch.candidates[range].iter().map(|c| score_delta(c, batch)).collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, Plan, SystemBuilder};

    #[test]
    fn matches_plan_score_exactly() {
        // NativeEvaluator over the aggregation must equal Plan::score.
        let sys = SystemBuilder::new()
            .app("a1", (1..=10).map(f64::from).collect())
            .app("a2", vec![2.0; 7])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("cpu", 10.0, vec![10.0, 15.0])
            .overhead(45.0)
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v0 = plan.add_vm(&sys, InstanceTypeId(0));
        let v1 = plan.add_vm(&sys, InstanceTypeId(1));
        for t in sys.tasks() {
            let v = if t.id.0 % 2 == 0 { v0 } else { v1 };
            plan.vms[v].push_task(&sys, t.id);
        }
        let direct = plan.score(&sys);
        let via_eval = NativeEvaluator.eval_plan(&sys, &plan);
        assert!((direct.makespan - via_eval.makespan).abs() < 1e-9);
        assert!((direct.cost - via_eval.cost).abs() < 1e-9);
    }

    #[test]
    fn delta_scoring_matches_owned_batch_bit_for_bit() {
        let sys = SystemBuilder::new()
            .app("a1", (1..=9).map(f64::from).collect())
            .app("a2", vec![2.5; 6])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("cpu", 10.0, vec![10.0, 15.0])
            .overhead(45.0)
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v0 = plan.add_vm(&sys, InstanceTypeId(0));
        let v1 = plan.add_vm(&sys, InstanceTypeId(1));
        for t in sys.tasks() {
            let v = if t.id.0 % 3 == 0 { v0 } else { v1 };
            plan.vms[v].push_task(&sys, t.id);
        }
        // Delta form: one borrowed row per live VM plus a synthesised row.
        let mut delta = super::super::DeltaCandidate::default();
        for vm in &plan.vms {
            delta.push_vm(&sys, vm);
        }
        delta.push_synth(vec![3.0, 1.0], sys.perf.row(InstanceTypeId(0)), sys.rate(InstanceTypeId(0)));
        let mut dbatch = DeltaBatch::new(&sys);
        dbatch.push(delta);

        let direct = NativeEvaluator.eval_deltas(&dbatch);
        let via_owned = NativeEvaluator.eval_batch(&dbatch.to_eval_batch());
        assert_eq!(direct.len(), 1);
        assert_eq!(direct[0].makespan.to_bits(), via_owned[0].makespan.to_bits());
        assert_eq!(direct[0].cost.to_bits(), via_owned[0].cost.to_bits());
    }

    #[test]
    fn range_scoring_matches_full_batch_bit_for_bit() {
        let sys = SystemBuilder::new()
            .app("a1", (1..=9).map(f64::from).collect())
            .app("a2", vec![2.5; 6])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("cpu", 10.0, vec![10.0, 15.0])
            .overhead(45.0)
            .build()
            .unwrap();
        let mut batch = DeltaBatch::new(&sys);
        for k in 0..13usize {
            let mut c = DeltaCandidate::default();
            for v in 0..=(k % 3) {
                let it = crate::model::InstanceTypeId(((k + v) % 2) as u16);
                c.push_synth(
                    vec![1.0 + k as f64, v as f64 * 0.25],
                    sys.perf.row(it),
                    sys.rate(it),
                );
            }
            batch.push(c);
        }
        let full = NativeEvaluator.eval_deltas(&batch);
        for (lo, hi) in [(0usize, 13usize), (0, 5), (5, 13), (3, 4), (7, 7)] {
            let part = NativeEvaluator.eval_delta_range(&batch, lo..hi);
            assert_eq!(part.len(), hi - lo);
            for (i, s) in part.iter().enumerate() {
                assert_eq!(s.makespan.to_bits(), full[lo + i].makespan.to_bits());
                assert_eq!(s.cost.to_bits(), full[lo + i].cost.to_bits());
            }
        }
        assert!(NativeEvaluator.supports_chunked_deltas());
    }

    #[test]
    fn empty_batch() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let batch = EvalBatch::new(&sys);
        assert!(NativeEvaluator.eval_batch(&batch).is_empty());
    }

    #[test]
    fn inactive_slots_ignored() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut batch = EvalBatch::new(&sys);
        let mut c = super::super::Candidate::default();
        c.sizes.push(vec![100.0]);
        c.perf.push(vec![10.0]);
        c.rate.push(5.0);
        c.active.push(false);
        batch.push(c);
        let s = NativeEvaluator.eval_batch(&batch)[0];
        assert_eq!(s.makespan, 0.0);
        assert_eq!(s.cost, 0.0);
    }
}
