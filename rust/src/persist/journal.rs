//! The append-only job journal: length-prefixed, checksummed lifecycle
//! records with fsync on the transitions that must survive a crash.
//!
//! File layout: a fixed header (`b"BOTJ"` magic + little-endian u32
//! [`JOURNAL_VERSION`]) followed by framed records.  Each frame is a
//! u32 LE payload length, a u64 LE FNV-1a checksum of the payload, and
//! the payload itself — one canonical-JSON object with a `"kind"`
//! field (`accept` / `start` / `terminal` / `cancel`).  Canonical JSON
//! (sorted keys, via [`crate::util::Json`]) keeps the bytes
//! deterministic, so compaction rewrites are reproducible.
//!
//! See [`super`] (the module doc) for the full durability model: which
//! records are fsynced, what replay recovers, and how the torn-tail
//! scan and rewrite-and-swap compaction bound the file.

use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config;
use crate::coordinator::JobPriority;
use crate::util::{failpoint, Json};

use super::fnv1a;

/// On-disk format version, written in the header.  A file with any
/// other version is refused at open (no silent migration).
pub const JOURNAL_VERSION: u32 = 1;

/// File magic: "botsched journal".
const MAGIC: [u8; 4] = *b"BOTJ";

/// Header bytes: magic + version.
const HEADER_LEN: usize = 8;

/// Frame overhead per record: u32 payload length + u64 checksum.
const FRAME_LEN: usize = 12;

/// Sanity bound on one record payload; a length field beyond it is
/// treated as a corrupt tail, not an allocation request.
const MAX_PAYLOAD: usize = 1 << 28;

/// Records below which auto-compaction never triggers (tiny journals
/// are not worth rewriting).
const COMPACT_MIN: u64 = 64;

/// One job recovered from a journal replay, in accept order.
#[derive(Debug, Clone)]
pub struct RecoveredJob {
    pub id: String,
    /// The job's op name (registry listing).
    pub op: String,
    /// The full request line to re-execute if the job never finished.
    pub line: String,
    /// Queue placement the job was admitted with.
    pub priority: JobPriority,
    /// Present when the job reached a terminal state before the crash:
    /// the recovered outcome is servable without re-running anything.
    pub terminal: Option<RecoveredTerminal>,
}

/// The recovered outcome of a journaled terminal job.
#[derive(Debug, Clone)]
pub struct RecoveredTerminal {
    /// `"done"` / `"failed"` / `"cancelled"`.
    pub state: String,
    pub result: Option<Json>,
    pub error: Option<String>,
}

/// Replay-index state of one journaled job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum IdxState {
    Live,
    Terminal,
}

#[derive(Debug)]
struct Inner {
    file: File,
    /// In-memory replay index: every journaled job still relevant to a
    /// future replay.  [`Journal::forget`] drops evicted jobs so the
    /// map (and, after compaction, the file) stays bounded by the
    /// registry cap instead of growing with coordinator lifetime.
    index: HashMap<String, IdxState>,
    /// Records currently in the file (including obsolete ones).
    records: u64,
    /// File size in bytes.
    bytes: u64,
    /// Completed rewrite-and-swap compactions.
    compactions: u64,
}

/// The append-only job journal.  All methods are best-effort on IO
/// failure *after* open: an unwritable record flips the journal into
/// **degraded (memory-only) mode** — reported to stderr and on
/// `stats`/`health` — rather than taking the serving path down;
/// durability degrades, availability does not.  While degraded no
/// records are written (jobs admitted in the window are never
/// journaled, so a crash loses them — visibly, never inconsistently)
/// until a [`Journal::probe_reattach`] succeeds.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    inner: Mutex<Inner>,
    /// Set on the first write failure, cleared by a successful
    /// reattach probe.
    degraded: AtomicBool,
    /// Total append/fsync failures over the journal's lifetime.
    write_errors: AtomicU64,
}

impl Journal {
    /// Open (or create) a journal and replay it.  Returns the journal
    /// plus every recovered job in accept order.  A torn tail — a
    /// record truncated or corrupted by a crash mid-append — ends the
    /// replay scan and is truncated away so subsequent appends are
    /// clean.  A file with a foreign magic or version is refused.
    pub fn open(path: &Path) -> io::Result<(Self, Vec<RecoveredJob>)> {
        if failpoint::apply("journal.replay").is_some() {
            return Err(failpoint::injected("journal.replay"));
        }
        let mut file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let mut raw = Vec::new();
        file.read_to_end(&mut raw)?;
        if raw.is_empty() {
            file.write_all(&MAGIC)?;
            file.write_all(&JOURNAL_VERSION.to_le_bytes())?;
            file.sync_data()?;
            raw.extend_from_slice(&MAGIC);
            raw.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
        }
        if raw.len() < HEADER_LEN || raw[..4] != MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{} is not a botsched journal", path.display()),
            ));
        }
        let version = u32::from_le_bytes(raw[4..8].try_into().unwrap());
        if version != JOURNAL_VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "journal {} has version {version}, this build speaks {JOURNAL_VERSION}",
                    path.display()
                ),
            ));
        }
        let (payloads, good_len) = scan(&raw);
        if good_len < raw.len() {
            // Torn tail from a crash mid-append: drop it for good so
            // the next append starts at a clean frame boundary.
            file.set_len(good_len as u64)?;
        }
        let (recovered, index) = replay(&payloads);
        file.seek(SeekFrom::End(0))?;
        let inner = Inner {
            file,
            index,
            records: payloads.len() as u64,
            bytes: good_len as u64,
            compactions: 0,
        };
        Ok((
            Self {
                path: path.to_path_buf(),
                inner: Mutex::new(inner),
                degraded: AtomicBool::new(false),
                write_errors: AtomicU64::new(0),
            },
            recovered,
        ))
    }

    /// The journal's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Journal a job admission: id, op, the full request line and the
    /// queue placement.  Fsynced — callers invoke this *before* the
    /// job becomes visible to any worker, so admission is durable by
    /// the time anyone can observe the job.
    pub fn admit(&self, id: &str, op: &str, line: &str, prio: JobPriority) {
        let payload = Json::obj(vec![
            ("id", Json::str(id)),
            ("kind", Json::str("accept")),
            ("line", Json::str(line)),
            ("op", Json::str(op)),
            ("placement", config::job_priority_to_json(&prio)),
        ]);
        let mut g = self.inner.lock().unwrap();
        if self.is_degraded() {
            // Memory-only window: the job is never indexed, so its
            // later transitions are no-ops too — wholly unjournaled,
            // never half-journaled.
            return;
        }
        match append(&mut g, &payload, true) {
            Ok(()) => {
                g.index.insert(id.to_string(), IdxState::Live);
            }
            Err(e) => self.note_write_error(id, "accept", &e),
        }
    }

    /// Journal a job start (informational, not fsynced).  No-op for
    /// jobs the journal never admitted (sync heavy ops, tests).
    pub fn record_start(&self, id: &str) {
        let mut g = self.inner.lock().unwrap();
        if self.is_degraded() || g.index.get(id) != Some(&IdxState::Live) {
            return;
        }
        let payload = Json::obj(vec![("id", Json::str(id)), ("kind", Json::str("start"))]);
        if let Err(e) = append(&mut g, &payload, false) {
            self.note_write_error(id, "start", &e);
        }
    }

    /// Journal a terminal transition with its result or error.
    /// Fsynced — a result served once must survive a crash.  No-op for
    /// unadmitted jobs and for repeat transitions.
    pub fn record_terminal(
        &self,
        id: &str,
        state: &str,
        result: Option<&Json>,
        error: Option<&str>,
    ) {
        let mut g = self.inner.lock().unwrap();
        if self.is_degraded() || g.index.get(id) != Some(&IdxState::Live) {
            return;
        }
        let mut fields = vec![
            ("id", Json::str(id)),
            ("kind", Json::str("terminal")),
            ("state", Json::str(state)),
        ];
        if let Some(r) = result {
            fields.push(("result", r.clone()));
        }
        if let Some(e) = error {
            fields.push(("error", Json::str(e)));
        }
        match append(&mut g, &Json::obj(fields), true) {
            Ok(()) => {
                g.index.insert(id.to_string(), IdxState::Terminal);
            }
            Err(e) => {
                self.note_write_error(id, "terminal", &e);
                return;
            }
        }
        self.maybe_compact(&mut g);
    }

    /// Journal a cancellation (a terminal marker; written but not
    /// fsynced — a cancel lost to a crash re-runs the job, which is
    /// safe).  No-op for unadmitted jobs and repeat transitions.
    pub fn record_cancel(&self, id: &str) {
        let mut g = self.inner.lock().unwrap();
        if self.is_degraded() || g.index.get(id) != Some(&IdxState::Live) {
            return;
        }
        let payload = Json::obj(vec![("id", Json::str(id)), ("kind", Json::str("cancel"))]);
        match append(&mut g, &payload, false) {
            Ok(()) => {
                g.index.insert(id.to_string(), IdxState::Terminal);
            }
            Err(e) => {
                self.note_write_error(id, "cancel", &e);
                return;
            }
        }
        self.maybe_compact(&mut g);
    }

    /// True while the journal is in degraded (memory-only) mode after
    /// a write failure: no records are being written, and jobs
    /// admitted in this window will not survive a crash.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Total append/fsync failures observed so far.
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    fn note_write_error(&self, id: &str, what: &str, e: &io::Error) {
        self.write_errors.fetch_add(1, Ordering::Relaxed);
        if !self.degraded.swap(true, Ordering::Relaxed) {
            eprintln!(
                "journal: failed to record {what} of {id}: {e} — \
                 entering degraded (memory-only) mode"
            );
        } else {
            eprintln!("journal: failed to record {what} of {id}: {e}");
        }
    }

    /// Try to leave degraded mode: roll the file back to the last good
    /// frame boundary (a failed append may have left partial bytes)
    /// and fsync a no-op probe record through the normal append path.
    /// Returns `true` when the journal is healthy afterwards.  Called
    /// periodically by the coordinator's prober thread; safe (and
    /// cheap) to call while healthy.
    pub fn probe_reattach(&self) -> bool {
        if !self.is_degraded() {
            return true;
        }
        let mut g = self.inner.lock().unwrap();
        let boundary = g.bytes;
        let rolled = g
            .file
            .set_len(boundary)
            .and_then(|()| g.file.seek(SeekFrom::End(0)))
            .is_ok();
        if !rolled {
            return false;
        }
        // Probe records carry no id: replay and compaction both skip
        // them, so they are pure padding.
        let probe = Json::obj(vec![("kind", Json::str("probe"))]);
        if append(&mut g, &probe, true).is_err() {
            return false;
        }
        self.degraded.store(false, Ordering::Relaxed);
        eprintln!(
            "journal: reattached, leaving degraded mode ({} write errors so far)",
            self.write_errors()
        );
        true
    }

    /// Drop a job from the replay index (registry eviction).  Index
    /// only, no file IO — safe to call under the registry lock; the
    /// job's records become garbage the next compaction drops.
    pub fn forget(&self, id: &str) {
        self.inner.lock().unwrap().index.remove(id);
    }

    /// Rewrite the journal down to its replay-relevant records (accept
    /// for every indexed job, the terminal marker for finished ones)
    /// and atomically swap it in.  Also triggered automatically once
    /// obsolete records dominate.
    pub fn compact(&self) -> io::Result<()> {
        let mut g = self.inner.lock().unwrap();
        compact(&self.path, &mut g)
    }

    /// Durability statistics for the `persist` op.
    pub fn stats(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let live = g.index.values().filter(|s| **s == IdxState::Live).count();
        let terminal = g.index.len() - live;
        Json::obj(vec![
            ("bytes", Json::num(g.bytes as f64)),
            ("compactions", Json::num(g.compactions as f64)),
            ("degraded", Json::Bool(self.is_degraded())),
            ("enabled", Json::Bool(true)),
            ("live", Json::num(live as f64)),
            ("path", Json::str(self.path.display().to_string())),
            ("records", Json::num(g.records as f64)),
            ("terminal", Json::num(terminal as f64)),
            ("version", Json::num(f64::from(JOURNAL_VERSION))),
            ("write_errors", Json::num(self.write_errors() as f64)),
        ])
    }

    /// Compact when the file is non-trivial and at least half its
    /// records are obsolete (starts, forgotten jobs, duplicates).  A
    /// replay needs 1 record per live job and 2 per terminal one.
    fn maybe_compact(&self, g: &mut Inner) {
        if g.records < COMPACT_MIN {
            return;
        }
        let useful: u64 = g
            .index
            .values()
            .map(|s| match s {
                IdxState::Live => 1,
                IdxState::Terminal => 2,
            })
            .sum();
        if useful * 2 > g.records {
            return;
        }
        if let Err(e) = compact(&self.path, g) {
            eprintln!("journal: compaction failed: {e}");
        }
    }
}

/// Frame and append one record; optionally fsync.  Chaos-instrumented:
/// `journal.append` can fail the write outright or tear it (write only
/// the first `n` frame bytes, as a crash mid-`write(2)` would), and
/// `journal.fsync` fails the durability barrier after a clean write.
fn append(g: &mut Inner, payload: &Json, fsync: bool) -> io::Result<()> {
    let text = payload.to_string();
    let frame = frame(text.as_bytes());
    match failpoint::apply("journal.append") {
        Some(failpoint::FailAction::TornWrite(n)) => {
            // Persist the torn prefix so replay sees exactly what a
            // real torn append leaves behind.
            let n = n.min(frame.len());
            let _ = g.file.write_all(&frame[..n]).and_then(|()| g.file.sync_data());
            return Err(failpoint::injected("journal.append"));
        }
        Some(_) => return Err(failpoint::injected("journal.append")),
        None => {}
    }
    g.file.write_all(&frame)?;
    if fsync {
        failpoint::io_error("journal.fsync")?;
        g.file.sync_data()?;
    }
    g.records += 1;
    g.bytes += frame.len() as u64;
    Ok(())
}

fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_LEN + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Walk the framed records after the header; stops at the first
/// truncated, oversized, checksum-failing or unparsable frame (the
/// torn tail a crash mid-append leaves).  Returns the parsed payloads
/// and the byte offset of the last good frame's end.
fn scan(raw: &[u8]) -> (Vec<Json>, usize) {
    let mut out = Vec::new();
    let mut pos = HEADER_LEN;
    while raw.len() >= pos + FRAME_LEN {
        let len = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(raw[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_PAYLOAD || raw.len() < pos + FRAME_LEN + len {
            break;
        }
        let payload = &raw[pos + FRAME_LEN..pos + FRAME_LEN + len];
        if fnv1a(payload) != sum {
            break;
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(j) = Json::parse(text) else { break };
        out.push(j);
        pos += FRAME_LEN + len;
    }
    (out, pos)
}

/// Fold the record stream into recovered jobs (accept order) and the
/// replay index.  Later records win only where the lifecycle allows:
/// the first accept per id sticks, the first terminal/cancel marker
/// sticks (transitions are once-guarded at write time), starts are
/// informational.
fn replay(payloads: &[Json]) -> (Vec<RecoveredJob>, HashMap<String, IdxState>) {
    let mut order: Vec<String> = Vec::new();
    let mut jobs: HashMap<String, RecoveredJob> = HashMap::new();
    for p in payloads {
        let (Some(kind), Some(id)) = (
            p.get("kind").and_then(Json::as_str),
            p.get("id").and_then(Json::as_str),
        ) else {
            continue;
        };
        match kind {
            "accept" => {
                if jobs.contains_key(id) {
                    continue;
                }
                let placement = p.get("placement").cloned().unwrap_or_else(|| Json::obj(vec![]));
                let priority = config::job_priority_from_json(&placement).unwrap_or_default();
                jobs.insert(
                    id.to_string(),
                    RecoveredJob {
                        id: id.to_string(),
                        op: p.get("op").and_then(Json::as_str).unwrap_or("?").to_string(),
                        line: p.get("line").and_then(Json::as_str).unwrap_or("").to_string(),
                        priority,
                        terminal: None,
                    },
                );
                order.push(id.to_string());
            }
            "terminal" => {
                if let Some(job) = jobs.get_mut(id) {
                    if job.terminal.is_none() {
                        job.terminal = Some(RecoveredTerminal {
                            state: p
                                .get("state")
                                .and_then(Json::as_str)
                                .unwrap_or("failed")
                                .to_string(),
                            result: p.get("result").cloned(),
                            error: p.get("error").and_then(Json::as_str).map(str::to_string),
                        });
                    }
                }
            }
            "cancel" => {
                if let Some(job) = jobs.get_mut(id) {
                    if job.terminal.is_none() {
                        job.terminal = Some(RecoveredTerminal {
                            state: "cancelled".to_string(),
                            result: None,
                            error: None,
                        });
                    }
                }
            }
            // Starts (and unknown future kinds) carry no replay state.
            _ => {}
        }
    }
    let index = jobs
        .iter()
        .map(|(id, j)| {
            (
                id.clone(),
                if j.terminal.is_some() { IdxState::Terminal } else { IdxState::Live },
            )
        })
        .collect();
    let recovered = order.into_iter().filter_map(|id| jobs.remove(&id)).collect();
    (recovered, index)
}

/// Rewrite-and-swap: scan the current file, keep only replay-relevant
/// records (in their original order), write them to `<path>.tmp`,
/// fsync, atomically rename over the journal and reopen the append
/// handle.  Runs under the journal mutex.
fn compact(path: &Path, g: &mut Inner) -> io::Result<()> {
    g.file.sync_data()?;
    g.file.seek(SeekFrom::Start(0))?;
    let mut raw = Vec::new();
    g.file.read_to_end(&mut raw)?;
    let (payloads, _) = scan(&raw);
    let tmp_path = path.with_file_name(match path.file_name().and_then(|n| n.to_str()) {
        Some(name) => format!("{name}.tmp"),
        None => "journal.tmp".to_string(),
    });
    let mut tmp = File::create(&tmp_path)?;
    tmp.write_all(&MAGIC)?;
    tmp.write_all(&JOURNAL_VERSION.to_le_bytes())?;
    let mut kept = 0u64;
    let mut bytes = HEADER_LEN as u64;
    let mut seen_accept: HashSet<String> = HashSet::new();
    let mut seen_terminal: HashSet<String> = HashSet::new();
    for p in &payloads {
        let (Some(kind), Some(id)) = (
            p.get("kind").and_then(Json::as_str),
            p.get("id").and_then(Json::as_str),
        ) else {
            continue;
        };
        let keep = match kind {
            "accept" => g.index.contains_key(id) && seen_accept.insert(id.to_string()),
            "terminal" | "cancel" => {
                g.index.get(id) == Some(&IdxState::Terminal)
                    && seen_terminal.insert(id.to_string())
            }
            _ => false,
        };
        if !keep {
            continue;
        }
        let framed = frame(p.to_string().as_bytes());
        tmp.write_all(&framed)?;
        kept += 1;
        bytes += framed.len() as u64;
    }
    tmp.sync_data()?;
    drop(tmp);
    std::fs::rename(&tmp_path, path)?;
    let mut file = OpenOptions::new().read(true).write(true).open(path)?;
    file.seek(SeekFrom::End(0))?;
    g.file = file;
    g.records = kept;
    g.bytes = bytes;
    g.compactions += 1;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fresh per-test journal path (removed before use so reruns
    /// never see a previous process's file).
    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("botsched-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    #[test]
    fn accept_and_terminal_records_survive_reopen() {
        let path = tmp("roundtrip.journal");
        {
            let (j, recovered) = Journal::open(&path).unwrap();
            assert!(recovered.is_empty());
            j.admit("j-0", "plan", r#"{"budget":80,"op":"plan"}"#, JobPriority::new(3));
            j.admit("j-1", "sweep", r#"{"op":"sweep"}"#, JobPriority::default());
            j.record_start("j-0");
            j.record_terminal("j-0", "done", Some(&Json::num(42.0)), None);
        }
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 2);
        assert_eq!(recovered[0].id, "j-0");
        assert_eq!(recovered[0].priority.priority, 3);
        let t = recovered[0].terminal.as_ref().unwrap();
        assert_eq!(t.state, "done");
        assert_eq!(t.result, Some(Json::num(42.0)));
        assert_eq!(recovered[1].id, "j-1");
        assert_eq!(recovered[1].op, "sweep");
        assert_eq!(recovered[1].line, r#"{"op":"sweep"}"#);
        assert!(recovered[1].terminal.is_none(), "unfinished job replays live");
    }

    #[test]
    fn unadmitted_ids_are_never_journaled() {
        let path = tmp("unadmitted.journal");
        {
            let (j, _) = Journal::open(&path).unwrap();
            // Sync heavy ops call start/terminal without an admit.
            j.record_start("j-7");
            j.record_terminal("j-7", "done", Some(&Json::Bool(true)), None);
            j.record_cancel("j-8");
        }
        let (j, recovered) = Journal::open(&path).unwrap();
        assert!(recovered.is_empty());
        assert_eq!(j.stats().get("records").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn torn_tail_is_tolerated_and_truncated() {
        let path = tmp("torn.journal");
        {
            let (j, _) = Journal::open(&path).unwrap();
            j.admit("j-0", "plan", r#"{"budget":80,"op":"plan"}"#, JobPriority::default());
            j.admit("j-1", "plan", r#"{"budget":90,"op":"plan"}"#, JobPriority::default());
        }
        // A crash mid-append: frame claims 64 bytes, only 3 follow.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&64u32.to_le_bytes()).unwrap();
            f.write_all(&[1, 2, 3]).unwrap();
        }
        let len_before = std::fs::metadata(&path).unwrap().len();
        let (j, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 2, "good records survive the torn tail");
        assert!(std::fs::metadata(&path).unwrap().len() < len_before, "tail truncated");
        // The truncated journal appends cleanly.
        j.admit("j-2", "plan", r#"{"budget":10,"op":"plan"}"#, JobPriority::default());
        drop(j);
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 3);
        assert_eq!(recovered[2].id, "j-2");
    }

    #[test]
    fn checksum_failure_drops_the_tail_record() {
        let path = tmp("checksum.journal");
        {
            let (j, _) = Journal::open(&path).unwrap();
            j.admit("j-0", "plan", r#"{"budget":80,"op":"plan"}"#, JobPriority::default());
            j.admit("j-1", "plan", r#"{"budget":90,"op":"plan"}"#, JobPriority::default());
        }
        // Flip the last payload byte: the second record's checksum fails.
        let mut raw = std::fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff;
        std::fs::write(&path, &raw).unwrap();
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].id, "j-0");
    }

    #[test]
    fn foreign_magic_or_version_is_refused() {
        let path = tmp("foreign.journal");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(Journal::open(&path).is_err());
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &raw).unwrap();
        let err = Journal::open(&path).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn cancel_without_terminal_replays_as_cancelled() {
        let path = tmp("cancel.journal");
        {
            let (j, _) = Journal::open(&path).unwrap();
            j.admit("j-0", "campaign", r#"{"budget":80,"op":"campaign"}"#, JobPriority::default());
            j.record_start("j-0");
            j.record_cancel("j-0");
            // A late terminal after the cancel marker must not win.
            j.record_terminal("j-0", "done", Some(&Json::Bool(true)), None);
        }
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 1);
        let t = recovered[0].terminal.as_ref().unwrap();
        assert_eq!(t.state, "cancelled");
        assert!(t.result.is_none());
    }

    #[test]
    fn compaction_preserves_replay_equivalence() {
        let path = tmp("compact.journal");
        let (j, _) = Journal::open(&path).unwrap();
        for i in 0..10 {
            let id = format!("j-{i}");
            j.admit(&id, "plan", &format!(r#"{{"budget":{i},"op":"plan"}}"#), JobPriority::new(2));
            j.record_start(&id);
            if i < 8 {
                j.record_terminal(&id, "done", Some(&Json::num(i as f64)), None);
            }
        }
        let before = j.stats().get("records").unwrap().as_u64().unwrap();
        j.compact().unwrap();
        let after = j.stats().get("records").unwrap().as_u64().unwrap();
        // 10 accepts + 8 terminals survive; 10 starts are dropped.
        assert_eq!(after, 18);
        assert!(after < before, "{after} < {before}");
        assert_eq!(j.stats().get("compactions").unwrap().as_u64(), Some(1));
        drop(j);
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 10);
        for (i, job) in recovered.iter().enumerate() {
            assert_eq!(job.id, format!("j-{i}"), "accept order preserved");
            assert_eq!(job.priority.priority, 2);
            if i < 8 {
                let t = job.terminal.as_ref().unwrap();
                assert_eq!(t.state, "done");
                assert_eq!(t.result, Some(Json::num(i as f64)));
            } else {
                assert!(job.terminal.is_none());
            }
        }
    }

    #[test]
    fn forget_drops_the_job_at_the_next_compaction() {
        let path = tmp("forget.journal");
        let (j, _) = Journal::open(&path).unwrap();
        j.admit("j-0", "plan", r#"{"budget":80,"op":"plan"}"#, JobPriority::default());
        j.record_terminal("j-0", "done", Some(&Json::Bool(true)), None);
        j.admit("j-1", "plan", r#"{"budget":90,"op":"plan"}"#, JobPriority::default());
        j.forget("j-0");
        j.compact().unwrap();
        drop(j);
        let (_, recovered) = Journal::open(&path).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].id, "j-1");
    }

    #[test]
    fn auto_compaction_fires_once_obsolete_records_dominate() {
        let path = tmp("auto.journal");
        let (j, _) = Journal::open(&path).unwrap();
        for i in 0..100 {
            let id = format!("j-{i}");
            j.admit(&id, "plan", r#"{"budget":1,"op":"plan"}"#, JobPriority::default());
            j.record_terminal(&id, "done", Some(&Json::Bool(true)), None);
        }
        // Evictions shrink the index; the next terminal transition
        // notices the garbage ratio and compacts automatically.
        for i in 0..90 {
            j.forget(&format!("j-{i}"));
        }
        j.admit("j-100", "plan", r#"{"budget":1,"op":"plan"}"#, JobPriority::default());
        j.record_terminal("j-100", "done", Some(&Json::Bool(true)), None);
        let stats = j.stats();
        assert!(stats.get("compactions").unwrap().as_u64().unwrap() >= 1, "{stats}");
        // 11 jobs remain, each accept + terminal.
        assert_eq!(stats.get("records").unwrap().as_u64(), Some(22));
        assert_eq!(stats.get("terminal").unwrap().as_u64(), Some(11));
        assert_eq!(stats.get("live").unwrap().as_u64(), Some(0));
    }
}
