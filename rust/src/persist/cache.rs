//! Content-addressed solve cache: a bounded LRU map from canonical
//! request keys to [`SolveOutcome`]s.
//!
//! Keys are canonical strings built by the API layer (see
//! `PlanRequest::cache_key`): sorted-field JSON over the system
//! target and the normalised solve parameters, with outcome-irrelevant
//! knobs (`threads`, `detail`) stripped and [`CACHE_VERSION`] baked
//! in.  The cache stores the full key alongside each entry and
//! compares it on lookup, so an FNV hash collision degrades to a miss
//! rather than serving the wrong plan.
//!
//! Hit/miss/insert/evict accounting lives with the caller (the
//! coordinator's metrics), keeping this module dependency-free.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use crate::scheduler::SolveOutcome;
use crate::util::failpoint;

use super::fnv1a;

/// Baked into every cache key.  Bump when the key schema, the solver,
/// or the [`SolveOutcome`] shape changes in a way that makes old
/// entries wrong — all prior keys then self-invalidate.
pub const CACHE_VERSION: u32 = 1;

#[derive(Debug, Clone)]
struct Entry {
    /// Full canonical key, compared on lookup (collision safety).
    key: String,
    outcome: SolveOutcome,
}

#[derive(Debug, Default)]
struct CacheInner {
    map: HashMap<u64, Entry>,
    /// Recency order, least-recently-used at the front.  Touch is
    /// O(capacity) — fine for the operator-bounded capacities this
    /// cache is configured with (`--cache-capacity`).
    order: VecDeque<u64>,
}

/// A bounded LRU solve cache.  Capacity 0 disables it: every lookup
/// misses and every insert is a no-op.
#[derive(Debug)]
pub struct SolveCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
}

impl SolveCache {
    pub fn new(capacity: usize) -> Self {
        Self { capacity, inner: Mutex::new(CacheInner::default()) }
    }

    /// Look up a canonical key; a hit clones the outcome and promotes
    /// the entry to most-recently-used.
    pub fn get(&self, key: &str) -> Option<SolveOutcome> {
        if self.capacity == 0 {
            return None;
        }
        let h = fnv1a(key.as_bytes());
        let mut g = self.inner.lock().unwrap();
        let entry = g.map.get(&h)?;
        if entry.key != key {
            return None;
        }
        let outcome = entry.outcome.clone();
        if let Some(pos) = g.order.iter().position(|x| *x == h) {
            g.order.remove(pos);
        }
        g.order.push_back(h);
        Some(outcome)
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used
    /// one if the cache is full.  Returns whether an eviction happened.
    /// The `cache.insert` failpoint drops the insert (the outcome is
    /// still served, only never cached) — caching must stay an
    /// optimisation, never a correctness dependency.
    pub fn insert(&self, key: String, outcome: SolveOutcome) -> bool {
        if self.capacity == 0 || failpoint::apply("cache.insert").is_some() {
            return false;
        }
        let h = fnv1a(key.as_bytes());
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&h) {
            g.map.insert(h, Entry { key, outcome });
            if let Some(pos) = g.order.iter().position(|x| *x == h) {
                g.order.remove(pos);
            }
            g.order.push_back(h);
            return false;
        }
        let mut evicted = false;
        if g.map.len() >= self.capacity {
            if let Some(old) = g.order.pop_front() {
                g.map.remove(&old);
                evicted = true;
            }
        }
        g.map.insert(h, Entry { key, outcome });
        g.order.push_back(h);
        evicted
    }

    /// (capacity, current entry count).
    pub fn stats(&self) -> (usize, usize) {
        (self.capacity, self.inner.lock().unwrap().map.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Plan, PlanScore};

    fn outcome(tag: f64) -> SolveOutcome {
        SolveOutcome {
            policy: "test",
            plan: Plan::new(),
            score: PlanScore { makespan: tag, cost: tag * 2.0 },
            feasible: true,
            iterations: 3,
            probes: 1,
            effective_budget: tag,
        }
    }

    #[test]
    fn hit_returns_the_exact_stored_bits() {
        let c = SolveCache::new(4);
        assert!(c.get("k1").is_none());
        c.insert("k1".to_string(), outcome(12.5));
        let hit = c.get("k1").expect("hit");
        assert_eq!(hit.score.makespan.to_bits(), 12.5f64.to_bits());
        assert_eq!(hit.score.cost.to_bits(), 25.0f64.to_bits());
        assert_eq!(hit.effective_budget.to_bits(), 12.5f64.to_bits());
        assert_eq!(hit.policy, "test");
        assert!(c.get("k2").is_none(), "different key misses");
    }

    #[test]
    fn lru_eviction_order_is_pinned() {
        let c = SolveCache::new(2);
        assert!(!c.insert("a".to_string(), outcome(1.0)));
        assert!(!c.insert("b".to_string(), outcome(2.0)));
        // Touch "a": "b" becomes least recently used.
        assert!(c.get("a").is_some());
        assert!(c.insert("c".to_string(), outcome(3.0)), "full cache evicts");
        assert!(c.get("b").is_none(), "LRU entry evicted");
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        assert_eq!(c.stats(), (2, 2));
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let c = SolveCache::new(2);
        c.insert("a".to_string(), outcome(1.0));
        c.insert("b".to_string(), outcome(2.0));
        assert!(!c.insert("a".to_string(), outcome(9.0)), "refresh, not eviction");
        assert_eq!(c.get("a").unwrap().score.makespan, 9.0);
        // The refresh promoted "a", so "b" is now the LRU victim.
        c.insert("c".to_string(), outcome(3.0));
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_some());
    }

    #[test]
    fn capacity_zero_disables_cleanly() {
        let c = SolveCache::new(0);
        assert!(!c.insert("a".to_string(), outcome(1.0)));
        assert!(c.get("a").is_none());
        assert_eq!(c.stats(), (0, 0));
    }
}
