//! Durability layer for the coordinator: a crash-recoverable job
//! journal plus a content-addressed solve cache.  Both halves are
//! dependency-free (std only) and optional — a coordinator started
//! without `--journal` / `--cache-capacity` behaves exactly as before.
//!
//! # Durability model
//!
//! The [`journal::Journal`] is an append-only file of length-prefixed,
//! FNV-1a-checksummed records, one per job lifecycle event:
//!
//! * **accept** — the job's id, op, full request line and queue
//!   placement.  Written *and fsynced* before the job becomes visible
//!   to any pool worker, so an id handed to a client is durable by the
//!   time the client sees it.
//! * **start** — informational (written, not fsynced).  A job with a
//!   start but no terminal record re-runs after a crash.
//! * **terminal** — the job's final state plus its result or error.
//!   Written *and fsynced*, so a result served once survives a crash
//!   and is re-served byte-identically after recovery.
//! * **cancel** — a terminal marker for cancelled jobs (written, not
//!   fsynced: a cancel lost to a machine crash re-runs the job, which
//!   is safe — the client already gave up on it).
//!
//! What survives a crash (power loss included): every accepted job's
//! admission and every Done/Failed job's outcome.  What may be lost:
//! start markers, cancels, and progress/partial-result streams (which
//! are never journaled).  On restart the coordinator replays the
//! journal before accepting traffic: terminal jobs re-enter the
//! registry with their recovered result (servable from `status`);
//! accepted-but-unfinished jobs re-enqueue under their original ids
//! and execute again.  Relative `deadline_ms` placements restart from
//! recovery time — the original submission instant did not survive.
//!
//! Replay tolerates a torn tail: the first truncated or
//! checksum-failing record ends the scan and the tail is truncated
//! away, so a crash mid-append never poisons the log.  Once terminal
//! and forgotten records dominate, the journal compacts by
//! rewrite-and-swap (atomic rename), bounding its size against the
//! live job set.
//!
//! The [`cache::SolveCache`] memoises `plan` solves by a canonical
//! content hash of (system/scenario target, normalised solve
//! parameters).  Outcome-irrelevant knobs (`threads`, `detail`) are
//! excluded from the key; `seed` is included because it changes the
//! solution.  [`CACHE_VERSION`] is baked into every key, so a format
//! or solver change that bumps it self-invalidates all prior entries.
//! The cache is bounded (`--cache-capacity`, LRU eviction) and may
//! serve an entry computed arbitrarily long ago — safe here because
//! solves are pure functions of the request, but a policy whose
//! results depend on ambient state must not be cached without bumping
//! the version.

pub mod cache;
pub mod journal;

pub use cache::{SolveCache, CACHE_VERSION};
pub use journal::{Journal, RecoveredJob, RecoveredTerminal, JOURNAL_VERSION};

/// FNV-1a over `bytes` — the same constants as the engine's shard
/// hash; dependency-free and stable across platforms and releases
/// (journal checksums and cache keys must not drift between builds).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_the_published_vectors() {
        // Classic FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85dd_35c9_cd7b_a406);
    }
}
