//! JSON (de)serialisation of the public configuration surface: problem
//! systems, planner settings and noise models.
//!
//! Used by the CLI (`--system file.json`), the coordinator wire protocol
//! and the report files.  The schema mirrors the model types 1:1:
//!
//! ```json
//! {
//!   "overhead": 30.0,
//!   "hour": 3600.0,
//!   "billing": "hourly",
//!   "apps": [
//!     {"name": "A1", "task_sizes": [1, 1, 2, 3]},
//!     {"name": "A2", "tasks": 250, "sizes_equally_spaced": [1, 5]}
//!   ],
//!   "instance_types": [
//!     {"name": "it1", "cost_per_hour": 5.0, "perf": [20.0, 24.0]}
//!   ]
//! }
//! ```

use anyhow::{anyhow, bail, Context, Result};

use crate::cloudsim::NoiseModel;
use crate::coordinator::JobPriority;
use crate::model::{BillingPolicy, System, SystemBuilder};
use crate::scheduler::{PlannerConfig, SolveRequest};
use crate::util::Json;

/// Parse a request's queue placement: `priority` (0..=9, default 0;
/// 9 = most urgent) and an optional `deadline_ms` *relative to
/// submission*.  Both fields are strict: present-but-mistyped or
/// out-of-range values are errors, never silent defaults.  Requests
/// carrying neither field get the all-defaults placement, which the
/// engine schedules in plain FIFO order — exactly the legacy behaviour.
///
/// Thin wrapper over [`crate::coordinator::api::Placement`] — the typed
/// API owns the field rules; this keeps the historical entry point.
pub fn job_priority_from_json(j: &Json) -> Result<JobPriority> {
    Ok(crate::coordinator::api::Placement::decode(j)
        .map_err(|e| anyhow!("{}", e.message))?
        .job_priority())
}

/// The inverse of [`job_priority_from_json`]: encode a placement into
/// the same JSON shape it parses.  The journal's `accept` records use
/// this so replayed jobs re-enqueue under their original placement.
pub fn job_priority_to_json(p: &JobPriority) -> Json {
    let mut fields = vec![("priority", Json::num(f64::from(p.priority)))];
    if let Some(ms) = p.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    Json::obj(fields)
}

/// Parse a [`System`] from its JSON description.
pub fn system_from_json(j: &Json) -> Result<System> {
    let mut b = SystemBuilder::new();
    if let Some(o) = j.get("overhead").and_then(Json::as_f64) {
        b = b.overhead(o);
    }
    if let Some(h) = j.get("hour").and_then(Json::as_f64) {
        b = b.hour(h);
    }
    if let Some(bill) = j.get("billing").and_then(Json::as_str) {
        b = b.billing(match bill {
            "hourly" => BillingPolicy::HourlyCeil,
            "per_second" => BillingPolicy::PerSecond,
            other => bail!("unknown billing policy {other:?}"),
        });
    }
    let apps = j
        .get("apps")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("system.apps missing"))?;
    for (i, app) in apps.iter().enumerate() {
        let name = app
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("app{i}"));
        let sizes: Vec<f64> = if let Some(arr) = app.get("task_sizes").and_then(Json::as_arr) {
            arr.iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric task size")))
                .collect::<Result<_>>()?
        } else if let (Some(n), Some(range)) = (
            app.get("tasks").and_then(Json::as_u64),
            app.get("sizes_equally_spaced").and_then(Json::as_arr),
        ) {
            let lo = range
                .first()
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("bad sizes_equally_spaced"))? as i64;
            let hi = range
                .get(1)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("bad sizes_equally_spaced"))? as i64;
            if hi < lo {
                bail!("sizes_equally_spaced range inverted");
            }
            let span = (hi - lo + 1) as u64;
            (0..n).map(|k| (lo + (k % span) as i64) as f64).collect()
        } else {
            bail!("app {name}: need task_sizes or tasks+sizes_equally_spaced");
        };
        b = b.app(&name, sizes);
    }
    let its = j
        .get("instance_types")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("system.instance_types missing"))?;
    for (i, it) in its.iter().enumerate() {
        let name = it
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("it{i}"));
        let cost = it
            .get("cost_per_hour")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("instance type {name}: cost_per_hour missing"))?;
        let perf: Vec<f64> = it
            .get("perf")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("instance type {name}: perf missing"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric perf")))
            .collect::<Result<_>>()?;
        b = b.instance_type(&name, cost, perf);
    }
    b.build().map_err(|e| anyhow!("invalid system: {e}"))
}

/// Serialise a [`System`] (inverse of [`system_from_json`]).
pub fn system_to_json(sys: &System) -> Json {
    Json::obj(vec![
        ("overhead", Json::num(sys.overhead)),
        ("hour", Json::num(sys.hour)),
        (
            "billing",
            Json::str(match sys.billing {
                BillingPolicy::HourlyCeil => "hourly",
                BillingPolicy::PerSecond => "per_second",
            }),
        ),
        (
            "apps",
            Json::arr(sys.apps.iter().map(|a| {
                Json::obj(vec![
                    ("name", Json::str(&a.name)),
                    ("task_sizes", Json::arr(a.task_sizes.iter().map(|s| Json::num(*s)))),
                ])
            })),
        ),
        (
            "instance_types",
            Json::arr(sys.instance_types.iter().map(|it| {
                Json::obj(vec![
                    ("name", Json::str(&it.name)),
                    ("cost_per_hour", Json::num(it.cost_per_hour)),
                    ("perf", Json::arr(sys.perf.row(it.id).iter().map(|p| Json::num(*p)))),
                ])
            })),
        ),
    ])
}

/// Serialise a full execution plan (per-VM instance type + task ids).
pub fn plan_to_json(sys: &System, plan: &crate::model::Plan) -> Json {
    Json::obj(vec![(
        "vms",
        Json::arr(plan.vms.iter().map(|vm| {
            Json::obj(vec![
                ("instance_type", Json::str(&sys.instance_type(vm.it).name)),
                ("instance_type_id", Json::num(vm.it.0 as f64)),
                (
                    "tasks",
                    Json::arr(vm.tasks().iter().map(|t| Json::num(t.0 as f64))),
                ),
            ])
        })),
    )])
}

/// Rebuild a plan from its JSON form (inverse of [`plan_to_json`]).
pub fn plan_from_json(sys: &System, j: &Json) -> Result<crate::model::Plan> {
    let mut plan = crate::model::Plan::new();
    let vms = j
        .get("vms")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("plan: missing vms[]"))?;
    for (i, vm) in vms.iter().enumerate() {
        let it = vm
            .get("instance_type_id")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("plan vm {i}: missing instance_type_id"))?;
        if it as usize >= sys.n_types() {
            bail!("plan vm {i}: unknown instance type {it}");
        }
        let idx = plan.add_vm(sys, crate::model::InstanceTypeId(it as u16));
        for t in vm
            .get("tasks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan vm {i}: missing tasks[]"))?
        {
            let tid = t.as_u64().ok_or_else(|| anyhow!("plan vm {i}: bad task id"))?;
            if tid as usize >= sys.tasks().len() {
                bail!("plan vm {i}: unknown task {tid}");
            }
            plan.vms[idx].push_task(sys, crate::model::TaskId(tid as u32));
        }
    }
    Ok(plan)
}

/// Load a system from a JSON file, or the paper's Table I setup for the
/// reserved name `"paper"` (optionally `"paper:<overhead>"`).
pub fn load_system(spec: &str) -> Result<System> {
    if spec == "paper" {
        return Ok(crate::workload::paper::table1_system(0.0));
    }
    if let Some(o) = spec.strip_prefix("paper:") {
        let o: f64 = o.parse().context("overhead in paper:<overhead>")?;
        return Ok(crate::workload::paper::table1_system(o));
    }
    let text =
        std::fs::read_to_string(spec).with_context(|| format!("reading system file {spec}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {spec}"))?;
    system_from_json(&j)
}

/// Parse a [`PlannerConfig`] from JSON (all fields optional).  Thin
/// wrapper over [`crate::coordinator::api::PlannerOverrides`].
pub fn planner_config_from_json(j: &Json) -> Result<PlannerConfig> {
    Ok(crate::coordinator::api::PlannerOverrides::decode(j).to_config())
}

/// Parse a [`SolveRequest`] from JSON: `budget` (required) plus the
/// optional policy knobs `deadline`, `seed`, `n_starts`, `perf_jitter`,
/// `sample_frac`, `threads` (worker threads for parallelisable
/// policies; 0 = auto, bounded at 256), `remaining` (residual task ids
/// for `"dynamic"` re-planning) and a nested `planner` config.  The
/// evaluator handle is attached by the caller
/// ([`SolveRequest::with_evaluator`]).
///
/// Thin wrapper over [`crate::coordinator::api::SolveParams`] — the
/// typed API owns the field rules (strictness, bounds, error strings);
/// this keeps the historical entry point for file-driven callers.
pub fn solve_request_from_json(j: &Json) -> Result<SolveRequest<'static>> {
    Ok(crate::coordinator::api::SolveParams::decode(j)
        .map_err(|e| anyhow!("{}", e.message))?
        .solve_request())
}

/// Parse a [`NoiseModel`] from JSON (all fields optional, default
/// none).  Thin wrapper over [`crate::coordinator::api::NoiseSpec`].
pub fn noise_from_json(j: &Json) -> NoiseModel {
    crate::coordinator::api::NoiseSpec::decode(j).model()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_priority_parses_and_validates() {
        let j = Json::parse(r#"{"op":"submit"}"#).unwrap();
        assert_eq!(job_priority_from_json(&j).unwrap(), JobPriority::default());
        let j = Json::parse(r#"{"priority":9,"deadline_ms":2500}"#).unwrap();
        let p = job_priority_from_json(&j).unwrap();
        assert_eq!(p.priority, 9);
        assert_eq!(p.deadline_ms, Some(2500));
        for bad in [
            r#"{"priority":10}"#,
            r#"{"priority":-1}"#,
            r#"{"priority":"urgent"}"#,
            r#"{"deadline_ms":1.5}"#,
            r#"{"deadline_ms":99999999999999}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(job_priority_from_json(&j).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn job_priority_roundtrips_through_json() {
        for p in [
            JobPriority::default(),
            JobPriority::new(7),
            JobPriority::new(3).with_deadline_ms(2500),
        ] {
            let j = job_priority_to_json(&p);
            assert_eq!(job_priority_from_json(&j).unwrap(), p, "{j}");
        }
        // Defaults encode compactly: no deadline field when none is set.
        assert_eq!(job_priority_to_json(&JobPriority::new(2)).to_string(), r#"{"priority":2}"#);
    }

    #[test]
    fn roundtrip_paper_system() {
        let sys = crate::workload::paper::table1_system(30.0);
        let j = system_to_json(&sys);
        let back = system_from_json(&j).unwrap();
        assert_eq!(back.n_apps(), 3);
        assert_eq!(back.n_types(), 4);
        assert_eq!(back.overhead, 30.0);
        assert_eq!(back.tasks().len(), 750);
        assert_eq!(back.perf.row(crate::model::InstanceTypeId(2)), sys.perf.row(crate::model::InstanceTypeId(2)));
    }

    #[test]
    fn equally_spaced_shorthand() {
        let j = Json::parse(
            r#"{"apps": [{"tasks": 10, "sizes_equally_spaced": [1, 5]}],
                "instance_types": [{"cost_per_hour": 5, "perf": [10]}]}"#,
        )
        .unwrap();
        let sys = system_from_json(&j).unwrap();
        assert_eq!(sys.tasks().len(), 10);
        assert_eq!(sys.apps[0].total_size(), 1.0 + 2.0 + 3.0 + 4.0 + 5.0 + 1.0 + 2.0 + 3.0 + 4.0 + 5.0);
    }

    #[test]
    fn bad_inputs_error() {
        assert!(system_from_json(&Json::parse(r#"{}"#).unwrap()).is_err());
        assert!(system_from_json(
            &Json::parse(r#"{"apps": [], "instance_types": []}"#).unwrap()
        )
        .is_err());
        assert!(system_from_json(
            &Json::parse(
                r#"{"billing": "weird", "apps": [{"task_sizes": [1]}],
                    "instance_types": [{"cost_per_hour": 5, "perf": [10]}]}"#
            )
            .unwrap()
        )
        .is_err());
    }

    #[test]
    fn load_system_paper_shorthand() {
        assert_eq!(load_system("paper").unwrap().overhead, 0.0);
        assert_eq!(load_system("paper:45").unwrap().overhead, 45.0);
        assert!(load_system("/does/not/exist.json").is_err());
    }

    #[test]
    fn planner_config_overrides() {
        let j = Json::parse(r#"{"max_iters": 3, "enable_split": false, "replace_k": 2}"#).unwrap();
        let cfg = planner_config_from_json(&j).unwrap();
        assert_eq!(cfg.max_iters, 3);
        assert!(!cfg.enable_split);
        assert!(cfg.enable_balance);
        assert_eq!(cfg.replace_k, 2);
    }

    #[test]
    fn plan_json_roundtrip() {
        let sys = crate::workload::paper::table1_system(0.0);
        let plan = crate::scheduler::Planner::new(&sys).find(70.0).plan;
        let j = plan_to_json(&sys, &plan);
        let back = plan_from_json(&sys, &j).unwrap();
        assert_eq!(back.n_vms(), plan.n_vms());
        assert!(back.validate_partition(&sys).is_ok());
        let (a, b) = (plan.score(&sys), back.score(&sys));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.cost, b.cost);
    }

    #[test]
    fn plan_from_json_rejects_garbage() {
        let sys = crate::workload::paper::table1_system(0.0);
        assert!(plan_from_json(&sys, &Json::parse("{}").unwrap()).is_err());
        let j = Json::parse(r#"{"vms":[{"instance_type_id":99,"tasks":[]}]}"#).unwrap();
        assert!(plan_from_json(&sys, &j).is_err());
        let j = Json::parse(r#"{"vms":[{"instance_type_id":0,"tasks":[100000]}]}"#).unwrap();
        assert!(plan_from_json(&sys, &j).is_err());
    }

    #[test]
    fn solve_request_parsing() {
        let j = Json::parse(
            r#"{"budget": 80, "deadline": 3600, "seed": 4, "n_starts": 3,
                "perf_jitter": 0.2, "sample_frac": 0.5, "threads": 4,
                "remaining": [0, 5, 9],
                "planner": {"max_iters": 7}}"#,
        )
        .unwrap();
        let req = solve_request_from_json(&j).unwrap();
        assert_eq!(req.budget, 80.0);
        assert_eq!(req.deadline, Some(3600.0));
        assert_eq!(req.seed, 4);
        assert_eq!(req.n_starts, 3);
        assert_eq!(req.perf_jitter, 0.2);
        assert_eq!(req.sample_frac, 0.5);
        assert_eq!(req.threads, 4);
        assert_eq!(
            req.remaining,
            Some(vec![
                crate::model::TaskId(0),
                crate::model::TaskId(5),
                crate::model::TaskId(9)
            ])
        );
        assert_eq!(req.planner.max_iters, 7);

        // remaining must be a non-empty array of integer ids.
        for bad in [
            r#"{"budget": 10, "remaining": "all"}"#,
            r#"{"budget": 10, "remaining": []}"#,
            r#"{"budget": 10, "remaining": [1.5]}"#,
            r#"{"budget": 10, "remaining": [-3]}"#,
            r#"{"budget": 10, "threads": "many"}"#,
            r#"{"budget": 10, "threads": 9999}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(solve_request_from_json(&j).is_err(), "{bad} must be rejected");
        }

        assert!(solve_request_from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(r#"{"budget": 10, "sample_frac": 0}"#).unwrap();
        assert!(solve_request_from_json(&bad).is_err());
        // A present-but-mistyped knob is an error, not a silent drop.
        let bad = Json::parse(r#"{"budget": 10, "deadline": "3600"}"#).unwrap();
        let msg = solve_request_from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("deadline"), "{msg}");
        let bad = Json::parse(r#"{"budget": 10, "seed": -1}"#).unwrap();
        assert!(solve_request_from_json(&bad).is_err());
    }

    #[test]
    fn noise_parsing() {
        let j = Json::parse(r#"{"task_sigma": 0.1, "mean_lifetime": 5000}"#).unwrap();
        let n = noise_from_json(&j);
        assert_eq!(n.task_sigma, 0.1);
        assert_eq!(n.mean_lifetime, Some(5000.0));
        assert_eq!(n.boot_sigma, 0.0);
    }
}
