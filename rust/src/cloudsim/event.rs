//! The simulator's event queue: a deterministic min-heap over simulated
//! time with a sequence-number tie-break (equal-time events fire in
//! insertion order, so runs are bit-reproducible).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::model::TaskId;

/// What happens at a point in simulated time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// VM finished booting and may start its first task.
    VmReady { vm: usize },
    /// VM finished executing a task.
    TaskDone { vm: usize, task: TaskId },
    /// VM suffered a failure; everything not yet finished is lost.
    VmFailed { vm: usize },
}

/// A scheduled event.
#[derive(Debug, Clone)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse to pop the earliest event;
        // lower sequence number wins ties.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::VmReady { vm: 0 });
        q.push(1.0, EventKind::VmReady { vm: 1 });
        q.push(3.0, EventKind::VmReady { vm: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn equal_times_fire_in_insertion_order() {
        let mut q = EventQueue::new();
        for vm in 0..5 {
            q.push(2.0, EventKind::VmReady { vm });
        }
        let vms: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::VmReady { vm } => vm,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(vms, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, EventKind::VmFailed { vm: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
