//! "Test runs": sample task executions to observe performance.
//!
//! Sec. III-A: "In order to acquire the performance between instance
//! types and applications, we suggest to perform some test runs."  This
//! module simulates those runs — a few tasks per (instance type,
//! application) cell, timed under the noise model — producing the
//! observations the `perf_estim` XLA artifact (and its rust-native
//! mirror) turns back into an estimated performance matrix.

use crate::model::{AppId, InstanceTypeId, System};
use crate::util::Rng;

use super::noise::NoiseModel;

/// One timed test run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub it: InstanceTypeId,
    pub app: AppId,
    pub size: f64,
    /// Observed wall-clock execution time (noisy).
    pub time: f64,
}

/// Run `per_cell` sampled tasks on every (type, app) cell.
pub fn sample_runs(
    sys: &System,
    per_cell: usize,
    noise: &NoiseModel,
    seed: u64,
) -> Vec<Observation> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(per_cell * sys.n_types() * sys.n_apps());
    for it in &sys.instance_types {
        for app in &sys.apps {
            if app.is_empty() {
                continue;
            }
            for _ in 0..per_cell {
                let size = *rng.choice(&app.task_sizes);
                let nominal = sys.perf.get(it.id, app.id) * size;
                let time = nominal * noise.task_multiplier(&mut rng);
                out.push(Observation { it: it.id, app: app.id, size, time });
            }
        }
    }
    out
}

/// Rust-native mirror of the `perf_estim` artifact: per-cell weighted
/// least squares of `time = P * size` with a prior pseudo-count.  The
/// runtime's XLA-backed implementation is differentially tested against
/// this (same formula as `python/compile/kernels/ref.py::perf_estim_ref`).
pub fn estimate_perf_native(
    sys: &System,
    obs: &[Observation],
    prior: &[f64],
    prior_weight: f64,
) -> Vec<f64> {
    let m = sys.n_apps();
    let cells = sys.n_types() * m;
    assert_eq!(prior.len(), cells);
    let mut num: Vec<f64> = prior.iter().map(|p| p * prior_weight).collect();
    let mut den: Vec<f64> = vec![prior_weight; cells];
    for o in obs {
        let c = o.it.index() * m + o.app.index();
        num[c] += o.size * o.time;
        den[c] += o.size * o.size;
    }
    num.iter().zip(&den).map(|(n, d)| if *d > 0.0 { n / d } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn noiseless_estimation_recovers_table1_exactly() {
        let sys = table1_system(0.0);
        let obs = sample_runs(&sys, 5, &NoiseModel::none(), 1);
        assert_eq!(obs.len(), 5 * 4 * 3);
        let prior = vec![0.0; 12];
        let est = estimate_perf_native(&sys, &obs, &prior, 0.0);
        for it in &sys.instance_types {
            for app in &sys.apps {
                let truth = sys.perf.get(it.id, app.id);
                let got = est[it.id.index() * 3 + app.id.index()];
                assert!((got - truth).abs() < 1e-9, "cell ({}, {}): {got} vs {truth}", it.id.0, app.id.0);
            }
        }
    }

    #[test]
    fn noisy_estimation_is_close() {
        let sys = table1_system(0.0);
        let obs = sample_runs(&sys, 40, &NoiseModel::jitter(0.05), 2);
        let prior = vec![15.0; 12];
        let est = estimate_perf_native(&sys, &obs, &prior, 1e-6);
        for it in &sys.instance_types {
            for app in &sys.apps {
                let truth = sys.perf.get(it.id, app.id);
                let got = est[it.id.index() * 3 + app.id.index()];
                assert!((got - truth).abs() / truth < 0.1, "cell off: {got} vs {truth}");
            }
        }
    }

    #[test]
    fn unsampled_cells_fall_back_to_prior() {
        let sys = table1_system(0.0);
        let prior = vec![42.0; 12];
        let est = estimate_perf_native(&sys, &[], &prior, 1.0);
        assert!(est.iter().all(|p| (*p - 42.0).abs() < 1e-12));
    }
}
