//! The discrete-event simulation engine.
//!
//! Two execution modes:
//!
//! * [`Simulator::run_plan`] — the paper's setting: every task is pinned
//!   to its VM by the execution plan and runs in assignment order;
//! * [`Simulator::run_online`] — non-clairvoyant setting: provisioned VMs
//!   pull tasks from the [`OnlineDispatcher`] as they go idle.
//!
//! Billing follows the system's `BillingPolicy`: a VM is charged from
//! time 0 (provisioning) until it finishes its last task — or until it
//! fails.  With `NoiseModel::none()` the simulated makespan/cost equal
//! the planner's analytic eq. 5-8 prediction exactly; the integration
//! tests pin that equivalence.
//!
//! The fleet state is struct-of-arrays ([`Fleet`]): per-VM scalars live
//! in parallel vectors and all pinned task queues are flattened
//! back-to-back into one `Vec<TaskId>` with per-VM cursors, so the
//! event loop — the hot path of campaign replications, which re-run the
//! simulation hundreds of times per plan — touches a handful of
//! contiguous arrays instead of a `Vec` of queue-owning structs.  The
//! event *logic* is unchanged from the historical AoS engine: the same
//! event push sequence in the same order yields bit-identical outcomes
//! (pinned by the `arena_parity` suite against a verbatim copy of the
//! old engine).

use crate::model::{billed_cost, InstanceTypeId, Plan, System, TaskId};
use crate::scheduler::nonclairvoyant::OnlineDispatcher;
use crate::util::Rng;

use super::event::{EventKind, EventQueue};
use super::noise::NoiseModel;

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub noise: NoiseModel,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { noise: NoiseModel::none(), seed: 0 }
    }
}

/// Per-VM accounting.
#[derive(Debug, Clone)]
pub struct VmStats {
    pub it: InstanceTypeId,
    /// When the VM became usable (boot complete).
    pub ready_at: f64,
    /// When the VM went idle for good (last task done, or failure).
    pub finished_at: f64,
    /// Seconds spent executing tasks.
    pub busy: f64,
    pub tasks_done: usize,
    pub failed: bool,
    pub billed: f64,
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Time the last VM went idle (== completion time when nothing
    /// stranded).
    pub makespan: f64,
    /// Total billed cost across all VMs.
    pub cost: f64,
    pub completed: Vec<TaskId>,
    /// Tasks lost to VM failures (in-flight and queued on dead VMs).
    pub stranded: Vec<TaskId>,
    pub vm_stats: Vec<VmStats>,
    pub failures: usize,
}

impl SimOutcome {
    pub fn all_done(&self) -> bool {
        self.stranded.is_empty()
    }
}

/// Struct-of-arrays fleet state: index `i` across every vector is one
/// VM.  Pinned queues are flattened into `queue`; VM `i`'s outstanding
/// tasks are `queue[q_cursor[i]..q_end[i]]` and popping advances the
/// cursor (the flattened segments never shift).
#[derive(Debug)]
struct Fleet {
    it: Vec<InstanceTypeId>,
    in_flight: Vec<Option<TaskId>>,
    ready_at: Vec<f64>,
    finished_at: Vec<f64>,
    busy: Vec<f64>,
    tasks_done: Vec<usize>,
    failed: Vec<bool>,
    /// All pinned task queues, back-to-back in VM order.
    queue: Vec<TaskId>,
    q_cursor: Vec<usize>,
    q_end: Vec<usize>,
}

impl Fleet {
    fn from_plan(plan: &Plan) -> Self {
        let n = plan.n_vms();
        let mut fleet = Self::with_capacity(n, plan.n_assigned());
        for vm in &plan.vms {
            fleet.push_vm(vm.it, vm.tasks());
        }
        fleet
    }

    fn from_types(types: &[InstanceTypeId]) -> Self {
        let mut fleet = Self::with_capacity(types.len(), 0);
        for &it in types {
            fleet.push_vm(it, &[]);
        }
        fleet
    }

    fn with_capacity(n_vms: usize, n_tasks: usize) -> Self {
        Self {
            it: Vec::with_capacity(n_vms),
            in_flight: Vec::with_capacity(n_vms),
            ready_at: Vec::with_capacity(n_vms),
            finished_at: Vec::with_capacity(n_vms),
            busy: Vec::with_capacity(n_vms),
            tasks_done: Vec::with_capacity(n_vms),
            failed: Vec::with_capacity(n_vms),
            queue: Vec::with_capacity(n_tasks),
            q_cursor: Vec::with_capacity(n_vms),
            q_end: Vec::with_capacity(n_vms),
        }
    }

    fn push_vm(&mut self, it: InstanceTypeId, tasks: &[TaskId]) {
        self.it.push(it);
        self.in_flight.push(None);
        self.ready_at.push(0.0);
        self.finished_at.push(0.0);
        self.busy.push(0.0);
        self.tasks_done.push(0);
        self.failed.push(false);
        self.q_cursor.push(self.queue.len());
        self.queue.extend_from_slice(tasks);
        self.q_end.push(self.queue.len());
    }

    fn len(&self) -> usize {
        self.it.len()
    }

    /// Pop the front of VM `i`'s pinned queue (mirror of the historical
    /// `VecDeque::pop_front`).
    fn pop_queued(&mut self, i: usize) -> Option<TaskId> {
        if self.q_cursor[i] < self.q_end[i] {
            let t = self.queue[self.q_cursor[i]];
            self.q_cursor[i] += 1;
            Some(t)
        } else {
            None
        }
    }

    /// VM `i`'s not-yet-started pinned tasks, in queue order.
    fn pending(&self, i: usize) -> &[TaskId] {
        &self.queue[self.q_cursor[i]..self.q_end[i]]
    }
}

/// The engine.  Stateless; each `run_*` call is independent and fully
/// determined by `(system, workload, config)`.
pub struct Simulator;

impl Simulator {
    /// Execute a pinned plan.
    pub fn run_plan(sys: &System, plan: &Plan, config: &SimConfig) -> SimOutcome {
        Self::run(sys, Fleet::from_plan(plan), None, config)
    }

    /// Execute with online (non-clairvoyant) dispatch over the given VM
    /// fleet.
    pub fn run_online(
        sys: &System,
        fleet: &[InstanceTypeId],
        dispatcher: OnlineDispatcher,
        config: &SimConfig,
    ) -> SimOutcome {
        Self::run(sys, Fleet::from_types(fleet), Some(dispatcher), config)
    }

    fn run(
        sys: &System,
        mut fleet: Fleet,
        mut dispatcher: Option<OnlineDispatcher>,
        config: &SimConfig,
    ) -> SimOutcome {
        let noise = config.noise;
        let mut rng = Rng::new(config.seed);
        let mut q = EventQueue::new();
        let mut completed = Vec::new();
        let mut failures = 0usize;

        // Boot every VM; schedule its (optional) failure.
        for i in 0..fleet.len() {
            let boot = sys.overhead * noise.boot_multiplier(&mut rng);
            fleet.ready_at[i] = boot;
            fleet.finished_at[i] = boot;
            q.push(boot, EventKind::VmReady { vm: i });
            if let Some(life) = noise.failure_time(&mut rng) {
                q.push(boot + life, EventKind::VmFailed { vm: i });
            }
        }

        while let Some(ev) = q.pop() {
            match ev.kind {
                EventKind::VmReady { vm } => {
                    Self::start_next(
                        sys,
                        &mut fleet,
                        vm,
                        ev.time,
                        &mut dispatcher,
                        &noise,
                        &mut rng,
                        &mut q,
                    );
                }
                EventKind::TaskDone { vm, task } => {
                    if fleet.failed[vm] {
                        continue; // completion raced the failure; dropped
                    }
                    fleet.in_flight[vm] = None;
                    fleet.tasks_done[vm] += 1;
                    fleet.finished_at[vm] = ev.time;
                    completed.push(task);
                    Self::start_next(
                        sys,
                        &mut fleet,
                        vm,
                        ev.time,
                        &mut dispatcher,
                        &noise,
                        &mut rng,
                        &mut q,
                    );
                }
                EventKind::VmFailed { vm } => {
                    if fleet.failed[vm] {
                        continue;
                    }
                    // A failure after the VM drained everything is moot.
                    if fleet.in_flight[vm].is_none() && fleet.pending(vm).is_empty() {
                        continue;
                    }
                    fleet.failed[vm] = true;
                    fleet.finished_at[vm] = ev.time;
                    failures += 1;
                }
            }
        }

        // Collect stranded tasks: in-flight + queued on failed VMs.
        // (Live VMs always drain their queues, so leftovers imply failure.)
        let mut stranded = Vec::new();
        for i in 0..fleet.len() {
            if let Some(t) = fleet.in_flight[i] {
                stranded.push(t);
            }
            stranded.extend_from_slice(fleet.pending(i));
        }
        // An all-VMs-failed run can leave tasks inside the dispatcher.
        if let Some(d) = &mut dispatcher {
            if !d.is_empty() {
                let fallback = fleet.it.first().copied().unwrap_or(InstanceTypeId(0));
                while let Some(t) = d.next_for(sys, fallback) {
                    stranded.push(t);
                }
            }
        }

        let mut cost = 0.0;
        let vm_stats: Vec<VmStats> = (0..fleet.len())
            .map(|i| {
                let billed =
                    billed_cost(fleet.finished_at[i], sys.rate(fleet.it[i]), sys.hour, sys.billing);
                cost += billed;
                VmStats {
                    it: fleet.it[i],
                    ready_at: fleet.ready_at[i],
                    finished_at: fleet.finished_at[i],
                    busy: fleet.busy[i],
                    tasks_done: fleet.tasks_done[i],
                    failed: fleet.failed[i],
                    billed,
                }
            })
            .collect();
        let makespan = fleet.finished_at.iter().copied().fold(0.0, f64::max);

        SimOutcome { makespan, cost, completed, stranded, vm_stats, failures }
    }

    #[allow(clippy::too_many_arguments)]
    fn start_next(
        sys: &System,
        fleet: &mut Fleet,
        vm: usize,
        now: f64,
        dispatcher: &mut Option<OnlineDispatcher>,
        noise: &NoiseModel,
        rng: &mut Rng,
        q: &mut EventQueue,
    ) {
        if fleet.failed[vm] || fleet.in_flight[vm].is_some() {
            return;
        }
        let next = match (fleet.pop_queued(vm), dispatcher.as_mut()) {
            (Some(t), _) => Some(t),
            (None, Some(d)) => d.next_for(sys, fleet.it[vm]),
            (None, None) => None,
        };
        let Some(task) = next else {
            return;
        };
        let dur = sys.exec_time(fleet.it[vm], task) * noise.task_multiplier(rng);
        fleet.in_flight[vm] = Some(task);
        fleet.busy[vm] += dur;
        q.push(now + dur, EventKind::TaskDone { vm, task });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::Planner;
    use crate::workload::paper::table1_system;

    #[test]
    fn noiseless_sim_matches_analytic_score() {
        let sys = table1_system(30.0);
        let report = Planner::new(&sys).find(80.0);
        let sim = Simulator::run_plan(&sys, &report.plan, &SimConfig::default());
        assert!(sim.all_done());
        assert_eq!(sim.completed.len(), 750);
        assert!(
            (sim.makespan - report.score.makespan).abs() < 1e-6,
            "sim {} vs analytic {}",
            sim.makespan,
            report.score.makespan
        );
        assert!(
            (sim.cost - report.score.cost).abs() < 1e-6,
            "sim {} vs analytic {}",
            sim.cost,
            report.score.cost
        );
    }

    #[test]
    fn jitter_changes_times_but_completes() {
        let sys = table1_system(0.0);
        let report = Planner::new(&sys).find(80.0);
        let cfg = SimConfig { noise: NoiseModel::jitter(0.1), seed: 7 };
        let sim = Simulator::run_plan(&sys, &report.plan, &cfg);
        assert!(sim.all_done());
        assert!(sim.makespan > 0.0);
        assert!((sim.makespan - report.score.makespan).abs() > 1e-9);
        // Deterministic given the seed.
        let sim2 = Simulator::run_plan(&sys, &report.plan, &cfg);
        assert_eq!(sim.makespan, sim2.makespan);
        assert_eq!(sim.cost, sim2.cost);
    }

    #[test]
    fn failures_strand_tasks() {
        let sys = table1_system(0.0);
        let report = Planner::new(&sys).find(80.0);
        // Mean lifetime far below the makespan: most VMs die mid-run.
        let cfg = SimConfig { noise: NoiseModel::with_failures(0.0, 300.0), seed: 3 };
        let sim = Simulator::run_plan(&sys, &report.plan, &cfg);
        assert!(sim.failures > 0);
        assert!(!sim.stranded.is_empty());
        assert_eq!(sim.completed.len() + sim.stranded.len(), 750);
    }

    #[test]
    fn online_dispatch_completes_everything() {
        let sys = table1_system(0.0);
        let fleet = vec![
            InstanceTypeId(2),
            InstanceTypeId(2),
            InstanceTypeId(3),
            InstanceTypeId(3),
            InstanceTypeId(0),
        ];
        let d = OnlineDispatcher::new(&sys);
        let sim = Simulator::run_online(&sys, &fleet, d, &SimConfig::default());
        assert!(sim.all_done());
        assert_eq!(sim.completed.len(), 750);
        // Work-conserving: every VM did something.
        assert!(sim.vm_stats.iter().all(|v| v.tasks_done > 0));
    }

    #[test]
    fn online_beats_or_matches_worst_pinned() {
        // Online self-scheduling should not be worse than piling all
        // tasks onto one VM of the same fleet.
        let sys = table1_system(0.0);
        let fleet = vec![InstanceTypeId(3); 4];
        let d = OnlineDispatcher::new(&sys);
        let online = Simulator::run_online(&sys, &fleet, d, &SimConfig::default());
        let mut pinned = Plan::new();
        let v0 = pinned.add_vm(&sys, InstanceTypeId(3));
        for _ in 1..4 {
            pinned.add_vm(&sys, InstanceTypeId(3));
        }
        for t in sys.tasks() {
            pinned.vms[v0].push_task(&sys, t.id);
        }
        let worst = Simulator::run_plan(&sys, &pinned, &SimConfig::default());
        assert!(online.makespan <= worst.makespan);
    }

    #[test]
    fn empty_plan_is_empty_outcome() {
        let sys = table1_system(0.0);
        let plan = Plan::new();
        let sim = Simulator::run_plan(&sys, &plan, &SimConfig::default());
        assert_eq!(sim.makespan, 0.0);
        assert_eq!(sim.cost, 0.0);
        assert!(sim.completed.is_empty());
    }

    #[test]
    fn flattened_queues_mirror_per_vm_order() {
        let sys = table1_system(0.0);
        let mut plan = Plan::new();
        let v0 = plan.add_vm(&sys, InstanceTypeId(0));
        let v1 = plan.add_vm(&sys, InstanceTypeId(1));
        for t in [0u32, 2, 4] {
            plan.vms[v0].push_task(&sys, TaskId(t));
        }
        for t in [1u32, 3] {
            plan.vms[v1].push_task(&sys, TaskId(t));
        }
        let mut fleet = Fleet::from_plan(&plan);
        assert_eq!(fleet.pending(0), plan.vms[0].tasks());
        assert_eq!(fleet.pending(1), plan.vms[1].tasks());
        // Popping VM 1 never disturbs VM 0's segment.
        assert_eq!(fleet.pop_queued(1), Some(TaskId(1)));
        assert_eq!(fleet.pending(0), plan.vms[0].tasks());
        assert_eq!(fleet.pending(1), &plan.vms[1].tasks()[1..]);
        assert_eq!(fleet.pop_queued(1), Some(TaskId(3)));
        assert_eq!(fleet.pop_queued(1), None);
    }
}
// (appended tests: billing-policy and overhead edge cases)
#[cfg(test)]
mod more_tests {
    use super::*;
    use crate::model::{BillingPolicy, SystemBuilder};
    use crate::scheduler::Planner;

    #[test]
    fn per_second_billing_in_simulator_matches_analytic() {
        let sys = SystemBuilder::new()
            .app("a", vec![10.0; 20])
            .instance_type("x", 6.0, vec![3.0])
            .instance_type("y", 9.0, vec![2.0])
            .billing(BillingPolicy::PerSecond)
            .overhead(25.0)
            .build()
            .unwrap();
        let r = Planner::new(&sys).find(2.0);
        let sim = Simulator::run_plan(&sys, &r.plan, &SimConfig::default());
        assert!(sim.all_done());
        assert!((sim.cost - r.score.cost).abs() < 1e-9);
        assert!((sim.makespan - r.score.makespan).abs() < 1e-9);
    }

    #[test]
    fn boot_overhead_delays_first_task() {
        let sys = SystemBuilder::new()
            .app("a", vec![10.0])
            .instance_type("x", 5.0, vec![2.0])
            .overhead(300.0)
            .build()
            .unwrap();
        let mut plan = crate::model::Plan::new();
        let v = plan.add_vm(&sys, crate::model::InstanceTypeId(0));
        plan.vms[v].push_task(&sys, crate::model::TaskId(0));
        let sim = Simulator::run_plan(&sys, &plan, &SimConfig::default());
        assert_eq!(sim.makespan, 320.0); // 300 boot + 20 exec
        assert_eq!(sim.vm_stats[0].ready_at, 300.0);
    }

    #[test]
    fn failed_vm_still_bills_until_failure() {
        let sys = crate::workload::paper::table1_system(0.0);
        let r = Planner::new(&sys).find(80.0);
        let cfg = SimConfig { noise: NoiseModel::with_failures(0.0, 600.0), seed: 2 };
        let sim = Simulator::run_plan(&sys, &r.plan, &cfg);
        if sim.failures > 0 {
            // Every failed VM billed at least one hour.
            for v in sim.vm_stats.iter().filter(|v| v.failed) {
                assert!(v.billed >= sys.rate(v.it));
            }
        }
    }
}
