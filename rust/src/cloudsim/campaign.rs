//! Closed-loop campaign execution: plan → simulate → re-plan on failure.
//!
//! This is the "dynamic scheduling feature to handle any unexpected
//! issues during runtime" the paper's Sec. VI sketches, built on the
//! simulator's failure injection and `scheduler::dynamic::replan`.
//!
//! Round r: the residual workload is planned with the money left, the
//! plan is executed on the simulated cloud; tasks stranded by VM failures
//! roll into round r+1.  The campaign reports the cumulative wall-clock
//! (rounds execute back-to-back: failures are detected when the round's
//! surviving VMs drain) and cumulative spend.
//!
//! Each round's residual planning runs through the [`Policy`] API, so a
//! campaign can execute *any* registered policy (the budget heuristic by
//! default; see [`CampaignSpec::with_policy`]).

use std::fmt;
use std::sync::Arc;

use crate::eval::PlanEvaluator;
use crate::model::{PlanScore, System, TaskId};
use crate::scheduler::dynamic::replan_policy;
use crate::scheduler::{BudgetHeuristic, Policy, SolveRequest};
use crate::util::CancelToken;

use super::engine::{SimConfig, SimOutcome, Simulator};

/// Campaign parameters.
#[derive(Clone)]
pub struct CampaignSpec {
    pub budget: f64,
    pub sim: SimConfig,
    /// The policy planning each round's residual workload.
    pub policy: Arc<dyn Policy>,
    /// Template for each round's [`SolveRequest`]: policy knobs
    /// (deadline, restart count, sample fraction, planner config, ...)
    /// apply to every round.  The template's `budget` is overridden with
    /// each round's remaining money and its `seed` with a per-round
    /// variation of `sim.seed`.
    pub base_request: SolveRequest<'static>,
    /// Evaluator each round's planning scores through (`None` = native).
    /// Kept outside `base_request` because the template must be
    /// `'static` while the evaluator is a shared handle.
    pub evaluator: Option<Arc<dyn PlanEvaluator>>,
    /// Safety cap on re-planning rounds.
    pub max_rounds: usize,
    /// Fraction of the remaining budget held back from each round as
    /// failure-recovery headroom (0.0 = paper behaviour: spend it all).
    /// On an unreliable cloud, VMs that die mid-hour waste billed money,
    /// so a round that consumes the full remaining budget leaves nothing
    /// to re-run stranded tasks.
    pub reserve_frac: f64,
    /// When true, a recovery round whose residual plan cannot satisfy
    /// the remaining money is *not executed* — the campaign stops
    /// incomplete but within budget.  When false (default), recovery is
    /// best-effort: stranded tasks are always re-run, even if that
    /// overshoots the budget (completion is prioritised over cost).
    pub enforce_budget: bool,
}

impl CampaignSpec {
    pub fn new(budget: f64) -> Self {
        Self {
            budget,
            sim: SimConfig::default(),
            policy: Arc::new(BudgetHeuristic),
            base_request: SolveRequest::new(budget),
            evaluator: None,
            max_rounds: 8,
            reserve_frac: 0.0,
            enforce_budget: false,
        }
    }

    /// Plan each round with `policy` instead of the budget heuristic
    /// (e.g. a handle from [`PolicyRegistry::get_arc`]).
    ///
    /// [`PolicyRegistry::get_arc`]: crate::scheduler::PolicyRegistry::get_arc
    pub fn with_policy(mut self, policy: Arc<dyn Policy>) -> Self {
        self.policy = policy;
        self
    }

    /// Enable failure-recovery headroom.
    pub fn with_reserve(mut self, frac: f64) -> Self {
        assert!((0.0..1.0).contains(&frac));
        self.reserve_frac = frac;
        self
    }

    /// Refuse to execute rounds that would overshoot the budget.
    pub fn strict(mut self) -> Self {
        self.enforce_budget = true;
        self
    }
}

impl fmt::Debug for CampaignSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CampaignSpec")
            .field("budget", &self.budget)
            .field("sim", &self.sim)
            .field("policy", &self.policy.name())
            .field("base_request", &self.base_request)
            .field("evaluator", &self.evaluator.as_ref().map(|e| e.name()))
            .field("max_rounds", &self.max_rounds)
            .field("reserve_frac", &self.reserve_frac)
            .field("enforce_budget", &self.enforce_budget)
            .finish()
    }
}

/// Result of a campaign.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    /// Sum of round makespans (rounds run back-to-back).
    pub wall_clock: f64,
    /// Total money spent across rounds.
    pub spent: f64,
    /// Whether every task eventually completed.
    pub complete: bool,
    /// Whether total spend stayed within the budget.
    pub within_budget: bool,
    pub rounds: Vec<SimOutcome>,
    /// The analytic score of the first (primary) plan.
    pub planned: PlanScore,
}

/// Run a full campaign on the simulated cloud.
pub fn run_campaign(sys: &System, spec: &CampaignSpec) -> CampaignOutcome {
    run_campaign_ctl(sys, spec, &CancelToken::default(), &mut |_, _| {})
}

/// [`run_campaign`] with a cooperative [`CancelToken`] checked at every
/// round boundary and a per-round observer (`on_round(index, outcome)`)
/// invoked as each round's simulation completes — the hooks the
/// coordinator's job engine uses for mid-flight cancellation and
/// streaming partial results.  A cancelled campaign reports the rounds
/// that did run (`complete` is false unless they happened to finish the
/// workload).
pub fn run_campaign_ctl(
    sys: &System,
    spec: &CampaignSpec,
    cancel: &CancelToken,
    on_round: &mut dyn FnMut(usize, &SimOutcome),
) -> CampaignOutcome {
    let mut remaining: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
    let mut wall = 0.0;
    let mut spent = 0.0;
    let mut rounds = Vec::new();
    let mut planned: Option<PlanScore> = None;

    for round in 0..spec.max_rounds {
        if remaining.is_empty() || cancel.is_cancelled() {
            break;
        }
        let budget_left = (spec.budget - spent).max(0.0);
        // Hold back recovery headroom on every round but the last.
        let round_budget = if round + 1 < spec.max_rounds {
            budget_left * (1.0 - spec.reserve_frac)
        } else {
            budget_left
        };
        let mut req = spec
            .base_request
            .clone()
            .with_budget(round_budget)
            .with_seed(spec.sim.seed.wrapping_add(round as u64))
            .with_cancel(cancel.clone());
        if let Some(e) = &spec.evaluator {
            req = req.with_evaluator(e.as_ref());
        }
        // The residual set is expressed through the sub-problem below;
        // a stale task list in the template would be misread there.
        req.remaining = None;
        let outcome = replan_policy(sys, &remaining, spec.policy.as_ref(), &req);
        if spec.enforce_budget && !outcome.score.satisfies(budget_left) {
            break; // stop incomplete rather than overshoot the budget
        }
        planned.get_or_insert(outcome.score);

        let sim_cfg = SimConfig { seed: spec.sim.seed.wrapping_add(round as u64), ..spec.sim };
        let sim = Simulator::run_plan(sys, &outcome.plan, &sim_cfg);
        wall += sim.makespan;
        spent += sim.cost;
        remaining = sim.stranded.clone();
        on_round(round, &sim);
        rounds.push(sim);
    }

    CampaignOutcome {
        wall_clock: wall,
        spent,
        complete: remaining.is_empty(),
        within_budget: spent <= spec.budget + 1e-9,
        rounds,
        planned: planned.unwrap_or(PlanScore { makespan: 0.0, cost: 0.0 }),
    }
}

/// Aggregate statistics over a set of campaign replications (shared by
/// the CLI and the coordinator's `campaign` op).
#[derive(Debug, Clone, Copy)]
pub struct ReplicationSummary {
    pub replications: usize,
    /// Replications that completed every task.
    pub complete: usize,
    /// Replications whose total spend stayed within the budget.
    pub within_budget: usize,
    pub mean_wall_clock: f64,
    pub mean_spent: f64,
}

/// Summarise replication outcomes.  Panics on an empty slice (callers
/// always run at least one replication).
pub fn summarise_replications(outs: &[CampaignOutcome]) -> ReplicationSummary {
    assert!(!outs.is_empty(), "no replications to summarise");
    let n = outs.len() as f64;
    ReplicationSummary {
        replications: outs.len(),
        complete: outs.iter().filter(|o| o.complete).count(),
        within_budget: outs.iter().filter(|o| o.within_budget).count(),
        mean_wall_clock: outs.iter().map(|o| o.wall_clock).sum::<f64>() / n,
        mean_spent: outs.iter().map(|o| o.spent).sum::<f64>() / n,
    }
}

/// Monte-Carlo replications of a campaign: `replications` independent
/// runs of [`run_campaign`], replication `r` seeded with
/// `spec.sim.seed + r·φ` (a golden-ratio stride, so the per-round seed
/// offsets of different replications never collide).  Replications are
/// independent, so they fan out over the [`crate::util::parallel`] pool
/// (`threads`: 1 = sequential, 0 = auto) and merge in replication order
/// — the outcome vector is identical at any thread count.  Replication 0
/// is exactly `run_campaign(sys, spec)`.
pub fn run_campaign_replications(
    sys: &System,
    spec: &CampaignSpec,
    replications: usize,
    threads: usize,
) -> Vec<CampaignOutcome> {
    run_campaign_replications_ctl(
        sys,
        spec,
        replications,
        threads,
        &CancelToken::default(),
        &|_, _| {},
    )
    .into_iter()
    .flatten()
    .collect()
}

/// [`run_campaign_replications`] with mid-flight control: the
/// [`CancelToken`] is checked at every replication boundary (a
/// replication already running when the token fires completes; ones not
/// yet started are skipped and come back as `None`), and
/// `on_replication(index, outcome)` streams each finished replication
/// to the caller as it completes — out of order under parallelism, so
/// observers must be `Sync`.  The returned vector is always
/// `replications` long, in replication order, with `None` holes for the
/// cancelled tail.
pub fn run_campaign_replications_ctl(
    sys: &System,
    spec: &CampaignSpec,
    replications: usize,
    threads: usize,
    cancel: &CancelToken,
    on_replication: &(dyn Fn(usize, &CampaignOutcome) + Sync),
) -> Vec<Option<CampaignOutcome>> {
    crate::util::parallel_map(threads, replications.max(1), |r| {
        if cancel.is_cancelled() {
            return None;
        }
        let mut s = spec.clone();
        s.sim.seed = spec
            .sim
            .seed
            .wrapping_add((r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let out = run_campaign(sys, &s);
        on_replication(r, &out);
        Some(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudsim::noise::NoiseModel;
    use crate::workload::paper::table1_system;

    #[test]
    fn clean_campaign_is_single_round() {
        let sys = table1_system(0.0);
        let out = run_campaign(&sys, &CampaignSpec::new(80.0));
        assert!(out.complete);
        assert_eq!(out.rounds.len(), 1);
        assert!(out.within_budget);
        assert!((out.wall_clock - out.planned.makespan).abs() < 1e-6);
    }

    #[test]
    fn failing_cloud_triggers_replanning_and_completes() {
        let sys = table1_system(0.0);
        let mut spec = CampaignSpec::new(200.0);
        spec.sim.noise = NoiseModel::with_failures(0.05, 2500.0);
        spec.sim.seed = 11;
        let out = run_campaign(&sys, &spec);
        assert!(out.rounds.len() > 1, "failures should force extra rounds");
        assert!(out.complete, "campaign must finish the workload");
        let done: usize = out.rounds.iter().map(|r| r.completed.len()).sum();
        assert_eq!(done, 750);
        // Wall clock strictly exceeds the first-round plan (failures cost time).
        assert!(out.wall_clock >= out.planned.makespan);
    }

    #[test]
    fn campaign_runs_any_registered_policy() {
        let sys = table1_system(0.0);
        let registry = crate::scheduler::PolicyRegistry::builtin();
        for name in ["mp", "mi", "multistart"] {
            let spec = CampaignSpec::new(120.0)
                .with_policy(registry.get_arc(name).expect("builtin"));
            let out = run_campaign(&sys, &spec);
            assert!(out.complete, "{name}: clean cloud must finish");
            assert_eq!(out.rounds.len(), 1, "{name}: clean cloud is single-round");
        }
    }

    #[test]
    fn campaign_base_request_carries_policy_knobs() {
        let sys = table1_system(0.0);
        let registry = crate::scheduler::PolicyRegistry::builtin();
        let mut spec =
            CampaignSpec::new(200.0).with_policy(registry.get_arc("deadline").expect("builtin"));
        spec.base_request = spec.base_request.with_deadline(3600.0);
        let out = run_campaign(&sys, &spec);
        assert!(out.complete);
        assert!(
            out.planned.makespan <= 3600.0 + 1e-6,
            "deadline knob must reach the per-round solver (got {:.1}s)",
            out.planned.makespan
        );
    }

    #[test]
    fn replications_deterministic_at_any_thread_count() {
        let sys = table1_system(0.0);
        let mut spec = CampaignSpec::new(200.0);
        spec.sim.noise = NoiseModel::with_failures(0.05, 2500.0);
        spec.sim.seed = 3;
        let seq = run_campaign_replications(&sys, &spec, 4, 1);
        assert_eq!(seq.len(), 4);
        // Replication 0 is the plain campaign.
        let plain = run_campaign(&sys, &spec);
        assert_eq!(seq[0].wall_clock.to_bits(), plain.wall_clock.to_bits());
        assert_eq!(seq[0].spent.to_bits(), plain.spent.to_bits());
        // Distinct seeds actually diversify the replications.
        assert!(
            seq.iter().any(|o| o.wall_clock.to_bits() != seq[0].wall_clock.to_bits()),
            "replications should differ under failures"
        );
        for threads in [2usize, 4] {
            let par = run_campaign_replications(&sys, &spec, 4, threads);
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.wall_clock.to_bits(), b.wall_clock.to_bits(), "threads {threads}");
                assert_eq!(a.spent.to_bits(), b.spent.to_bits(), "threads {threads}");
                assert_eq!(a.complete, b.complete);
                assert_eq!(a.rounds.len(), b.rounds.len());
            }
        }
        // The shared summary agrees with a hand-rolled fold.
        let s = summarise_replications(&seq);
        assert_eq!(s.replications, 4);
        assert_eq!(s.complete, seq.iter().filter(|o| o.complete).count());
        assert_eq!(s.within_budget, seq.iter().filter(|o| o.within_budget).count());
        let mean = seq.iter().map(|o| o.wall_clock).sum::<f64>() / 4.0;
        assert!((s.mean_wall_clock - mean).abs() < 1e-9);
    }

    #[test]
    fn campaign_respects_round_cap() {
        let sys = table1_system(0.0);
        let mut spec = CampaignSpec::new(200.0);
        // Pathological cloud: everything dies almost immediately.
        spec.sim.noise = NoiseModel::with_failures(0.0, 10.0);
        spec.max_rounds = 3;
        let out = run_campaign(&sys, &spec);
        assert!(out.rounds.len() <= 3);
        // With VMs dying after ~10s almost nothing completes.
        assert!(!out.complete);
    }
}
