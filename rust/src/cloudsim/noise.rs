//! Performance-noise models for the simulator.
//!
//! The paper's framework executes plans deterministically; real clouds do
//! not.  The noise model perturbs per-task execution times and boot
//! overheads multiplicatively (log-normal, mean-one) and optionally
//! schedules VM failures (exponential lifetimes).  `NoiseModel::none()`
//! reproduces the paper's deterministic setting exactly.

use crate::util::Rng;

/// Multiplicative noise + failure injection parameters.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Sigma of the mean-one log-normal task-time multiplier (0 = exact).
    pub task_sigma: f64,
    /// Sigma of the mean-one log-normal boot-time multiplier.
    pub boot_sigma: f64,
    /// Mean VM lifetime in seconds for exponential failures
    /// (`None` = VMs never fail).
    pub mean_lifetime: Option<f64>,
}

impl NoiseModel {
    /// The paper's deterministic setting.
    pub fn none() -> Self {
        Self { task_sigma: 0.0, boot_sigma: 0.0, mean_lifetime: None }
    }

    /// Mild multi-tenant jitter (~10% task-time spread), no failures.
    pub fn jitter(task_sigma: f64) -> Self {
        Self { task_sigma, boot_sigma: task_sigma, mean_lifetime: None }
    }

    /// Jitter + exponential VM failures with the given mean lifetime.
    pub fn with_failures(task_sigma: f64, mean_lifetime: f64) -> Self {
        Self { task_sigma, boot_sigma: task_sigma, mean_lifetime: Some(mean_lifetime) }
    }

    /// Mean-one log-normal multiplier with sigma `s`: exp(N(-s²/2, s)).
    fn mean_one_lognormal(rng: &mut Rng, s: f64) -> f64 {
        if s == 0.0 {
            1.0
        } else {
            rng.log_normal(-s * s / 2.0, s)
        }
    }

    /// Multiplier applied to one task's nominal execution time.
    pub fn task_multiplier(&self, rng: &mut Rng) -> f64 {
        Self::mean_one_lognormal(rng, self.task_sigma)
    }

    /// Multiplier applied to a VM's nominal boot overhead.
    pub fn boot_multiplier(&self, rng: &mut Rng) -> f64 {
        Self::mean_one_lognormal(rng, self.boot_sigma)
    }

    /// Sampled failure time for a VM (from boot), if failures are on.
    pub fn failure_time(&self, rng: &mut Rng) -> Option<f64> {
        self.mean_lifetime.map(|m| rng.exponential(1.0 / m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_exact() {
        let m = NoiseModel::none();
        let mut rng = Rng::new(0);
        assert_eq!(m.task_multiplier(&mut rng), 1.0);
        assert_eq!(m.boot_multiplier(&mut rng), 1.0);
        assert_eq!(m.failure_time(&mut rng), None);
    }

    #[test]
    fn jitter_is_mean_one() {
        let m = NoiseModel::jitter(0.2);
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| m.task_multiplier(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn failures_have_requested_mean() {
        let m = NoiseModel::with_failures(0.0, 5000.0);
        let mut rng = Rng::new(2);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| m.failure_time(&mut rng).unwrap()).sum::<f64>() / n as f64;
        assert!((mean - 5000.0).abs() < 100.0, "mean {mean}");
    }
}
