//! Discrete-event cloud simulator.
//!
//! The substrate the paper's evaluation ran on (the authors used a Scala
//! simulation framework): VMs boot with overhead `o`, execute their
//! assigned tasks sequentially, bill by the hourly ceiling, and the
//! simulated makespan/cost are compared against the planner's analytic
//! prediction.  On top of the paper's model the simulator adds the
//! realism knobs the paper's future work calls for:
//!
//! * [`noise`] — multiplicative per-task performance jitter (multi-tenant
//!   interference) and boot-time variance;
//! * failure injection — VMs die at exponentially distributed times,
//!   stranding their unfinished tasks;
//! * [`campaign`] — closed-loop execution: simulate, detect failures,
//!   re-plan the residual workload (`scheduler::dynamic`), repeat; with
//!   Monte-Carlo replications over the `util::parallel` worker pool
//!   ([`run_campaign_replications`]);
//! * [`sampling`] — "test runs" producing noisy (type, app, size, time)
//!   observations for the perf-matrix estimator artifact.

pub mod campaign;
pub mod engine;
pub mod event;
pub mod noise;
pub mod sampling;

pub use campaign::{
    run_campaign, run_campaign_ctl, run_campaign_replications, run_campaign_replications_ctl,
    summarise_replications, CampaignOutcome, CampaignSpec, ReplicationSummary,
};
pub use engine::{SimConfig, SimOutcome, Simulator, VmStats};
pub use event::{Event, EventKind, EventQueue};
pub use noise::NoiseModel;
pub use sampling::{sample_runs, Observation};
