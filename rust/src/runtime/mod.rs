//! PJRT/XLA runtime: load and execute the AOT-compiled artifacts.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which embed the L1
//! pallas kernel) to HLO **text** once at build time; this module loads
//! them through the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`) and exposes:
//!
//! * [`XlaEvaluator`] — batched candidate-plan scoring behind the
//!   [`crate::eval::PlanEvaluator`] trait (the coordinator hot path);
//! * [`XlaPerfEstimator`] — the perf-matrix estimation artifact;
//! * [`ArtifactMeta`] / [`artifacts_dir`] — discovery of `artifacts/`
//!   and its `meta.json` shape manifest.
//!
//! Python never runs here: the rust binary is self-contained once
//! `make artifacts` has produced the `.hlo.txt` files.

pub mod artifacts;
pub mod estimator;
pub mod plan_eval;

pub use artifacts::{artifacts_dir, ArtifactMeta};
pub use estimator::XlaPerfEstimator;
pub use plan_eval::XlaEvaluator;
