//! Artifact discovery and the `meta.json` shape manifest.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Static shapes of the compiled artifacts (see `python/compile/aot.py`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub hour_seconds: f64,
    /// plan_eval: (file, K candidates, V vm slots, M apps).
    pub plan_eval_file: PathBuf,
    pub k: usize,
    pub v: usize,
    pub m: usize,
    /// Optional small-batch variant (same V/M, smaller K) — the planner's
    /// REPLACE step scores a handful of candidates at a time and padding
    /// those to the full K wastes most of the execution (see §Perf).
    pub plan_eval_small: Option<(PathBuf, usize)>,
    /// perf_estim: (file, S samples, C cells).
    pub perf_estim_file: PathBuf,
    pub s: usize,
    pub c: usize,
}

/// Locate the artifacts directory: `$BOTSCHED_ARTIFACTS` if set, else
/// `artifacts/` relative to the current directory, else relative to the
/// executable's workspace root.
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("BOTSCHED_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("meta.json").exists() {
            return Ok(p);
        }
        return Err(anyhow!("$BOTSCHED_ARTIFACTS={} has no meta.json", p.display()));
    }
    for base in [Path::new("artifacts"), Path::new("../artifacts")] {
        if base.join("meta.json").exists() {
            return Ok(base.to_path_buf());
        }
    }
    // Fall back to the crate root (tests run from target subdirs).
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.join("meta.json").exists() {
        return Ok(manifest);
    }
    Err(anyhow!(
        "artifacts/ not found — run `make artifacts` (or set $BOTSCHED_ARTIFACTS)"
    ))
}

impl ArtifactMeta {
    /// Load `meta.json` from the discovered artifacts directory.
    pub fn load() -> Result<Self> {
        Self::load_from(&artifacts_dir()?)
    }

    pub fn load_from(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", meta_path.display()))?;
        let field = |keys: &[&str]| -> Result<f64> {
            j.path(keys)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("meta.json missing {}", keys.join(".")))
        };
        let file = |keys: &[&str]| -> Result<PathBuf> {
            Ok(dir.join(
                j.path(keys)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("meta.json missing {}", keys.join(".")))?,
            ))
        };
        let plan_eval_small = match (
            file(&["plan_eval_small", "file"]),
            field(&["plan_eval_small", "k"]),
        ) {
            (Ok(f), Ok(k)) if f.exists() => Some((f, k as usize)),
            _ => None,
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            hour_seconds: field(&["hour_seconds"])?,
            plan_eval_file: file(&["plan_eval", "file"])?,
            k: field(&["plan_eval", "k"])? as usize,
            v: field(&["plan_eval", "v"])? as usize,
            m: field(&["plan_eval", "m"])? as usize,
            plan_eval_small,
            perf_estim_file: file(&["perf_estim", "file"])?,
            s: field(&["perf_estim", "s"])? as usize,
            c: field(&["perf_estim", "c"])? as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_loads_when_artifacts_built() {
        // `make artifacts` is a prerequisite of `make test`; skip quietly
        // if this checkout has not built them (pure-cargo runs).
        let Ok(dir) = artifacts_dir() else { return };
        let meta = ArtifactMeta::load_from(&dir).expect("meta parses");
        assert_eq!(meta.hour_seconds, 3600.0);
        assert!(meta.k > 0 && meta.v > 0 && meta.m > 0);
        assert!(meta.plan_eval_file.exists());
        assert!(meta.perf_estim_file.exists());
    }
}
