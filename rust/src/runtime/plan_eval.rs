//! The XLA-backed [`PlanEvaluator`]: batched candidate-plan scoring on
//! the AOT-compiled `plan_eval.hlo.txt` artifact (which embeds the L1
//! pallas kernel).
//!
//! Candidates are padded to the artifact's static `(K, V, M)` shape and
//! scored `K` at a time in a single PJRT execution.  Results are exact
//! f32 renditions of eq. 5-8; the differential tests against
//! [`NativeEvaluator`](crate::eval::NativeEvaluator) pin agreement to
//! ~1e-3 relative (f32 vs f64 reduction order).
//!
//! Fallback rules (delegating to the native evaluator):
//! * a candidate with more than `V` VMs or more than `M` applications;
//! * a system using `BillingPolicy::PerSecond` (the artifact hard-codes
//!   the paper's hourly ceiling).

use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::eval::{EvalBatch, NativeEvaluator, PlanEvaluator};
use crate::model::{BillingPolicy, PlanScore};

use super::artifacts::ArtifactMeta;

/// PJRT executable wrapper.
///
/// SAFETY: `PjRtLoadedExecutable` holds raw pointers and is neither `Send`
/// nor `Sync` by auto-derivation, but the underlying PJRT CPU client is
/// thread-safe for serialized use; all access goes through the `Mutex`,
/// and the owning client lives as long as the executable (the xla crate
/// keeps a refcounted handle inside).
struct ExeCell(Mutex<xla::PjRtLoadedExecutable>);
unsafe impl Send for ExeCell {}
unsafe impl Sync for ExeCell {}

/// Batched plan scoring through the AOT artifact.
pub struct XlaEvaluator {
    exe: ExeCell,
    /// Small-batch executable (K = meta.plan_eval_small) — §Perf: the
    /// planner's REPLACE step scores 4-16 candidates at a time; padding
    /// those to K=64 wastes ~8x compute per call.
    exe_small: Option<(ExeCell, usize)>,
    meta: ArtifactMeta,
    /// Pre-allocated staging buffers (size K*V*M etc.), reused across
    /// calls under the same lock as the executable.
    staging: Mutex<Staging>,
}

#[derive(Default)]
struct Staging {
    sizes: Vec<f32>,
    perf: Vec<f32>,
    rate: Vec<f32>,
    active: Vec<f32>,
}

impl XlaEvaluator {
    /// Load the artifact and compile it on the PJRT CPU client.
    pub fn load() -> Result<Self> {
        Self::load_with(ArtifactMeta::load()?)
    }

    pub fn load_with(meta: ArtifactMeta) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };
        let exe = compile(&meta.plan_eval_file)?;
        let exe_small = match &meta.plan_eval_small {
            Some((path, k_small)) => Some((ExeCell(Mutex::new(compile(path)?)), *k_small)),
            None => None,
        };
        let (k, v, m) = (meta.k, meta.v, meta.m);
        let staging = Staging {
            sizes: vec![0.0; k * v * m],
            perf: vec![0.0; k * v * m],
            rate: vec![0.0; k * v],
            active: vec![0.0; k * v],
        };
        Ok(Self {
            exe: ExeCell(Mutex::new(exe)),
            exe_small,
            meta,
            staging: Mutex::new(staging),
        })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Score one chunk of at most `K` candidates (all fitting V/M).
    ///
    /// Chunks no larger than the small artifact's K run on the small
    /// executable — same numerics, ~K_big/K_small less padded compute.
    fn eval_chunk(&self, batch: &EvalBatch, idx: &[usize], out: &mut [PlanScore]) -> Result<()> {
        let (v, m) = (self.meta.v, self.meta.m);
        // Pick the executable: small when the chunk fits it.
        let (exe_cell, k) = match &self.exe_small {
            Some((cell, k_small)) if idx.len() <= *k_small => (cell, *k_small),
            _ => (&self.exe, self.meta.k),
        };
        debug_assert!(idx.len() <= k);

        let mut staging = self.staging.lock().unwrap();
        // Only the first k*... prefix of the staging buffers is used.
        staging.sizes[..k * v * m].iter_mut().for_each(|x| *x = 0.0);
        staging.perf[..k * v * m].iter_mut().for_each(|x| *x = 0.0);
        staging.rate[..k * v].iter_mut().for_each(|x| *x = 0.0);
        staging.active[..k * v].iter_mut().for_each(|x| *x = 0.0);

        for (row, &ci) in idx.iter().enumerate() {
            let cand = &batch.candidates[ci];
            for vi in 0..cand.n_vms() {
                if !cand.active[vi] {
                    continue;
                }
                let base = (row * v + vi) * m;
                for (ai, (&s, &p)) in
                    cand.sizes[vi].iter().zip(&cand.perf[vi]).enumerate()
                {
                    staging.sizes[base + ai] = s as f32;
                    staging.perf[base + ai] = p as f32;
                }
                staging.rate[row * v + vi] = cand.rate[vi] as f32;
                staging.active[row * v + vi] = 1.0;
            }
        }

        let overhead = xla::Literal::vec1(&[batch.overhead as f32]).reshape(&[1, 1])?;
        let hour = xla::Literal::vec1(&[batch.hour as f32]).reshape(&[1, 1])?;
        let sizes = xla::Literal::vec1(&staging.sizes[..k * v * m])
            .reshape(&[k as i64, v as i64, m as i64])?;
        let perf = xla::Literal::vec1(&staging.perf[..k * v * m])
            .reshape(&[k as i64, v as i64, m as i64])?;
        let rate = xla::Literal::vec1(&staging.rate[..k * v]).reshape(&[k as i64, v as i64])?;
        let active =
            xla::Literal::vec1(&staging.active[..k * v]).reshape(&[k as i64, v as i64])?;
        drop(staging);

        let exe = exe_cell.0.lock().unwrap();
        let result = exe
            .execute::<xla::Literal>(&[overhead, hour, sizes, perf, rate, active])?[0][0]
            .to_literal_sync()?;
        drop(exe);
        let (_exec, cost, makespan) = result.to_tuple3()?;
        let cost: Vec<f32> = cost.to_vec()?;
        let makespan: Vec<f32> = makespan.to_vec()?;

        for (row, &ci) in idx.iter().enumerate() {
            out[ci] = PlanScore { makespan: makespan[row] as f64, cost: cost[row] as f64 };
        }
        Ok(())
    }
}

impl PlanEvaluator for XlaEvaluator {
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore> {
        let mut out = vec![PlanScore { makespan: 0.0, cost: 0.0 }; batch.len()];
        if batch.is_empty() {
            return out;
        }
        // Partition into XLA-eligible candidates and native fallbacks.
        let mut eligible = Vec::with_capacity(batch.len());
        let mut fallback = Vec::new();
        let per_second = batch.billing == BillingPolicy::PerSecond;
        for (i, c) in batch.candidates.iter().enumerate() {
            if per_second || c.n_vms() > self.meta.v || batch.n_apps > self.meta.m {
                fallback.push(i);
            } else {
                eligible.push(i);
            }
        }
        if !fallback.is_empty() {
            let mut nb = EvalBatch {
                candidates: fallback.iter().map(|&i| batch.candidates[i].clone()).collect(),
                ..batch.clone()
            };
            nb.n_apps = batch.n_apps;
            for (j, score) in NativeEvaluator.eval_batch(&nb).into_iter().enumerate() {
                out[fallback[j]] = score;
            }
        }
        // Chunking: full-K chunks first, then the tail in small-K chunks
        // (when the small artifact exists) to minimise padded compute.
        let mut chunks: Vec<&[usize]> = Vec::new();
        let mut rest = eligible.as_slice();
        while rest.len() >= self.meta.k {
            let (head, tail) = rest.split_at(self.meta.k);
            chunks.push(head);
            rest = tail;
        }
        match &self.exe_small {
            Some((_, k_small)) => {
                while !rest.is_empty() {
                    let n = rest.len().min(*k_small);
                    let (head, tail) = rest.split_at(n);
                    chunks.push(head);
                    rest = tail;
                }
            }
            None => {
                if !rest.is_empty() {
                    chunks.push(rest);
                }
            }
        }
        for chunk in chunks {
            if let Err(e) = self.eval_chunk(batch, chunk, &mut out) {
                // A runtime failure on the XLA path must not take the
                // coordinator down: score the chunk natively.
                eprintln!("warning: XLA eval failed ({e:#}); falling back to native");
                let nb = EvalBatch {
                    candidates: chunk.iter().map(|&i| batch.candidates[i].clone()).collect(),
                    ..batch.clone()
                };
                for (j, score) in NativeEvaluator.eval_batch(&nb).into_iter().enumerate() {
                    out[chunk[j]] = score;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
