//! The XLA-backed performance-matrix estimator (the `perf_estim`
//! artifact): turns sampled test-run observations into an estimated
//! `P[N x M]`, mirroring `cloudsim::sampling::estimate_perf_native`.

use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::cloudsim::Observation;
use crate::model::System;

use super::artifacts::ArtifactMeta;

struct ExeCell(Mutex<xla::PjRtLoadedExecutable>);
// SAFETY: see `plan_eval.rs` — serialized access to a CPU-client
// executable whose client handle is refcounted inside the crate.
unsafe impl Send for ExeCell {}
unsafe impl Sync for ExeCell {}

/// Estimator over the AOT `perf_estim.hlo.txt` artifact.
pub struct XlaPerfEstimator {
    exe: ExeCell,
    meta: ArtifactMeta,
}

impl XlaPerfEstimator {
    pub fn load() -> Result<Self> {
        Self::load_with(ArtifactMeta::load()?)
    }

    pub fn load_with(meta: ArtifactMeta) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(&meta.perf_estim_file)
            .with_context(|| format!("loading {}", meta.perf_estim_file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling perf_estim artifact")?;
        Ok(Self { exe: ExeCell(Mutex::new(exe)), meta })
    }

    /// Estimate the flattened performance matrix (`it.index() * M + app`)
    /// from observations.  `prior` must have `n_types * n_apps` entries;
    /// unsampled cells return the prior.
    ///
    /// Errors if the system or sample count exceeds the artifact's static
    /// shape (S samples, C cells) — chunk the observations if needed.
    pub fn estimate(
        &self,
        sys: &System,
        obs: &[Observation],
        prior: &[f64],
        prior_weight: f64,
    ) -> Result<Vec<f64>> {
        let (s_max, c_max) = (self.meta.s, self.meta.c);
        let m = sys.n_apps();
        let cells = sys.n_types() * m;
        if cells > c_max {
            return Err(anyhow!("system has {cells} cells > artifact C={c_max}"));
        }
        if obs.len() > s_max {
            return Err(anyhow!("{} observations > artifact S={s_max}", obs.len()));
        }
        if prior.len() != cells {
            return Err(anyhow!("prior has {} entries, want {cells}", prior.len()));
        }

        let mut indicator = vec![0.0f32; s_max * c_max];
        let mut size = vec![0.0f32; s_max];
        let mut time = vec![0.0f32; s_max];
        for (i, o) in obs.iter().enumerate() {
            let c = o.it.index() * m + o.app.index();
            indicator[i * c_max + c] = 1.0;
            size[i] = o.size as f32;
            time[i] = o.time as f32;
        }
        let mut prior_pad = vec![0.0f32; c_max];
        for (i, p) in prior.iter().enumerate() {
            prior_pad[i] = *p as f32;
        }

        let args = [
            xla::Literal::vec1(&indicator).reshape(&[s_max as i64, c_max as i64])?,
            xla::Literal::vec1(&size),
            xla::Literal::vec1(&time),
            xla::Literal::vec1(&prior_pad),
            xla::Literal::vec1(&[prior_weight as f32]),
        ];
        let exe = self.exe.0.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        drop(exe);
        let p_hat = result.to_tuple1()?;
        let p_hat: Vec<f32> = p_hat.to_vec()?;
        Ok(p_hat[..cells].iter().map(|p| *p as f64).collect())
    }
}
