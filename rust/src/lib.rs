//! # botsched
//!
//! Budget-constrained execution of multiple Bag-of-Tasks (BoT) applications
//! on the cloud — a production-shaped reproduction of
//! *Thai, Varghese, Barker: "Budget Constrained Execution of Multiple
//! Bag-of-Tasks Applications on the Cloud"* (IEEE CLOUD 2015,
//! DOI 10.1109/CLOUD.2015.131).
//!
//! Every planning consumer — library callers, the CLI, the coordinator's
//! wire protocol, the cloud simulator's campaigns, the benches — speaks
//! one solver API: a [`scheduler::Policy`] is resolved by name from the
//! [`scheduler::PolicyRegistry`], given a [`scheduler::SolveRequest`]
//! (budget, optional deadline, evaluator handle, seed, tuning knobs) and
//! returns a [`scheduler::SolveOutcome`] (plan, makespan, cost,
//! feasibility, iteration trace).  Adding a scheduling scenario is one
//! `impl Policy` plus one registry line; it then works everywhere,
//! including over the wire via `{"op":"plan","policy":"<name>",...}`.
//!
//! The crate is organised in layers:
//!
//! * [`model`] — the paper's Section III problem model: applications, tasks,
//!   instance types, the performance matrix, VMs, execution plans, and the
//!   hourly billing / makespan cost model.
//! * [`scheduler`] — the policy layer.  The unified `Policy` /
//!   `SolveRequest` / `SolveOutcome` / `PolicyRegistry` API fronts the
//!   paper's Section IV heuristic planner (`INITIAL`, `ASSIGN`,
//!   `BALANCE`, `REDUCE`, `ADD`, `SPLIT`, `REPLACE`, and the `FIND`
//!   fixed-point loop), the Section V comparison baselines (MI, MP), a
//!   multi-start wrapper, and the future-work extensions (deadline-aware,
//!   dynamic rescheduling, non-clairvoyant).
//! * [`cloudsim`] — a discrete-event cloud simulator substrate (VM boot
//!   overhead, per-hour billing, performance jitter, failures) standing in
//!   for the paper's Scala simulation framework and for a real IaaS cloud;
//!   its closed-loop campaigns re-plan through any registered policy.
//! * [`workload`] — BoT workload and performance-matrix generators,
//!   including the paper's exact Table I setup.
//! * [`runtime`] — PJRT/XLA runtime: loads the AOT-compiled plan-evaluation
//!   artifacts produced by `python/compile/aot.py` and exposes them behind
//!   the [`eval::PlanEvaluator`] trait (the evaluator handle a
//!   `SolveRequest` carries).
//! * [`coordinator`] — the long-running leader: a TCP JSON protocol server
//!   with request batching that plans (any policy, by name, with
//!   `list_policies` discovery), simulates and reports.  Its wire surface
//!   is the typed, versioned [`coordinator::api`] (one `Request`/`Response`
//!   struct per op, structured `ApiError` codes, v2 `describe` schema),
//!   spoken natively by the first-class blocking
//!   [`coordinator::Client`].
//! * [`persist`] — dependency-free durability: the coordinator's
//!   crash-recoverable job journal (append-only, checksummed,
//!   compacting) and the bounded content-addressed solve cache behind
//!   `--journal` / `--cache-capacity`.
//! * [`loadgen`] — the open-loop load generator: seeded arrival
//!   processes and request mixes driving a live coordinator through
//!   concurrent pipelined clients, with record-and-replay traffic tapes
//!   ([`workload::LoadTrace`]) and SLO reports (throughput vs offered
//!   load, latency percentiles, served/busy/deadline-exceeded
//!   breakdowns, saturation-knee sweeps) — `botsched loadgen`.
//! * [`analysis`] — lower bounds, statistics and the policy-generic
//!   sweep/figure printers used by the benchmark harness.

pub mod analysis;
pub mod benchkit;
pub mod cloudsim;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod loadgen;
pub mod model;
pub mod persist;
pub mod runtime;
pub mod scheduler;
pub mod util;
pub mod workload;
