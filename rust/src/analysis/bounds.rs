//! Optimality references for the heuristic.
//!
//! * [`fractional_cost_floor`] — the LP-relaxation lower bound on the
//!   money needed to run the whole workload (hour quantisation and
//!   indivisible tasks dropped): each application's work is routed to its
//!   most cost-efficient instance type at fractional hours.
//! * [`makespan_floor`] — a lower bound on the makespan achievable within
//!   a budget: total VM-hours affordable caps parallel work.
//! * [`brute_force_best`] — exact optimum by exhaustive enumeration for
//!   tiny instances; used by the property tests to certify the heuristic
//!   is never wildly off and by DESIGN.md's feasibility analysis.

use crate::model::{InstanceTypeId, Plan, PlanScore, System};

/// LP-relaxation lower bound on the cost of any feasible plan (no plan,
/// however clever, can run the workload cheaper).
pub fn fractional_cost_floor(sys: &System) -> f64 {
    sys.apps
        .iter()
        .map(|app| {
            sys.instance_types
                .iter()
                .map(|it| {
                    sys.perf.get(it.id, app.id) * app.total_size() / sys.hour * it.cost_per_hour
                })
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

/// Lower bound on the makespan achievable with budget `b`.
///
/// Two effects cap parallel speed-up: (1) money — every VM-hour costs at
/// least `c_min`, so the budget buys at most `b / c_min` VM-hours, and
/// even an *ideal* machine (best type per application simultaneously,
/// which no mixture can beat) needs `work_ideal` seconds of compute;
/// (2) the single largest task cannot be split.  Both relaxations only
/// under-estimate, so this is a true floor for any plan, mixed or not.
pub fn makespan_floor(sys: &System, b: f64) -> f64 {
    // Ideal work: each app on its fastest type (no machine is better).
    let work_ideal: f64 = sys
        .apps
        .iter()
        .map(|a| {
            let best = sys
                .instance_types
                .iter()
                .map(|it| sys.perf.get(it.id, a.id))
                .fold(f64::INFINITY, f64::min);
            best * a.total_size()
        })
        .sum();
    let c_min = sys
        .instance_types
        .iter()
        .map(|it| it.cost_per_hour)
        .fold(f64::INFINITY, f64::min);
    let money_bound = match sys.billing {
        crate::model::BillingPolicy::HourlyCeil => {
            // Only whole VM-hours can be bought; `affordable_hours`
            // VM-hour lanes must cover `work_ideal`.
            let affordable_hours = (b / c_min).floor();
            if affordable_hours < 1.0 {
                f64::INFINITY
            } else {
                work_ideal / affordable_hours
            }
        }
        // Per-second billing makes parallelism cost-free (n VMs for T/n
        // seconds cost the same as one VM for T), so money does not bound
        // the makespan — only feasibility and the largest task do.
        crate::model::BillingPolicy::PerSecond => {
            if b * sys.hour / c_min < work_ideal {
                f64::INFINITY // cannot even afford the ideal work
            } else {
                0.0
            }
        }
    };
    let largest_task = sys
        .tasks()
        .iter()
        .map(|t| {
            sys.instance_types
                .iter()
                .map(|it| sys.perf.exec_time(it.id, t))
                .fold(f64::INFINITY, f64::min)
        })
        .fold(0.0, f64::max);
    (money_bound).max(largest_task) + sys.overhead
}

/// Lower bound on the makespan of *any* spread of a task set over `n`
/// identical machines of instance type `it`.
///
/// The task set is summarised per application: `agg[m]` is its total
/// size in app `m`, `max_size[m]` the largest single task size in app
/// `m`.  Two relaxations, both of which only under-estimate:
///
/// * the busiest lane cannot beat the average — total work divided
///   perfectly over `n` lanes;
/// * no lane can beat its largest indivisible task.
///
/// REPLACE uses this as its candidate-pruning bound: a swap whose new
/// VMs cannot possibly finish below the incumbent makespan is dominated
/// before any LPT rows are synthesised for it (threshold-exact — the
/// surviving winner is unchanged; see `scheduler::replace`).
pub fn spread_makespan_floor(
    sys: &System,
    agg: &[f64],
    max_size: &[f64],
    it: InstanceTypeId,
    n: usize,
) -> f64 {
    let perf = sys.perf.row(it);
    let total_work: f64 = agg.iter().zip(perf).map(|(s, p)| s * p).sum();
    let largest_task: f64 = max_size.iter().zip(perf).map(|(s, p)| s * p).fold(0.0, f64::max);
    sys.overhead + (total_work / n.max(1) as f64).max(largest_task)
}

/// Exhaustive search over all plans with at most `max_vms` VMs: exact
/// optimal `(makespan, cost)` under the budget, or `None` if infeasible
/// at that VM cap.  Exponential — use only for tiny instances (the
/// property tests cap `tasks x types` around 6 x 2).
pub fn brute_force_best(sys: &System, budget: f64, max_vms: usize) -> Option<PlanScore> {
    let mut best: Option<PlanScore> = None;
    // Enumerate VM multisets up to max_vms over instance types, then all
    // task assignments onto those VMs.
    let n_types = sys.n_types();
    let mut vm_types: Vec<InstanceTypeId> = Vec::new();
    enumerate_vm_sets(sys, budget, n_types, 0, max_vms, &mut vm_types, &mut best);
    best
}

fn enumerate_vm_sets(
    sys: &System,
    budget: f64,
    n_types: usize,
    from_type: usize,
    slots_left: usize,
    vm_types: &mut Vec<InstanceTypeId>,
    best: &mut Option<PlanScore>,
) {
    if !vm_types.is_empty() {
        assign_all(sys, budget, vm_types, 0, &mut Plan::new(), best);
    }
    if slots_left == 0 {
        return;
    }
    for t in from_type..n_types {
        vm_types.push(InstanceTypeId(t as u16));
        enumerate_vm_sets(sys, budget, n_types, t, slots_left - 1, vm_types, best);
        vm_types.pop();
    }
}

fn assign_all(
    sys: &System,
    budget: f64,
    vm_types: &[InstanceTypeId],
    task_idx: usize,
    plan: &mut Plan,
    best: &mut Option<PlanScore>,
) {
    if plan.n_vms() == 0 {
        for &it in vm_types {
            plan.add_vm(sys, it);
        }
    }
    if task_idx == sys.tasks().len() {
        let score = plan.score(sys);
        if score.satisfies(budget)
            && best
                .as_ref()
                .is_none_or(|b| (score.makespan, score.cost) < (b.makespan, b.cost))
        {
            *best = Some(score);
        }
        return;
    }
    let tid = sys.tasks()[task_idx].id;
    for v in 0..plan.n_vms() {
        plan.vms[v].push_task(sys, tid);
        assign_all(sys, budget, vm_types, task_idx + 1, plan, best);
        plan.vms[v].remove_task(sys, tid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemBuilder;
    use crate::scheduler::Planner;
    use crate::workload::paper::table1_system;

    #[test]
    fn fractional_floor_matches_hand_computation() {
        // DESIGN.md: A1 -> 750u at 36 u/$ = 20.83, A2/A3 -> 18.75 each.
        let sys = table1_system(0.0);
        let floor = fractional_cost_floor(&sys);
        assert!((floor - (750.0 * 10.0 / 3600.0 * 10.0) * 3.0 + 0.0).abs() < 5.0);
        assert!((58.0..59.0).contains(&floor), "floor {floor}");
    }

    #[test]
    fn makespan_floor_decreases_with_budget() {
        let sys = table1_system(0.0);
        let f60 = makespan_floor(&sys, 60.0);
        let f120 = makespan_floor(&sys, 120.0);
        assert!(f120 <= f60);
        assert!(f60.is_finite());
    }

    #[test]
    fn spread_floor_never_exceeds_a_real_lpt_spread() {
        use crate::eval::PlanArena;
        use crate::model::{InstanceTypeId, Plan};
        // Any real spread of the tasks over n identical VMs must finish
        // at or above the floor — check against an actual LPT layout.
        let sys = SystemBuilder::new()
            .app("a1", vec![5.0, 1.0, 3.0, 2.0, 8.0])
            .app("a2", vec![4.0, 4.0, 1.0, 6.0])
            .instance_type("x", 2.0, vec![7.0, 9.0])
            .overhead(20.0)
            .build()
            .unwrap();
        let it = InstanceTypeId(0);
        let mut agg = vec![0.0; sys.n_apps()];
        let mut max_size = vec![0.0f64; sys.n_apps()];
        for t in sys.tasks() {
            agg[t.app.index()] += t.size;
            max_size[t.app.index()] = max_size[t.app.index()].max(t.size);
        }
        for n in 1..=5usize {
            let floor = spread_makespan_floor(&sys, &agg, &max_size, it, n);
            let mut arena = PlanArena::from_plan(&sys, &Plan::new());
            let ids: Vec<usize> = (0..n).map(|_| arena.add_vm(it)).collect();
            let tasks: Vec<_> = sys.tasks().iter().map(|t| t.id).collect();
            for t in tasks {
                let dst = *ids
                    .iter()
                    .min_by(|&&a, &&b| arena.work_at(a).total_cmp(&arena.work_at(b)))
                    .unwrap();
                arena.push_task(&sys, dst, t);
            }
            let real = (0..arena.n_vms()).map(|p| arena.exec_at(&sys, p)).fold(0.0, f64::max);
            assert!(
                floor <= real + 1e-9,
                "n={n}: floor {floor} above real spread {real}"
            );
            assert!(floor >= sys.overhead);
        }
    }

    #[test]
    fn heuristic_within_2x_of_brute_force_tiny() {
        let sys = SystemBuilder::new()
            .app("a", vec![100.0, 200.0, 300.0])
            .app("b", vec![150.0, 250.0])
            .instance_type("x", 5.0, vec![3.0, 4.0])
            .instance_type("y", 9.0, vec![2.0, 2.0])
            .build()
            .unwrap();
        let budget = 30.0;
        let exact = brute_force_best(&sys, budget, 3).expect("feasible");
        let ours = Planner::new(&sys).find(budget);
        assert!(ours.feasible);
        assert!(
            ours.score.makespan <= exact.makespan * 2.0 + 1e-6,
            "heuristic {} vs exact {}",
            ours.score.makespan,
            exact.makespan
        );
        assert!(ours.score.makespan >= exact.makespan - 1e-6, "exact must be optimal");
    }

    #[test]
    fn brute_force_infeasible_budget_is_none() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        assert!(brute_force_best(&sys, 1.0, 2).is_none());
        assert!(brute_force_best(&sys, 5.0, 2).is_some());
    }
}
