//! Budget–makespan trade-off exploration: compute the Pareto frontier of
//! `(cost, makespan)` outcomes the planner can reach over a budget range.
//!
//! The paper studies fixed budgets; a user deciding *what budget to ask
//! for* wants the frontier — the set of non-dominated outcomes — plus the
//! knee (largest marginal makespan gain per extra unit of money).  Used
//! by `botsched sweep --json` consumers and the `deadline_campaign`
//! example's cost/deadline table.

use crate::model::{PlanScore, System};
use crate::scheduler::Planner;

/// One frontier point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParetoPoint {
    pub budget: f64,
    pub score: PlanScore,
}

/// Run the planner across `budgets` and keep the Pareto-optimal
/// `(cost, makespan)` outcomes (lower is better in both), sorted by cost.
/// Infeasible outcomes are dropped.
pub fn pareto_frontier(sys: &System, budgets: &[f64]) -> Vec<ParetoPoint> {
    let planner = Planner::new(sys);
    let mut points: Vec<ParetoPoint> = budgets
        .iter()
        .map(|&b| (b, planner.find(b)))
        .filter(|(_, r)| r.feasible)
        .map(|(b, r)| ParetoPoint { budget: b, score: r.score })
        .collect();
    points.sort_by(|a, b| {
        a.score
            .cost
            .total_cmp(&b.score.cost)
            .then(a.score.makespan.total_cmp(&b.score.makespan))
    });
    // Sweep: keep points whose makespan strictly improves on everything
    // cheaper.
    let mut frontier: Vec<ParetoPoint> = Vec::new();
    for p in points {
        match frontier.last() {
            Some(last)
                if p.score.makespan >= last.score.makespan - 1e-9 =>
            {
                // Dominated (or duplicate cost tier): same or worse
                // makespan for equal-or-higher cost.
            }
            _ => frontier.push(p),
        }
    }
    frontier
}

/// The knee of the frontier: the point with the best marginal
/// seconds-per-money improvement relative to the previous point.
/// `None` for frontiers with fewer than two points.
pub fn knee(frontier: &[ParetoPoint]) -> Option<ParetoPoint> {
    frontier
        .windows(2)
        .map(|w| {
            let dm = w[0].score.makespan - w[1].score.makespan; // gained seconds
            let dc = (w[1].score.cost - w[0].score.cost).max(1e-9); // extra money
            (dm / dc, w[1])
        })
        .max_by(|a, b| a.0.total_cmp(&b.0))
        .map(|(_, p)| p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn frontier_is_monotone() {
        let sys = table1_system(0.0);
        let budgets: Vec<f64> = (55..=100).step_by(5).map(f64::from).collect();
        let f = pareto_frontier(&sys, &budgets);
        assert!(f.len() >= 3, "frontier too small: {f:?}");
        for w in f.windows(2) {
            assert!(w[1].score.cost > w[0].score.cost - 1e-9);
            assert!(
                w[1].score.makespan < w[0].score.makespan - 1e-9,
                "non-improving frontier point: {w:?}"
            );
        }
    }

    #[test]
    fn dominated_points_removed() {
        let sys = table1_system(0.0);
        // Duplicated budgets produce duplicated outcomes; the frontier
        // must dedupe them.
        let f = pareto_frontier(&sys, &[80.0, 80.0, 80.0, 85.0]);
        assert!(f.len() <= 2);
    }

    #[test]
    fn infeasible_budgets_excluded() {
        let sys = table1_system(0.0);
        let f = pareto_frontier(&sys, &[10.0, 20.0, 30.0]);
        assert!(f.is_empty(), "sub-floor budgets cannot be on the frontier");
    }

    #[test]
    fn knee_exists_for_multi_point_frontier() {
        let sys = table1_system(0.0);
        let budgets: Vec<f64> = (60..=100).step_by(5).map(f64::from).collect();
        let f = pareto_frontier(&sys, &budgets);
        if f.len() >= 2 {
            let k = knee(&f).unwrap();
            assert!(f.iter().any(|p| (p.budget - k.budget).abs() < 1e-9));
        }
        assert!(knee(&f[..1.min(f.len())]).is_none());
    }
}
