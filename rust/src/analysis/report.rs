//! Regenerate the paper's evaluation artefacts (Fig. 1, Fig. 2, headline
//! claims) from live planner runs.
//!
//! The same sweep backs the `botsched figures` CLI, the `paper_repro`
//! example and the `fig1_exec_time` / `fig2_vm_mix` benches; EXPERIMENTS.md
//! records one canonical output.

use crate::analysis::stats;
use crate::eval::{NativeEvaluator, PlanEvaluator};
use crate::model::{Plan, PlanScore, System};
use crate::scheduler::{canonical_name, legacy_name, PolicyRegistry, SolveRequest, UnknownPolicy};
use crate::util::{CancelToken, Json};

/// The Fig. 1 / Fig. 2 comparison set (the paper's heuristic vs the
/// Sec. V baselines).
pub const CORE_POLICIES: &[&str] = &["budget-heuristic", "mi", "mp"];

/// One (policy, budget) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ApproachRow {
    /// Canonical policy name (see [`crate::scheduler::PolicyRegistry`]).
    pub approach: &'static str,
    pub budget: f64,
    pub score: PlanScore,
    pub feasible: bool,
    /// VM count per instance type (Fig. 2's quantity).
    pub vm_mix: Vec<usize>,
    /// Policy wall time in microseconds (for the §Perf log).
    pub plan_micros: u128,
}

impl ApproachRow {
    /// One sweep row as JSON — the shape of `SweepReport::to_json`'s
    /// `rows` entries, also streamed as a partial result by the
    /// coordinator while a sweep job is still running.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(self.approach)),
            // Legacy spelling, kept for pre-registry clients.
            ("approach", Json::str(legacy_name(self.approach))),
            ("budget", Json::num(self.budget)),
            ("makespan", Json::num(self.score.makespan)),
            ("cost", Json::num(self.score.cost)),
            ("feasible", Json::Bool(self.feasible)),
            (
                "vm_mix",
                Json::arr(self.vm_mix.iter().map(|n| Json::num(*n as f64))),
            ),
            ("plan_micros", Json::num(self.plan_micros as f64)),
        ])
    }
}

/// A budget sweep over a set of policies.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub budgets: Vec<f64>,
    pub rows: Vec<ApproachRow>,
}

/// Run the paper's comparison set (heuristic / MI / MP) across `budgets`
/// sequentially (see [`run_sweep_threads`] for the parallel form).
pub fn run_sweep(sys: &System, budgets: &[f64], evaluator: &dyn PlanEvaluator) -> SweepReport {
    run_sweep_threads(sys, budgets, evaluator, 1)
}

/// [`run_sweep`] with the budget×policy grid fanned out over `threads`
/// workers (1 = sequential, 0 = auto).  Rows are merged in grid order,
/// so the report is identical at any thread count (modulo the wall-time
/// `plan_micros` column).
pub fn run_sweep_threads(
    sys: &System,
    budgets: &[f64],
    evaluator: &dyn PlanEvaluator,
    threads: usize,
) -> SweepReport {
    run_policy_sweep(sys, budgets, CORE_POLICIES, &PolicyRegistry::builtin(), evaluator, threads)
        .expect("core policies are builtin")
}

/// Run any set of registered policies across `budgets` — the sweep is
/// policy-generic: every row comes from [`crate::scheduler::Policy::solve`].
/// The `budgets.len() × policies.len()` cells are independent and run on
/// the [`crate::util::parallel`] pool (`threads`: 1 = sequential,
/// 0 = auto); the deterministic ordered merge keeps the row order — and
/// every plan and score — identical to the sequential sweep.
pub fn run_policy_sweep(
    sys: &System,
    budgets: &[f64],
    policies: &[&str],
    registry: &PolicyRegistry,
    evaluator: &dyn PlanEvaluator,
    threads: usize,
) -> Result<SweepReport, UnknownPolicy> {
    run_policy_sweep_ctl(
        sys,
        budgets,
        policies,
        registry,
        evaluator,
        threads,
        &CancelToken::default(),
        &|_, _| {},
    )
}

/// [`run_policy_sweep`] with mid-flight control: the [`CancelToken`] is
/// checked at every cell boundary (cells not yet started when it fires
/// are skipped — a cancelled sweep's report holds only the completed
/// rows), and `on_cell(index, row)` streams each finished cell to the
/// caller as it completes (out of order under parallelism, so observers
/// must be `Sync`).  This is the hook the coordinator's job engine uses
/// for cancellation and progress on long sweeps.
#[allow(clippy::too_many_arguments)]
pub fn run_policy_sweep_ctl(
    sys: &System,
    budgets: &[f64],
    policies: &[&str],
    registry: &PolicyRegistry,
    evaluator: &dyn PlanEvaluator,
    threads: usize,
    cancel: &CancelToken,
    on_cell: &(dyn Fn(usize, &ApproachRow) + Sync),
) -> Result<SweepReport, UnknownPolicy> {
    // Resolve up front: an unknown name fails fast, before any solving.
    let resolved: Vec<&dyn crate::scheduler::Policy> = policies
        .iter()
        .map(|name| registry.resolve(name))
        .collect::<Result<_, _>>()?;
    let cells = budgets.len() * resolved.len();
    let rows = crate::util::parallel_map(threads, cells, |idx| {
        if cancel.is_cancelled() {
            return None;
        }
        let b = budgets[idx / resolved.len()];
        let policy = resolved[idx % resolved.len()];
        let req = SolveRequest::new(b)
            .with_evaluator(evaluator)
            .with_cancel(cancel.clone());
        let t0 = std::time::Instant::now();
        let out = policy.solve(sys, &req);
        let row = ApproachRow {
            approach: out.policy,
            budget: b,
            score: out.score,
            feasible: out.feasible,
            vm_mix: out.plan.vm_mix(sys),
            plan_micros: t0.elapsed().as_micros(),
        };
        on_cell(idx, &row);
        Some(row)
    });
    Ok(SweepReport {
        budgets: budgets.to_vec(),
        rows: rows.into_iter().flatten().collect(),
    })
}

impl SweepReport {
    /// Look up a cell; `approach` accepts aliases (`"heuristic"` finds
    /// the `"budget-heuristic"` rows).
    pub fn row(&self, approach: &str, budget: f64) -> Option<&ApproachRow> {
        let canon = canonical_name(approach);
        self.rows
            .iter()
            .find(|r| r.approach == canon && (r.budget - budget).abs() < 1e-9)
    }

    /// The distinct policies in this sweep, in first-appearance order.
    pub fn approaches(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for r in &self.rows {
            if !out.contains(&r.approach) {
                out.push(r.approach);
            }
        }
        out
    }

    /// Fig. 1: execution time vs budget, one column per policy.
    /// Infeasible cells are flagged with `*` (realized cost exceeds the
    /// budget — the paper plots nothing there).
    pub fn fig1_text(&self) -> String {
        let approaches = self.approaches();
        let mut out = String::from("Fig. 1 — Execution times for different approaches\nbudget ");
        for a in &approaches {
            out.push_str(&format!(" {a:>17}"));
        }
        out.push('\n');
        for &b in &self.budgets {
            out.push_str(&format!("{b:>6} "));
            for a in &approaches {
                let r = self.row(a, b).expect("sweep covers all cells");
                let flag = if r.feasible { ' ' } else { '*' };
                out.push_str(&format!(" {:>15.1}s{flag}", r.score.makespan));
            }
            out.push('\n');
        }
        out.push_str("(* = infeasible: realized cost exceeds the budget)\n");
        out
    }

    /// Fig. 2: number of VMs of each type vs budget, per policy.
    pub fn fig2_text(&self, sys: &System) -> String {
        let mut out = String::from("Fig. 2 — Number of VMs of each type\n");
        for a in self.approaches() {
            out.push_str(&format!("\n[{a}]\nbudget "));
            for it in &sys.instance_types {
                out.push_str(&format!("{:>6}", format!("it{}", it.id.0 + 1)));
            }
            out.push_str("  total\n");
            for &b in &self.budgets {
                let r = self.row(a, b).expect("cell");
                out.push_str(&format!("{b:>6} "));
                for &n in &r.vm_mix {
                    out.push_str(&format!("{n:>6}"));
                }
                out.push_str(&format!("{:>7}\n", r.vm_mix.iter().sum::<usize>()));
            }
        }
        out
    }

    /// Headline claims (Sec. V-C): average improvement vs MI and MP over
    /// the budgets where the respective pair is feasible, plus the
    /// minimum feasible budget per approach.
    pub fn headline(&self) -> Headline {
        let mut vs_mi = Vec::new();
        let mut vs_mp = Vec::new();
        for &b in &self.budgets {
            // Sweeps over other policy sets simply yield empty averages.
            let Some(ours) = self.row("budget-heuristic", b) else { continue };
            if let Some(mi) = self.row("mi", b) {
                if ours.feasible && mi.feasible {
                    vs_mi.push(stats::improvement_pct(ours.score.makespan, mi.score.makespan));
                }
            }
            if let Some(mp) = self.row("mp", b) {
                if ours.feasible && mp.feasible {
                    vs_mp.push(stats::improvement_pct(ours.score.makespan, mp.score.makespan));
                }
            }
        }
        let min_feasible = |a: &str| {
            self.budgets
                .iter()
                .copied()
                .filter(|&b| self.row(a, b).is_some_and(|r| r.feasible))
                .fold(f64::INFINITY, f64::min)
        };
        Headline {
            avg_improvement_vs_mi_pct: stats::mean(&vs_mi),
            avg_improvement_vs_mp_pct: stats::mean(&vs_mp),
            min_feasible_budget_heuristic: min_feasible("budget-heuristic"),
            min_feasible_budget_mi: min_feasible("mi"),
            min_feasible_budget_mp: min_feasible("mp"),
        }
    }

    /// Machine-readable dump (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("budgets", Json::arr(self.budgets.iter().map(|b| Json::num(*b)))),
            ("rows", Json::arr(self.rows.iter().map(ApproachRow::to_json))),
        ])
    }
}

/// Sec. V-C headline numbers.
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    pub avg_improvement_vs_mi_pct: f64,
    pub avg_improvement_vs_mp_pct: f64,
    pub min_feasible_budget_heuristic: f64,
    pub min_feasible_budget_mi: f64,
    pub min_feasible_budget_mp: f64,
}

impl Headline {
    pub fn text(&self) -> String {
        format!(
            "Headline (paper Sec. V-C):\n\
             avg improvement vs MI: {:+.1}% (paper: ~13%)\n\
             avg improvement vs MP: {:+.1}% (paper: ~7%)\n\
             min feasible budget  : heuristic {} | MP {} | MI {} \
             (paper: 40 | 45 | 50 — ordering is the reproducible shape)\n",
            self.avg_improvement_vs_mi_pct,
            self.avg_improvement_vs_mp_pct,
            fmt_budget(self.min_feasible_budget_heuristic),
            fmt_budget(self.min_feasible_budget_mp),
            fmt_budget(self.min_feasible_budget_mi),
        )
    }
}

fn fmt_budget(b: f64) -> String {
    if b.is_finite() {
        format!("{b}")
    } else {
        "never".into()
    }
}

/// Convenience used by several binaries: sweep the paper workload with
/// the native evaluator.
pub fn paper_sweep() -> (System, SweepReport) {
    let sys = crate::workload::paper::table1_system(0.0);
    let report = run_sweep(&sys, crate::workload::paper::BUDGETS, &NativeEvaluator);
    (sys, report)
}

/// Extract a plan for inspection (any registered policy; panics on an
/// unknown name — use [`PolicyRegistry::solve`] for fallible lookup).
pub fn plan_for(sys: &System, approach: &str, budget: f64) -> Plan {
    PolicyRegistry::builtin()
        .solve(approach, sys, &SolveRequest::new(budget))
        .unwrap_or_else(|e| panic!("{e}"))
        .plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    fn small_sweep() -> (System, SweepReport) {
        let sys = table1_system(0.0);
        let report = run_sweep(&sys, &[60.0, 80.0], &NativeEvaluator);
        (sys, report)
    }

    #[test]
    fn sweep_has_all_cells() {
        let (_, r) = small_sweep();
        assert_eq!(r.rows.len(), 6);
        assert_eq!(r.approaches(), vec!["budget-heuristic", "mi", "mp"]);
        for a in ["budget-heuristic", "mi", "mp"] {
            for b in [60.0, 80.0] {
                assert!(r.row(a, b).is_some());
            }
        }
        // Legacy alias still finds the heuristic rows.
        assert!(r.row("heuristic", 60.0).is_some());
    }

    #[test]
    fn fig_texts_render() {
        let (sys, r) = small_sweep();
        let f1 = r.fig1_text();
        assert!(f1.contains("budget"));
        assert!(f1.lines().count() >= 4);
        let f2 = r.fig2_text(&sys);
        assert!(f2.contains("[budget-heuristic]"));
        assert!(f2.contains("it4"));
    }

    #[test]
    fn policy_sweep_runs_arbitrary_policy_sets() {
        let sys = table1_system(0.0);
        let registry = crate::scheduler::PolicyRegistry::builtin();
        let r = run_policy_sweep(
            &sys,
            &[80.0],
            &["multistart", "mp"],
            &registry,
            &NativeEvaluator,
            1,
        )
        .unwrap();
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.approaches(), vec!["multistart", "mp"]);
        assert!(run_policy_sweep(&sys, &[80.0], &["zz"], &registry, &NativeEvaluator, 1).is_err());
    }

    #[test]
    fn threaded_sweep_matches_sequential() {
        let sys = table1_system(0.0);
        let seq = run_sweep(&sys, &[60.0, 80.0], &NativeEvaluator);
        for threads in [2usize, 4] {
            let par = run_sweep_threads(&sys, &[60.0, 80.0], &NativeEvaluator, threads);
            assert_eq!(par.rows.len(), seq.rows.len());
            for (a, b) in par.rows.iter().zip(&seq.rows) {
                assert_eq!(a.approach, b.approach, "threads {threads}");
                assert_eq!(a.budget, b.budget);
                assert_eq!(a.score.makespan.to_bits(), b.score.makespan.to_bits());
                assert_eq!(a.score.cost.to_bits(), b.score.cost.to_bits());
                assert_eq!(a.feasible, b.feasible);
                assert_eq!(a.vm_mix, b.vm_mix);
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let (_, r) = small_sweep();
        let text = r.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 6);
    }

    #[test]
    fn headline_computes() {
        let (_, r) = small_sweep();
        let h = r.headline();
        assert!(h.min_feasible_budget_heuristic <= h.min_feasible_budget_mi);
    }
}
