//! Regenerate the paper's evaluation artefacts (Fig. 1, Fig. 2, headline
//! claims) from live planner runs.
//!
//! The same sweep backs the `botsched figures` CLI, the `paper_repro`
//! example and the `fig1_exec_time` / `fig2_vm_mix` benches; EXPERIMENTS.md
//! records one canonical output.

use crate::analysis::stats;
use crate::eval::{NativeEvaluator, PlanEvaluator};
use crate::model::{Plan, PlanScore, System};
use crate::scheduler::{maximise_parallelism, minimise_individual, Planner};
use crate::util::Json;

/// One (approach, budget) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ApproachRow {
    pub approach: &'static str,
    pub budget: f64,
    pub score: PlanScore,
    pub feasible: bool,
    /// VM count per instance type (Fig. 2's quantity).
    pub vm_mix: Vec<usize>,
    /// Planner wall time in microseconds (for the §Perf log).
    pub plan_micros: u128,
}

/// The full budget sweep for the three approaches.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub budgets: Vec<f64>,
    pub rows: Vec<ApproachRow>,
}

/// Run Heuristic / MI / MP across `budgets`.
pub fn run_sweep(sys: &System, budgets: &[f64], evaluator: &dyn PlanEvaluator) -> SweepReport {
    let mut rows = Vec::with_capacity(budgets.len() * 3);
    for &b in budgets {
        // Heuristic (Algorithm 1).
        let t0 = std::time::Instant::now();
        let ours = Planner::with_evaluator(sys, evaluator).find(b);
        rows.push(ApproachRow {
            approach: "heuristic",
            budget: b,
            score: ours.score,
            feasible: ours.feasible,
            vm_mix: ours.plan.vm_mix(sys),
            plan_micros: t0.elapsed().as_micros(),
        });
        // Baselines.
        for (name, plan) in [
            ("mi", minimise_individual(sys, b)),
            ("mp", maximise_parallelism(sys, b)),
        ] {
            let t0 = std::time::Instant::now();
            let score = evaluator.eval_plan(sys, &plan);
            let micros = t0.elapsed().as_micros();
            rows.push(ApproachRow {
                approach: name,
                budget: b,
                score,
                feasible: score.satisfies(b),
                vm_mix: plan.vm_mix(sys),
                plan_micros: micros,
            });
        }
    }
    SweepReport { budgets: budgets.to_vec(), rows }
}

impl SweepReport {
    pub fn row(&self, approach: &str, budget: f64) -> Option<&ApproachRow> {
        self.rows
            .iter()
            .find(|r| r.approach == approach && (r.budget - budget).abs() < 1e-9)
    }

    /// Fig. 1: execution time vs budget, one column per approach.
    /// Infeasible cells are flagged with `*` (realized cost exceeds the
    /// budget — the paper plots nothing there).
    pub fn fig1_text(&self) -> String {
        let mut out = String::from(
            "Fig. 1 — Execution times for different approaches\n\
             budget   heuristic        MI               MP\n",
        );
        for &b in &self.budgets {
            out.push_str(&format!("{b:>6} "));
            for a in ["heuristic", "mi", "mp"] {
                let r = self.row(a, b).expect("sweep covers all cells");
                let flag = if r.feasible { ' ' } else { '*' };
                out.push_str(&format!(" {:>9.1}s{flag:<4}", r.score.makespan));
            }
            out.push('\n');
        }
        out.push_str("(* = infeasible: realized cost exceeds the budget)\n");
        out
    }

    /// Fig. 2: number of VMs of each type vs budget, per approach.
    pub fn fig2_text(&self, sys: &System) -> String {
        let mut out = String::from("Fig. 2 — Number of VMs of each type\n");
        for a in ["heuristic", "mi", "mp"] {
            out.push_str(&format!("\n[{a}]\nbudget "));
            for it in &sys.instance_types {
                out.push_str(&format!("{:>6}", format!("it{}", it.id.0 + 1)));
            }
            out.push_str("  total\n");
            for &b in &self.budgets {
                let r = self.row(a, b).expect("cell");
                out.push_str(&format!("{b:>6} "));
                for &n in &r.vm_mix {
                    out.push_str(&format!("{n:>6}"));
                }
                out.push_str(&format!("{:>7}\n", r.vm_mix.iter().sum::<usize>()));
            }
        }
        out
    }

    /// Headline claims (Sec. V-C): average improvement vs MI and MP over
    /// the budgets where the respective pair is feasible, plus the
    /// minimum feasible budget per approach.
    pub fn headline(&self) -> Headline {
        let mut vs_mi = Vec::new();
        let mut vs_mp = Vec::new();
        for &b in &self.budgets {
            let ours = self.row("heuristic", b).unwrap();
            let mi = self.row("mi", b).unwrap();
            let mp = self.row("mp", b).unwrap();
            if ours.feasible && mi.feasible {
                vs_mi.push(stats::improvement_pct(ours.score.makespan, mi.score.makespan));
            }
            if ours.feasible && mp.feasible {
                vs_mp.push(stats::improvement_pct(ours.score.makespan, mp.score.makespan));
            }
        }
        let min_feasible = |a: &str| {
            self.budgets
                .iter()
                .copied()
                .filter(|&b| self.row(a, b).is_some_and(|r| r.feasible))
                .fold(f64::INFINITY, f64::min)
        };
        Headline {
            avg_improvement_vs_mi_pct: stats::mean(&vs_mi),
            avg_improvement_vs_mp_pct: stats::mean(&vs_mp),
            min_feasible_budget_heuristic: min_feasible("heuristic"),
            min_feasible_budget_mi: min_feasible("mi"),
            min_feasible_budget_mp: min_feasible("mp"),
        }
    }

    /// Machine-readable dump (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("budgets", Json::arr(self.budgets.iter().map(|b| Json::num(*b)))),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("approach", Json::str(r.approach)),
                        ("budget", Json::num(r.budget)),
                        ("makespan", Json::num(r.score.makespan)),
                        ("cost", Json::num(r.score.cost)),
                        ("feasible", Json::Bool(r.feasible)),
                        (
                            "vm_mix",
                            Json::arr(r.vm_mix.iter().map(|n| Json::num(*n as f64))),
                        ),
                        ("plan_micros", Json::num(r.plan_micros as f64)),
                    ])
                })),
            ),
        ])
    }
}

/// Sec. V-C headline numbers.
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    pub avg_improvement_vs_mi_pct: f64,
    pub avg_improvement_vs_mp_pct: f64,
    pub min_feasible_budget_heuristic: f64,
    pub min_feasible_budget_mi: f64,
    pub min_feasible_budget_mp: f64,
}

impl Headline {
    pub fn text(&self) -> String {
        format!(
            "Headline (paper Sec. V-C):\n\
             avg improvement vs MI: {:+.1}% (paper: ~13%)\n\
             avg improvement vs MP: {:+.1}% (paper: ~7%)\n\
             min feasible budget  : heuristic {} | MP {} | MI {} \
             (paper: 40 | 45 | 50 — ordering is the reproducible shape)\n",
            self.avg_improvement_vs_mi_pct,
            self.avg_improvement_vs_mp_pct,
            fmt_budget(self.min_feasible_budget_heuristic),
            fmt_budget(self.min_feasible_budget_mp),
            fmt_budget(self.min_feasible_budget_mi),
        )
    }
}

fn fmt_budget(b: f64) -> String {
    if b.is_finite() {
        format!("{b}")
    } else {
        "never".into()
    }
}

/// Convenience used by several binaries: sweep the paper workload with
/// the native evaluator.
pub fn paper_sweep() -> (System, SweepReport) {
    let sys = crate::workload::paper::table1_system(0.0);
    let report = run_sweep(&sys, crate::workload::paper::BUDGETS, &NativeEvaluator);
    (sys, report)
}

/// Extract a plan for inspection (mirrors `run_sweep`'s construction).
pub fn plan_for(sys: &System, approach: &str, budget: f64) -> Plan {
    match approach {
        "heuristic" => Planner::new(sys).find(budget).plan,
        "mi" => minimise_individual(sys, budget),
        "mp" => maximise_parallelism(sys, budget),
        other => panic!("unknown approach {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    fn small_sweep() -> (System, SweepReport) {
        let sys = table1_system(0.0);
        let report = run_sweep(&sys, &[60.0, 80.0], &NativeEvaluator);
        (sys, report)
    }

    #[test]
    fn sweep_has_all_cells() {
        let (_, r) = small_sweep();
        assert_eq!(r.rows.len(), 6);
        for a in ["heuristic", "mi", "mp"] {
            for b in [60.0, 80.0] {
                assert!(r.row(a, b).is_some());
            }
        }
    }

    #[test]
    fn fig_texts_render() {
        let (sys, r) = small_sweep();
        let f1 = r.fig1_text();
        assert!(f1.contains("budget"));
        assert!(f1.lines().count() >= 4);
        let f2 = r.fig2_text(&sys);
        assert!(f2.contains("[heuristic]"));
        assert!(f2.contains("it4"));
    }

    #[test]
    fn json_roundtrip() {
        let (_, r) = small_sweep();
        let text = r.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 6);
    }

    #[test]
    fn headline_computes() {
        let (_, r) = small_sweep();
        let h = r.headline();
        assert!(h.min_feasible_budget_heuristic <= h.min_feasible_budget_mi);
    }
}
