//! Summary statistics for the benchmark harness and EXPERIMENTS.md.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (inputs must be positive); 0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (0..=100) by linear interpolation on sorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Sample standard deviation; 0 when fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentage improvement of `ours` over `other` (positive = ours lower):
/// `(other - ours) / other * 100`.
pub fn improvement_pct(ours: f64, other: f64) -> f64 {
    if other == 0.0 {
        0.0
    } else {
        (other - ours) / other * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(percentile(&xs, 25.0), 20.0);
        assert_eq!(percentile(&xs, 90.0), 46.0);
    }

    #[test]
    fn geomean_of_equal_factors() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn improvement_signs() {
        assert!((improvement_pct(90.0, 100.0) - 10.0).abs() < 1e-12);
        assert!(improvement_pct(110.0, 100.0) < 0.0);
        assert_eq!(improvement_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }
}
