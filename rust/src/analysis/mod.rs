//! Analysis utilities: optimality references, summary statistics and the
//! table/figure rendering used by the benchmark harness.
//!
//! * [`bounds`] — fractional (LP-relaxation) cost/makespan lower bounds
//!   and an exhaustive-search reference for tiny instances;
//! * [`stats`] — mean / median / percentiles / relative improvements;
//! * [`report`] — regenerates the paper's Fig. 1 and Fig. 2 (and the
//!   Table I echo) as text tables + JSON, from live planner runs.

pub mod bounds;
pub mod pareto;
pub mod report;
pub mod stats;

pub use bounds::{brute_force_best, fractional_cost_floor, makespan_floor, spread_makespan_floor};
pub use pareto::{knee, pareto_frontier, ParetoPoint};
pub use report::{
    run_policy_sweep, run_policy_sweep_ctl, run_sweep, run_sweep_threads, ApproachRow,
    SweepReport, CORE_POLICIES,
};
