//! Asynchronous job registry: long-running work (campaigns, sweeps) is
//! submitted, runs on a background thread, and is polled by id — the
//! serving pattern for requests that outlive a single socket
//! round-trip.
//!
//! Protocol surface (see [`super::protocol`]):
//!
//! ```text
//! {"op":"submit","job":{...any plan/sweep/simulate/campaign request...}}
//!   -> {"ok":true,"job_id":"j-3"}
//! {"op":"status","job_id":"j-3"}
//!   -> {"ok":true,"state":"running"} | {"state":"done","result":{...}}
//! {"op":"jobs"}          -> {"ok":true,"jobs":[{"id":..,"state":..},..]}
//! {"op":"cancel","job_id":"j-3"}   (best-effort: marks cancelled;
//!                                   running work is not interrupted)
//! ```

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::Json;

/// Lifecycle of a job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

#[derive(Debug)]
struct Job {
    id: String,
    state: JobState,
    /// The original request line (echoed in listings).
    request_op: String,
    result: Option<Json>,
    error: Option<String>,
}

/// Thread-safe registry of submitted jobs.
#[derive(Debug, Default)]
pub struct JobRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    jobs: HashMap<String, Job>,
    next_id: u64,
    /// Insertion order for stable listings.
    order: Vec<String>,
}

impl JobRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new job; returns its id.
    pub fn create(&self, request_op: &str) -> String {
        let mut g = self.inner.lock().unwrap();
        let id = format!("j-{}", g.next_id);
        g.next_id += 1;
        g.jobs.insert(
            id.clone(),
            Job {
                id: id.clone(),
                state: JobState::Queued,
                request_op: request_op.to_string(),
                result: None,
                error: None,
            },
        );
        g.order.push(id.clone());
        id
    }

    /// Transition to running unless the job was cancelled while queued.
    /// Returns false when the worker should skip the job.
    pub fn start(&self, id: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.jobs.get_mut(id) {
            Some(j) if j.state == JobState::Queued => {
                j.state = JobState::Running;
                true
            }
            _ => false,
        }
    }

    pub fn finish(&self, id: &str, result: Json) {
        let mut g = self.inner.lock().unwrap();
        if let Some(j) = g.jobs.get_mut(id) {
            if j.state == JobState::Running {
                j.state = JobState::Done;
                j.result = Some(result);
            }
        }
    }

    pub fn fail(&self, id: &str, error: String) {
        let mut g = self.inner.lock().unwrap();
        if let Some(j) = g.jobs.get_mut(id) {
            if j.state == JobState::Running || j.state == JobState::Queued {
                j.state = JobState::Failed;
                j.error = Some(error);
            }
        }
    }

    /// Best-effort cancel; returns whether the job existed and was not
    /// yet finished.
    pub fn cancel(&self, id: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.jobs.get_mut(id) {
            Some(j) if matches!(j.state, JobState::Queued | JobState::Running) => {
                j.state = JobState::Cancelled;
                true
            }
            _ => false,
        }
    }

    /// Status object for one job, or None if unknown.
    pub fn status(&self, id: &str) -> Option<Json> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).map(job_json)
    }

    /// Summary list of all jobs (insertion order).
    pub fn list(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::arr(g.order.iter().filter_map(|id| {
            g.jobs.get(id).map(|j| {
                Json::obj(vec![
                    ("id", Json::str(&j.id)),
                    ("op", Json::str(&j.request_op)),
                    ("state", Json::str(j.state.as_str())),
                ])
            })
        }))
    }
}

fn job_json(j: &Job) -> Json {
    let mut fields = vec![
        ("id", Json::str(&j.id)),
        ("op", Json::str(&j.request_op)),
        ("state", Json::str(j.state.as_str())),
    ];
    if let Some(r) = &j.result {
        fields.push(("result", r.clone()));
    }
    if let Some(e) = &j.error {
        fields.push(("error", Json::str(e)));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let r = JobRegistry::new();
        let id = r.create("campaign");
        assert!(r.status(&id).unwrap().get("state").unwrap().as_str() == Some("queued"));
        assert!(r.start(&id));
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("running"));
        r.finish(&id, Json::num(42.0));
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
        assert_eq!(s.get("result").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn cancel_before_start_skips_execution() {
        let r = JobRegistry::new();
        let id = r.create("sweep");
        assert!(r.cancel(&id));
        assert!(!r.start(&id), "cancelled job must not start");
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("cancelled"));
    }

    #[test]
    fn fail_records_error() {
        let r = JobRegistry::new();
        let id = r.create("plan");
        r.start(&id);
        r.fail(&id, "boom".into());
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("failed"));
        assert_eq!(s.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn listing_preserves_order_and_unknown_is_none() {
        let r = JobRegistry::new();
        let a = r.create("plan");
        let b = r.create("campaign");
        let list = r.list();
        let arr = list.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("id").unwrap().as_str(), Some(a.as_str()));
        assert_eq!(arr[1].get("id").unwrap().as_str(), Some(b.as_str()));
        assert!(r.status("j-999").is_none());
    }

    #[test]
    fn finish_after_cancel_is_ignored() {
        let r = JobRegistry::new();
        let id = r.create("x");
        r.start(&id);
        r.cancel(&id);
        r.finish(&id, Json::num(1.0));
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("cancelled"));
    }
}
