//! Job bookkeeping for the sharded [`super::engine::JobEngine`]: states,
//! results, cancellation tokens, progress counters and streaming partial
//! results, polled by id from any connection.
//!
//! The registry is the engine's source of truth — the engine owns the
//! queues and workers, the registry owns everything a client can
//! observe.  Each job carries a [`CancelToken`]; `cancel` both marks the
//! job and fires the token, so running work (campaign replications,
//! sweep cells, FIND iterations) stops cooperatively at its next
//! checkpoint.  Long jobs publish `done/total` progress and append
//! partial result rows that `status` streams back before the job
//! finishes.  Each record also carries the job's queue placement
//! ([`JobPriority`], echoed on `status` when non-default) and its
//! time-in-queue (`queue_wait_ms`, stamped when a worker starts it).
//!
//! Protocol surface (see [`super::protocol`]):
//!
//! ```text
//! {"op":"submit","job":{...any plan/sweep/simulate/campaign request...}}
//!   -> {"ok":true,"job_id":"j-3"}
//! {"op":"status","job_id":"j-3"}
//!   -> {"ok":true,"job":{"state":"running",
//!                        "progress":{"done":5,"total":64},
//!                        "partial_results":[{...},...]}}
//!    | {"ok":true,"job":{"state":"done","result":{...}}}
//! {"op":"jobs"}        -> {"ok":true,"jobs":[{"id":..,"state":..},..]}
//! {"op":"cancel","job_id":"j-3"}   (fires the job's cancel token;
//!                                   running work stops at its next
//!                                   cooperative checkpoint)
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::persist::Journal;
use crate::util::{CancelToken, Json};

use super::engine::JobPriority;

/// Partial-result rows retained per job (older rows are dropped first;
/// the drop count is reported so clients can detect truncation).
const MAX_PARTIALS: usize = 1024;

/// Jobs retained in the registry.  Every sync campaign/sweep also
/// creates a job record, so a long-lived coordinator would otherwise
/// grow without bound; once the cap is hit, the oldest *terminal* jobs
/// are evicted (live jobs are never dropped).
const MAX_JOBS: usize = 1024;

/// Lifecycle of a job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Whether the state is terminal (no further transitions).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

#[derive(Debug)]
struct Job {
    id: String,
    state: JobState,
    /// The original request line (echoed in listings).
    request_op: String,
    result: Option<Json>,
    error: Option<String>,
    /// Cooperative cancellation handle shared with the running work.
    cancel: CancelToken,
    /// `(done, total)` units of work, published by the job itself.
    progress: Option<(u64, u64)>,
    /// Streaming partial results (capped at [`MAX_PARTIALS`]).
    partials: VecDeque<Json>,
    /// Rows dropped from the front of `partials` once the cap was hit.
    partials_dropped: u64,
    /// Queue placement the job was admitted with (surfaced on `status`
    /// when it differs from the all-defaults legacy shape).
    priority: JobPriority,
    /// When the job was admitted to its shard queue.
    queued_at: Instant,
    /// Time spent queued before a worker picked the job up (stamped by
    /// [`JobRegistry::start`], surfaced as `queue_wait_ms` on `status`).
    queue_wait: Option<Duration>,
}

/// Thread-safe registry of submitted jobs.
#[derive(Debug, Default)]
pub struct JobRegistry {
    inner: Mutex<RegistryInner>,
    /// Signalled on every terminal transition (see [`wait_terminal`]).
    ///
    /// [`wait_terminal`]: Self::wait_terminal
    terminal: Condvar,
    /// Optional write-through journal (see [`crate::persist`]).  The
    /// registry decides each transition under its own lock and writes
    /// the journal record *after* releasing it — transitions are
    /// once-guarded, so no duplicate records, and the registry lock is
    /// never held across journal IO.
    journal: OnceLock<Arc<Journal>>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    jobs: HashMap<String, Job>,
    next_id: u64,
    /// Insertion order for stable listings.
    order: VecDeque<String>,
}

impl JobRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the write-through journal (once, at server startup,
    /// before any traffic).  Later calls are ignored.
    pub fn attach_journal(&self, journal: Arc<Journal>) {
        let _ = self.journal.set(journal);
    }

    /// The attached journal, if any.
    pub fn journal(&self) -> Option<&Arc<Journal>> {
        self.journal.get()
    }

    /// Ensure future generated ids start at `next` or later.  Replay
    /// only: recovered jobs keep their pre-crash ids, so the generator
    /// must skip past them.
    pub fn reserve_ids(&self, next: u64) {
        let mut g = self.inner.lock().unwrap();
        g.next_id = g.next_id.max(next);
    }

    /// Register a new job; returns its id.
    pub fn create(&self, request_op: &str) -> String {
        self.create_with(request_op, JobPriority::default())
    }

    /// [`create`](Self::create) with an explicit queue placement.
    pub fn create_with(&self, request_op: &str, priority: JobPriority) -> String {
        let mut g = self.inner.lock().unwrap();
        let id = format!("j-{}", g.next_id);
        g.next_id += 1;
        g.jobs.insert(
            id.clone(),
            Job {
                id: id.clone(),
                state: JobState::Queued,
                request_op: request_op.to_string(),
                result: None,
                error: None,
                cancel: CancelToken::new(),
                progress: None,
                partials: VecDeque::new(),
                partials_dropped: 0,
                priority,
                queued_at: Instant::now(),
                queue_wait: None,
            },
        );
        g.order.push_back(id.clone());
        Self::evict_capped(&mut g, self.journal.get());
        id
    }

    /// Bound the registry: evict the oldest *terminal* jobs past the
    /// cap, skipping over live ones (a long-running job at the head
    /// must neither be dropped nor shield everything behind it from
    /// eviction).  The listing stays in insertion order.  Evicted jobs
    /// are also dropped from the journal's replay index
    /// ([`Journal::forget`] is index-only, no IO, so calling it under
    /// the registry lock is fine) — a long-running coordinator's replay
    /// map, and after compaction its journal file, stays bounded by
    /// this same cap.
    fn evict_capped(g: &mut RegistryInner, journal: Option<&Arc<Journal>>) {
        if g.order.len() <= MAX_JOBS {
            return;
        }
        let mut excess = g.order.len() - MAX_JOBS;
        let RegistryInner { jobs, order, .. } = g;
        order.retain(|jid| {
            if excess == 0 {
                return true;
            }
            if jobs.get(jid).is_some_and(|j| !j.state.is_terminal()) {
                return true; // live: never evicted
            }
            jobs.remove(jid);
            if let Some(jr) = journal {
                jr.forget(jid);
            }
            excess -= 1;
            false
        });
    }

    /// Re-register a recovered job under its pre-crash id, queued for
    /// re-execution.  Replay only — ordinary admission goes through
    /// [`create_with`](Self::create_with).
    pub fn restore(&self, id: &str, request_op: &str, priority: JobPriority) {
        let mut g = self.inner.lock().unwrap();
        g.jobs.insert(
            id.to_string(),
            Job {
                id: id.to_string(),
                state: JobState::Queued,
                request_op: request_op.to_string(),
                result: None,
                error: None,
                cancel: CancelToken::new(),
                progress: None,
                partials: VecDeque::new(),
                partials_dropped: 0,
                priority,
                queued_at: Instant::now(),
                queue_wait: None,
            },
        );
        g.order.push_back(id.to_string());
        Self::evict_capped(&mut g, self.journal.get());
    }

    /// Re-register a recovered job directly in its terminal state, so
    /// its pre-crash result (or error) is servable from `status`
    /// without re-running anything.  Replay only.
    pub fn install_terminal(
        &self,
        id: &str,
        request_op: &str,
        priority: JobPriority,
        state: JobState,
        result: Option<Json>,
        error: Option<String>,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.jobs.insert(
            id.to_string(),
            Job {
                id: id.to_string(),
                state,
                request_op: request_op.to_string(),
                result,
                error,
                cancel: CancelToken::new(),
                progress: None,
                partials: VecDeque::new(),
                partials_dropped: 0,
                priority,
                queued_at: Instant::now(),
                queue_wait: None,
            },
        );
        g.order.push_back(id.to_string());
        Self::evict_capped(&mut g, self.journal.get());
    }

    /// The job's cancellation token (a clone sharing the same flag).
    pub fn token(&self, id: &str) -> Option<CancelToken> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).map(|j| j.cancel.clone())
    }

    /// Forget a job that was never admitted (the engine rejected it at
    /// the backlog bound): removes the record and its listing entry so
    /// rejected traffic cannot grow the registry.  The discarded id is
    /// always the most recently created, so the listing scan is O(1)
    /// from the back.
    pub fn discard(&self, id: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.jobs.remove(id).is_some() {
            if let Some(pos) = g.order.iter().rposition(|x| x == id) {
                g.order.remove(pos);
            }
        }
    }

    /// Transition to running unless the job was cancelled while queued.
    /// Returns false when the worker should skip the job.  Stamps the
    /// job's time-in-queue on the successful transition.
    pub fn start(&self, id: &str) -> bool {
        let started = {
            let mut g = self.inner.lock().unwrap();
            match g.jobs.get_mut(id) {
                Some(j) if j.state == JobState::Queued => {
                    j.state = JobState::Running;
                    j.queue_wait = Some(j.queued_at.elapsed());
                    true
                }
                _ => false,
            }
        };
        if started {
            if let Some(jr) = self.journal.get() {
                jr.record_start(id);
            }
        }
        started
    }

    /// Time the job spent queued before starting (None while queued).
    pub fn queue_wait(&self, id: &str) -> Option<Duration> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).and_then(|j| j.queue_wait)
    }

    pub fn finish(&self, id: &str, result: Json) {
        let journal = self.journal.get();
        // The result is cloned for the journal inside the critical
        // section (only when a journal is attached) so eviction cannot
        // race a re-read of the stored copy.
        let journal_copy = {
            let mut g = self.inner.lock().unwrap();
            match g.jobs.get_mut(id) {
                Some(j) if j.state == JobState::Running => {
                    let copy = journal.map(|_| result.clone());
                    j.state = JobState::Done;
                    j.result = Some(result);
                    self.terminal.notify_all();
                    copy
                }
                _ => None,
            }
        };
        if let (Some(jr), Some(copy)) = (journal, journal_copy) {
            jr.record_terminal(id, JobState::Done.as_str(), Some(&copy), None);
        }
    }

    pub fn fail(&self, id: &str, error: String) {
        let failed = {
            let mut g = self.inner.lock().unwrap();
            match g.jobs.get_mut(id) {
                Some(j) if j.state == JobState::Running || j.state == JobState::Queued => {
                    j.state = JobState::Failed;
                    j.error = Some(error.clone());
                    self.terminal.notify_all();
                    true
                }
                _ => false,
            }
        };
        if failed {
            if let Some(jr) = self.journal.get() {
                jr.record_terminal(id, JobState::Failed.as_str(), None, Some(&error));
            }
        }
    }

    /// Abort a live job: mark it failed with `error` *and* fire its
    /// [`CancelToken`] so running work stops at its next checkpoint.
    /// Used by the deadline sweeper / shed path and the stuck-worker
    /// watchdog, where "failed with a reason" is the honest state (a
    /// `cancel` is something the client asked for; an abort is not).
    /// Returns whether the job was live.
    pub fn abort(&self, id: &str, error: String) -> bool {
        let aborted = {
            let mut g = self.inner.lock().unwrap();
            match g.jobs.get_mut(id) {
                Some(j) if matches!(j.state, JobState::Queued | JobState::Running) => {
                    j.state = JobState::Failed;
                    j.error = Some(error.clone());
                    j.cancel.cancel();
                    self.terminal.notify_all();
                    true
                }
                _ => false,
            }
        };
        if aborted {
            if let Some(jr) = self.journal.get() {
                jr.record_terminal(id, JobState::Failed.as_str(), None, Some(&error));
            }
        }
        aborted
    }

    /// Ids of running jobs whose binding deadline (admission time +
    /// `deadline_ms`) has passed — the deadline sweeper's work list.
    pub fn running_deadline_expired(&self) -> Vec<String> {
        let now = Instant::now();
        let g = self.inner.lock().unwrap();
        g.jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .filter(|j| {
                j.priority
                    .deadline_ms
                    .is_some_and(|ms| now.duration_since(j.queued_at) >= Duration::from_millis(ms))
            })
            .map(|j| j.id.clone())
            .collect()
    }

    /// Cancel a job: marks it cancelled *and* fires its [`CancelToken`],
    /// so running work stops at its next cooperative checkpoint.
    /// Returns whether the job existed and was not yet finished.
    pub fn cancel(&self, id: &str) -> bool {
        let cancelled = {
            let mut g = self.inner.lock().unwrap();
            match g.jobs.get_mut(id) {
                Some(j) if matches!(j.state, JobState::Queued | JobState::Running) => {
                    j.state = JobState::Cancelled;
                    j.cancel.cancel();
                    self.terminal.notify_all();
                    true
                }
                _ => false,
            }
        };
        if cancelled {
            if let Some(jr) = self.journal.get() {
                jr.record_cancel(id);
            }
        }
        cancelled
    }

    /// Cancel every queued or running job (server shutdown).
    pub fn cancel_all(&self) {
        let cancelled: Vec<String> = {
            let mut g = self.inner.lock().unwrap();
            let mut ids = Vec::new();
            for j in g.jobs.values_mut() {
                if matches!(j.state, JobState::Queued | JobState::Running) {
                    j.state = JobState::Cancelled;
                    j.cancel.cancel();
                    ids.push(j.id.clone());
                }
            }
            self.terminal.notify_all();
            ids
        };
        if let Some(jr) = self.journal.get() {
            for id in &cancelled {
                jr.record_cancel(id);
            }
        }
    }

    /// Publish `done/total` progress for a running job.  `done` is
    /// monotonic for a fixed `total`: parallel publishers can deliver
    /// out of order, and a stale lower count must not make observed
    /// progress regress.  Ignored once the job reached a terminal state.
    pub fn set_progress(&self, id: &str, done: u64, total: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(j) = g.jobs.get_mut(id) {
            if !j.state.is_terminal() {
                j.progress = match j.progress {
                    Some((prev, t)) if t == total => Some((prev.max(done), total)),
                    _ => Some((done, total)),
                };
            }
        }
    }

    /// Append one streaming partial-result row (e.g. a finished campaign
    /// replication or sweep cell).  Rows beyond [`MAX_PARTIALS`] evict
    /// the oldest; ignored once the job reached a terminal state.
    pub fn push_partial(&self, id: &str, row: Json) {
        let mut g = self.inner.lock().unwrap();
        if let Some(j) = g.jobs.get_mut(id) {
            if !j.state.is_terminal() {
                if j.partials.len() >= MAX_PARTIALS {
                    j.partials.pop_front();
                    j.partials_dropped += 1;
                }
                j.partials.push_back(row);
            }
        }
    }

    /// Current state of one job, or None if unknown.
    pub fn state(&self, id: &str) -> Option<JobState> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).map(|j| j.state.clone())
    }

    /// Block until the job reaches a terminal state (or `timeout`
    /// expires); returns the state last observed.  None for unknown ids.
    pub fn wait_terminal(&self, id: &str, timeout: Duration) -> Option<JobState> {
        self.wait_outcome(id, timeout).map(|(state, _, _)| state)
    }

    /// [`wait_terminal`](Self::wait_terminal) that also captures the
    /// result/error *in the same critical section* as the terminal
    /// observation — a sync waiter is therefore immune to the registry
    /// evicting the (terminal) job between its wake-up and a separate
    /// result lookup.
    #[allow(clippy::type_complexity)]
    pub fn wait_outcome(
        &self,
        id: &str,
        timeout: Duration,
    ) -> Option<(JobState, Option<Json>, Option<String>)> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            let job = g.jobs.get(id)?;
            if job.state.is_terminal() {
                return Some((job.state.clone(), job.result.clone(), job.error.clone()));
            }
            let state = job.state.clone();
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                return Some((state, None, None));
            }
            let (guard, _) = self.terminal.wait_timeout(g, left).unwrap();
            g = guard;
        }
    }

    /// Status object for one job, or None if unknown.
    pub fn status(&self, id: &str) -> Option<Json> {
        self.status_from(id, 0)
    }

    /// [`status`](Self::status) with a streaming cursor: only partial
    /// rows with absolute index `>= from` are included (absolute = as
    /// published, counting evicted rows; the reply's `partials_next`
    /// says what to pass next time, so pollers receive each row once
    /// instead of the whole backlog on every poll).
    pub fn status_from(&self, id: &str, from: u64) -> Option<Json> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).map(|j| job_json(j, from))
    }

    /// The stored result of a finished job (None unless `Done`).
    pub fn result(&self, id: &str) -> Option<Json> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).and_then(|j| j.result.clone())
    }

    /// The stored error of a failed job (None unless `Failed`).
    pub fn error(&self, id: &str) -> Option<String> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(id).and_then(|j| j.error.clone())
    }

    /// Summary list of all jobs (insertion order).
    pub fn list(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::arr(g.order.iter().filter_map(|id| {
            g.jobs.get(id).map(|j| {
                let mut fields = vec![
                    ("id", Json::str(&j.id)),
                    ("op", Json::str(&j.request_op)),
                    ("state", Json::str(j.state.as_str())),
                ];
                if let Some((done, total)) = j.progress {
                    fields.push(("progress", progress_json(done, total)));
                }
                Json::obj(fields)
            })
        }))
    }
}

fn progress_json(done: u64, total: u64) -> Json {
    Json::obj(vec![
        ("done", Json::num(done as f64)),
        ("total", Json::num(total as f64)),
    ])
}

fn job_json(j: &Job, from: u64) -> Json {
    let mut fields = vec![
        ("id", Json::str(&j.id)),
        ("op", Json::str(&j.request_op)),
        ("state", Json::str(j.state.as_str())),
    ];
    // Non-default queue placement is echoed back; the legacy shape
    // (priority 0, no deadline) stays byte-identical.
    if j.priority.priority != 0 {
        fields.push(("priority", Json::num(f64::from(j.priority.priority))));
    }
    if let Some(ms) = j.priority.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    if let Some(wait) = j.queue_wait {
        fields.push(("queue_wait_ms", Json::num(wait.as_secs_f64() * 1e3)));
    }
    if let Some((done, total)) = j.progress {
        fields.push(("progress", progress_json(done, total)));
    }
    // Row k of the retained deque has absolute index dropped + k; the
    // cursor selects rows with absolute index >= from.
    let published = j.partials_dropped + j.partials.len() as u64;
    let skip = from.saturating_sub(j.partials_dropped).min(j.partials.len() as u64) as usize;
    if j.partials.len() > skip {
        fields.push((
            "partial_results",
            Json::arr(j.partials.iter().skip(skip).cloned()),
        ));
    }
    if published > 0 {
        // What to pass as the next poll's cursor (and a truncation
        // signal: rows below partials_dropped are gone for good).
        fields.push(("partials_next", Json::num(published as f64)));
        if j.partials_dropped > 0 {
            fields.push(("partials_dropped", Json::num(j.partials_dropped as f64)));
        }
    }
    if let Some(r) = &j.result {
        fields.push(("result", r.clone()));
    }
    if let Some(e) = &j.error {
        fields.push(("error", Json::str(e)));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let r = JobRegistry::new();
        let id = r.create("campaign");
        assert!(r.status(&id).unwrap().get("state").unwrap().as_str() == Some("queued"));
        assert!(r.start(&id));
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("running"));
        r.finish(&id, Json::num(42.0));
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
        assert_eq!(s.get("result").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn cancel_before_start_skips_execution_and_fires_token() {
        let r = JobRegistry::new();
        let id = r.create("sweep");
        let token = r.token(&id).unwrap();
        assert!(!token.is_cancelled());
        assert!(r.cancel(&id));
        assert!(token.is_cancelled(), "cancel must fire the job's token");
        assert!(!r.start(&id), "cancelled job must not start");
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("cancelled"));
    }

    #[test]
    fn fail_records_error() {
        let r = JobRegistry::new();
        let id = r.create("plan");
        r.start(&id);
        r.fail(&id, "boom".into());
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("failed"));
        assert_eq!(s.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn listing_preserves_order_and_unknown_is_none() {
        let r = JobRegistry::new();
        let a = r.create("plan");
        let b = r.create("campaign");
        let list = r.list();
        let arr = list.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("id").unwrap().as_str(), Some(a.as_str()));
        assert_eq!(arr[1].get("id").unwrap().as_str(), Some(b.as_str()));
        assert!(r.status("j-999").is_none());
        assert!(r.token("j-999").is_none());
    }

    #[test]
    fn finish_after_cancel_is_ignored() {
        let r = JobRegistry::new();
        let id = r.create("x");
        r.start(&id);
        r.cancel(&id);
        r.finish(&id, Json::num(1.0));
        assert_eq!(r.status(&id).unwrap().get("state").unwrap().as_str(), Some("cancelled"));
    }

    #[test]
    fn progress_and_partials_stream_through_status() {
        let r = JobRegistry::new();
        let id = r.create("campaign");
        r.start(&id);
        r.set_progress(&id, 2, 8);
        r.push_partial(&id, Json::num(1.0));
        r.push_partial(&id, Json::num(2.0));
        let s = r.status(&id).unwrap();
        assert_eq!(s.path(&["progress", "done"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(s.path(&["progress", "total"]).unwrap().as_f64(), Some(8.0));
        assert_eq!(s.get("partial_results").unwrap().as_arr().unwrap().len(), 2);
        // Terminal jobs stop accepting updates.
        r.finish(&id, Json::Bool(true));
        r.set_progress(&id, 9, 9);
        r.push_partial(&id, Json::num(3.0));
        let s = r.status(&id).unwrap();
        assert_eq!(s.path(&["progress", "done"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("partial_results").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn partials_cap_evicts_oldest() {
        let r = JobRegistry::new();
        let id = r.create("sweep");
        r.start(&id);
        for i in 0..(MAX_PARTIALS + 3) {
            r.push_partial(&id, Json::num(i as f64));
        }
        let s = r.status(&id).unwrap();
        let rows = s.get("partial_results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), MAX_PARTIALS);
        assert_eq!(rows[0].as_f64(), Some(3.0), "oldest rows evicted first");
        assert_eq!(s.get("partials_dropped").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn wait_terminal_wakes_on_finish() {
        let r = std::sync::Arc::new(JobRegistry::new());
        let id = r.create("plan");
        r.start(&id);
        let (r2, id2) = (std::sync::Arc::clone(&r), id.clone());
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            r2.finish(&id2, Json::Bool(true));
        });
        let state = r.wait_terminal(&id, Duration::from_secs(5)).unwrap();
        assert_eq!(state, JobState::Done);
        h.join().unwrap();
        // Unknown ids return None; a pending job returns its live state
        // on timeout.
        assert!(r.wait_terminal("j-999", Duration::from_millis(1)).is_none());
        let pending = r.create("plan");
        assert_eq!(
            r.wait_terminal(&pending, Duration::from_millis(10)),
            Some(JobState::Queued)
        );
    }

    #[test]
    fn registry_evicts_oldest_terminal_jobs_past_the_cap() {
        let r = JobRegistry::new();
        // A live job at the front is skipped by eviction, never dropped
        // — and does not shield the terminal jobs behind it.
        let live = r.create("long");
        r.start(&live);
        for _ in 0..(MAX_JOBS + 5) {
            let id = r.create("quick");
            r.start(&id);
            r.finish(&id, Json::Bool(true));
        }
        assert!(r.status(&live).is_some(), "live job must never be evicted");
        assert_eq!(
            r.list().as_arr().unwrap().len(),
            MAX_JOBS,
            "terminal jobs behind the live head keep the registry at the cap"
        );
        r.finish(&live, Json::Bool(true));
        // Now terminal, the old head is the next eviction victim.
        let id = r.create("one-more");
        assert_eq!(r.list().as_arr().unwrap().len(), MAX_JOBS);
        assert!(r.status(&id).is_some());
        assert!(r.status(&live).is_none(), "oldest terminal head evicted");
    }

    #[test]
    fn status_cursor_returns_only_new_partials() {
        let r = JobRegistry::new();
        let id = r.create("campaign");
        r.start(&id);
        for i in 0..5 {
            r.push_partial(&id, Json::num(i as f64));
        }
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("partial_results").unwrap().as_arr().unwrap().len(), 5);
        let next = s.get("partials_next").unwrap().as_u64().unwrap();
        assert_eq!(next, 5);
        // Poll again from the cursor: nothing new yet.
        let s = r.status_from(&id, next).unwrap();
        assert!(s.get("partial_results").is_none());
        assert_eq!(s.get("partials_next").unwrap().as_u64(), Some(5));
        // Two more rows: only they come back.
        r.push_partial(&id, Json::num(5.0));
        r.push_partial(&id, Json::num(6.0));
        let s = r.status_from(&id, next).unwrap();
        let rows = s.get("partial_results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_f64(), Some(5.0));
        assert_eq!(s.get("partials_next").unwrap().as_u64(), Some(7));
        // A cursor below the evicted range just returns what is retained.
        let s = r.status_from(&id, 0).unwrap();
        assert_eq!(s.get("partial_results").unwrap().as_arr().unwrap().len(), 7);
    }

    #[test]
    fn start_stamps_queue_wait_and_status_reports_it() {
        let r = JobRegistry::new();
        let id = r.create("plan");
        assert!(r.queue_wait(&id).is_none(), "no wait before start");
        let s = r.status(&id).unwrap();
        assert!(s.get("queue_wait_ms").is_none());
        assert!(s.get("priority").is_none(), "default placement stays implicit");
        std::thread::sleep(Duration::from_millis(5));
        assert!(r.start(&id));
        let wait = r.queue_wait(&id).expect("stamped at start");
        assert!(wait >= Duration::from_millis(4), "{wait:?}");
        let ms = r.status(&id).unwrap().get("queue_wait_ms").unwrap().as_f64().unwrap();
        assert!(ms >= 4.0, "{ms}");
    }

    #[test]
    fn non_default_placement_is_echoed_on_status() {
        let r = JobRegistry::new();
        let id = r.create_with("sweep", JobPriority::new(7).with_deadline_ms(1500));
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("priority").unwrap().as_f64(), Some(7.0));
        assert_eq!(s.get("deadline_ms").unwrap().as_f64(), Some(1500.0));
    }

    #[test]
    fn discard_forgets_an_unadmitted_job() {
        let r = JobRegistry::new();
        let keep = r.create("plan");
        let reject = r.create("sweep");
        r.discard(&reject);
        assert!(r.status(&reject).is_none());
        assert!(r.token(&reject).is_none());
        let list = r.list();
        let arr = list.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("id").unwrap().as_str(), Some(keep.as_str()));
        // Discarding twice (or an unknown id) is a no-op.
        r.discard(&reject);
        r.discard("j-999");
    }

    #[test]
    fn restore_and_install_terminal_recreate_pre_crash_jobs() {
        let r = JobRegistry::new();
        r.reserve_ids(5);
        r.restore("j-2", "campaign", JobPriority::new(1));
        r.install_terminal(
            "j-3",
            "plan",
            JobPriority::default(),
            JobState::Done,
            Some(Json::num(7.0)),
            None,
        );
        assert_eq!(r.state("j-2"), Some(JobState::Queued));
        let s = r.status("j-3").unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
        assert_eq!(s.get("result").unwrap().as_f64(), Some(7.0));
        // Fresh ids skip past the reserved range (no collision with
        // recovered jobs).
        assert_eq!(r.create("plan"), "j-5");
    }

    #[test]
    fn abort_fails_the_job_and_fires_its_token() {
        let r = JobRegistry::new();
        let id = r.create("campaign");
        r.start(&id);
        let token = r.token(&id).unwrap();
        assert!(r.abort(&id, "deadline_exceeded: too slow".into()));
        assert!(token.is_cancelled(), "abort must fire the token");
        let s = r.status(&id).unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("failed"));
        assert_eq!(s.get("error").unwrap().as_str(), Some("deadline_exceeded: too slow"));
        assert!(!r.abort(&id, "again".into()), "terminal jobs cannot be aborted");
    }

    #[test]
    fn running_deadline_expired_lists_only_overdue_running_jobs() {
        let r = JobRegistry::new();
        let overdue = r.create_with("plan", JobPriority::new(0).with_deadline_ms(1));
        r.start(&overdue);
        let future = r.create_with("plan", JobPriority::new(0).with_deadline_ms(60_000));
        r.start(&future);
        // Queued (not running) and deadline-less jobs are never listed.
        let _queued = r.create_with("plan", JobPriority::new(0).with_deadline_ms(1));
        let relaxed = r.create("plan");
        r.start(&relaxed);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(r.running_deadline_expired(), vec![overdue]);
    }

    #[test]
    fn cancel_all_fires_every_live_token() {
        let r = JobRegistry::new();
        let a = r.create("plan");
        let b = r.create("sweep");
        r.start(&a);
        let done = r.create("x");
        r.start(&done);
        r.finish(&done, Json::Bool(true));
        r.cancel_all();
        assert_eq!(r.state(&a), Some(JobState::Cancelled));
        assert_eq!(r.state(&b), Some(JobState::Cancelled));
        assert_eq!(r.state(&done), Some(JobState::Done), "finished jobs untouched");
        assert!(r.token(&a).unwrap().is_cancelled());
        assert!(r.token(&b).unwrap().is_cancelled());
    }
}
