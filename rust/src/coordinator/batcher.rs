//! Dynamic batching in front of the XLA evaluator.
//!
//! The artifact scores `K` candidates per execution no matter how many
//! are real (the shape is static), so concurrent planner threads each
//! scoring a handful of REPLACE candidates waste most of the batch.  The
//! [`BatchingEvaluator`] runs a background worker that drains queued
//! scoring requests, packs as many candidates as fit into one artifact
//! call, executes once, and distributes the scores back — the same
//! dynamic-batching move serving systems make for GPU inference, applied
//! to plan scoring.
//!
//! Requests block on a condvar until their scores arrive; the worker
//! waits up to `max_wait` for more work to coalesce once it has at least
//! one request (cap `K` candidates per execution).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::Metrics;
use crate::eval::{EvalBatch, PlanEvaluator};
use crate::model::PlanScore;

struct Job {
    batch: EvalBatch,
    reply: Arc<(Mutex<Option<Vec<PlanScore>>>, Condvar)>,
}

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    signal: Condvar,
}

/// A [`PlanEvaluator`] that coalesces concurrent scoring requests into
/// larger executions on the wrapped evaluator.
pub struct BatchingEvaluator {
    queue: Arc<Queue>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl BatchingEvaluator {
    /// `chunk` should match the artifact's K; `max_wait` bounds the extra
    /// latency spent waiting for co-batchable work.
    pub fn new(
        inner: Arc<dyn PlanEvaluator>,
        chunk: usize,
        max_wait: Duration,
        metrics: Arc<Metrics>,
    ) -> Self {
        let queue = Arc::new(Queue { jobs: Mutex::new(VecDeque::new()), signal: Condvar::new() });
        let stop = Arc::new(AtomicBool::new(false));
        let worker = {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || Self::worker_loop(queue, stop, inner, chunk, max_wait, metrics))
        };
        Self { queue, stop, worker: Some(worker) }
    }

    fn worker_loop(
        queue: Arc<Queue>,
        stop: Arc<AtomicBool>,
        inner: Arc<dyn PlanEvaluator>,
        chunk: usize,
        max_wait: Duration,
        metrics: Arc<Metrics>,
    ) {
        loop {
            // Wait for at least one job.
            let mut jobs = queue.jobs.lock().unwrap();
            while jobs.is_empty() {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                let (guard, _timeout) =
                    queue.signal.wait_timeout(jobs, Duration::from_millis(50)).unwrap();
                jobs = guard;
            }
            // Linger briefly for co-batchable work, then drain up to
            // `chunk` candidates' worth of jobs.
            if !max_wait.is_zero() {
                let deadline = std::time::Instant::now() + max_wait;
                loop {
                    let queued: usize = jobs.iter().map(|j| j.batch.len()).sum();
                    let now = std::time::Instant::now();
                    if queued >= chunk || now >= deadline {
                        break;
                    }
                    let (guard, _t) = queue.signal.wait_timeout(jobs, deadline - now).unwrap();
                    jobs = guard;
                }
            }
            let mut taken: Vec<Job> = Vec::new();
            let mut n_candidates = 0usize;
            while let Some(job) = jobs.front() {
                let n = job.batch.len();
                if !taken.is_empty() && n_candidates + n > chunk {
                    break;
                }
                n_candidates += n;
                taken.push(jobs.pop_front().unwrap());
            }
            drop(jobs);

            if taken.is_empty() {
                continue;
            }
            // Merge into one super-batch (environments must agree; jobs
            // with a different environment are evaluated separately).
            let mergeable = taken
                .iter()
                .all(|j| env_key(&j.batch) == env_key(&taken[0].batch));
            if mergeable && taken.len() > 1 {
                let mut merged = EvalBatch {
                    candidates: Vec::with_capacity(n_candidates),
                    ..taken[0].batch.clone()
                };
                for j in &taken {
                    merged.candidates.extend(j.batch.candidates.iter().cloned());
                }
                metrics.record_eval_batch(merged.len());
                let scores = inner.eval_batch(&merged);
                let mut off = 0usize;
                for j in taken {
                    let n = j.batch.len();
                    deliver(&j, scores[off..off + n].to_vec());
                    off += n;
                }
            } else {
                for j in taken {
                    metrics.record_eval_batch(j.batch.len());
                    let scores = inner.eval_batch(&j.batch);
                    deliver(&j, scores);
                }
            }
        }
    }
}

fn env_key(b: &EvalBatch) -> (u64, u64, u8, usize) {
    (
        b.overhead.to_bits(),
        b.hour.to_bits(),
        matches!(b.billing, crate::model::BillingPolicy::PerSecond) as u8,
        b.n_apps,
    )
}

fn deliver(job: &Job, scores: Vec<PlanScore>) {
    let (lock, cv) = &*job.reply;
    *lock.lock().unwrap() = Some(scores);
    cv.notify_one();
}

impl PlanEvaluator for BatchingEvaluator {
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore> {
        if batch.is_empty() {
            return Vec::new();
        }
        let reply = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut jobs = self.queue.jobs.lock().unwrap();
            jobs.push_back(Job { batch: batch.clone(), reply: Arc::clone(&reply) });
        }
        self.queue.signal.notify_all();
        let (lock, cv) = &*reply;
        let mut guard = lock.lock().unwrap();
        while guard.is_none() {
            guard = cv.wait(guard).unwrap();
        }
        guard.take().unwrap()
    }

    fn name(&self) -> &'static str {
        "batching"
    }
}

impl Drop for BatchingEvaluator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.queue.signal.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;
    use crate::scheduler::maximise_parallelism;
    use crate::workload::paper::table1_system;

    #[test]
    fn scores_match_inner_evaluator() {
        let metrics = Arc::new(Metrics::new());
        let be = BatchingEvaluator::new(
            Arc::new(NativeEvaluator),
            64,
            Duration::ZERO,
            Arc::clone(&metrics),
        );
        let sys = table1_system(0.0);
        let plan = maximise_parallelism(&sys, 60.0);
        let direct = NativeEvaluator.eval_plan(&sys, &plan);
        let batched = be.eval_plan(&sys, &plan);
        assert_eq!(direct.makespan, batched.makespan);
        assert_eq!(direct.cost, batched.cost);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let metrics = Arc::new(Metrics::new());
        let be = Arc::new(BatchingEvaluator::new(
            Arc::new(NativeEvaluator),
            64,
            Duration::from_millis(20),
            Arc::clone(&metrics),
        ));
        let sys = Arc::new(table1_system(0.0));
        let mut handles = Vec::new();
        for i in 0..8 {
            let be = Arc::clone(&be);
            let sys = Arc::clone(&sys);
            handles.push(std::thread::spawn(move || {
                let plan = maximise_parallelism(&sys, 40.0 + i as f64 * 5.0);
                be.eval_plan(&sys, &plan)
            }));
        }
        let scores: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(scores.len(), 8);
        let snap = metrics.snapshot();
        let batches = snap.get("eval_batches").unwrap().as_f64().unwrap();
        let cands = snap.get("eval_candidates").unwrap().as_f64().unwrap();
        assert_eq!(cands, 8.0);
        assert!(batches <= 8.0);
        assert!(batches >= 1.0);
    }

    #[test]
    fn empty_batch_short_circuits() {
        let metrics = Arc::new(Metrics::new());
        let be = BatchingEvaluator::new(
            Arc::new(NativeEvaluator),
            64,
            Duration::ZERO,
            metrics,
        );
        let sys = table1_system(0.0);
        let batch = EvalBatch::new(&sys);
        assert!(be.eval_batch(&batch).is_empty());
    }
}
