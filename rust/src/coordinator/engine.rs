//! The sharded job engine: a bounded worker pool that executes every
//! coordinator job (async `submit` jobs *and* the synchronous heavy ops,
//! which block their connection on [`JobEngine::run_sync`]).
//!
//! Architecture:
//!
//! * **N shards, N workers.**  A job's id hashes (FNV-1a) onto a shard
//!   queue; each shard has one dedicated worker.  Shard queues are FIFO,
//!   so two jobs landing on the same shard start in submission order.
//! * **Work stealing.**  An idle worker whose own queue is empty pops
//!   the front of the next non-empty shard (round-robin scan), so one
//!   slow shard never strands queued work while other workers idle.
//!   Stealing pops from the front — per-shard FIFO start order holds
//!   regardless of who executes the job.
//! * **Bounded concurrency.**  At most N jobs run at once; everything
//!   else queues.  This replaces the historical thread-per-job
//!   `std::thread::spawn` in the submit path, which let one burst of
//!   campaign submissions fork an unbounded number of OS threads.
//! * **Cooperative cancellation.**  Every job carries a
//!   [`CancelToken`] (owned by the [`JobRegistry`]); `cancel` fires it
//!   and the running work stops at its next checkpoint (campaign
//!   replication / round boundary, sweep cell, FIND iteration).
//!   Cancelled-while-queued jobs are skipped when popped.
//! * **Progress + partial results.**  The [`JobCtl`] handle given to
//!   each job publishes `done/total` counters and streaming partial
//!   rows into the registry, pollable via the `status` op while the job
//!   runs.
//!
//! The engine is transport-agnostic: jobs are plain `FnOnce(&JobCtl) ->
//! Result<Json, String>` closures, so the protocol layer, tests and
//! benches submit work directly.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::{CancelToken, Json};

use super::state::{JobRegistry, JobState};
use super::Metrics;

/// A unit of work: runs on a pool worker, returns the job's result body
/// or an error string.  Long jobs should poll `ctl` for cancellation and
/// publish progress through it.
pub type JobFn = Box<dyn FnOnce(&JobCtl) -> Result<Json, String> + Send + 'static>;

/// Upper bound a synchronous caller waits for its own job (effectively
/// "until done" — campaigns and sweeps finish far sooner; the bound only
/// guards against a wedged worker).
const SYNC_WAIT: Duration = Duration::from_secs(3600);

/// Per-job control handle: cancellation + progress publishing.
#[derive(Clone)]
pub struct JobCtl {
    id: String,
    registry: Arc<JobRegistry>,
    cancel: CancelToken,
}

impl JobCtl {
    pub fn id(&self) -> &str {
        &self.id
    }

    /// A clone of the job's cancellation token (share it with nested
    /// planner/simulator loops).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Publish `done/total` progress (visible via the `status` op).
    pub fn progress(&self, done: u64, total: u64) {
        self.registry.set_progress(&self.id, done, total);
    }

    /// Stream one partial-result row (visible via the `status` op while
    /// the job is still running).
    pub fn partial(&self, row: Json) {
        self.registry.push_partial(&self.id, row);
    }
}

struct Queued {
    id: String,
    work: JobFn,
}

struct Shared {
    /// One FIFO queue per shard, all behind one short-held lock.
    queues: Mutex<Vec<VecDeque<Queued>>>,
    ready: Condvar,
    stop: AtomicBool,
}

/// The sharded worker pool.  One instance per coordinator; submit from
/// any thread.
pub struct JobEngine {
    registry: Arc<JobRegistry>,
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<Metrics>,
    n_shards: usize,
}

/// Hard ceiling on worker shards: the knob is operator/wire-adjacent
/// (`--shards`), so bound it like every other thread count in the repo.
const MAX_SHARDS: usize = 256;

/// Resolve a shard-count request: `0` = auto (one per available core,
/// capped at 8 — job execution itself fans out over
/// [`crate::util::parallel`], so more shards mostly add idle threads).
/// Explicit requests are clamped to [`MAX_SHARDS`].
pub fn resolve_shards(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8)
    } else {
        requested.min(MAX_SHARDS)
    }
}

fn shard_of(id: &str, n_shards: usize) -> usize {
    // FNV-1a over the job id.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

impl JobEngine {
    /// Start an engine with `shards` worker shards (`0` = auto).
    pub fn new(shards: usize, metrics: Arc<Metrics>) -> Self {
        let n_shards = resolve_shards(shards).max(1);
        let registry = Arc::new(JobRegistry::new());
        let shared = Arc::new(Shared {
            queues: Mutex::new((0..n_shards).map(|_| VecDeque::new()).collect()),
            ready: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let workers = (0..n_shards)
            .map(|shard| {
                let shared = Arc::clone(&shared);
                let registry = Arc::clone(&registry);
                let metrics = Arc::clone(&metrics);
                std::thread::Builder::new()
                    .name(format!("job-engine-{shard}"))
                    .spawn(move || worker_loop(shard, &shared, &registry, &metrics))
                    .expect("spawning job-engine worker")
            })
            .collect();
        Self { registry, shared, workers: Mutex::new(workers), metrics, n_shards }
    }

    /// The registry backing `status` / `jobs` / `cancel`.
    pub fn registry(&self) -> &Arc<JobRegistry> {
        &self.registry
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Jobs queued but not yet started, per shard (for `stats`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared.queues.lock().unwrap().iter().map(VecDeque::len).collect()
    }

    /// Enqueue a job; returns its id immediately.  The job starts when a
    /// worker for its shard (or a stealing neighbour) frees up.
    pub fn submit(&self, op: &str, work: JobFn) -> String {
        let id = self.registry.create(op);
        self.metrics.record_job_submitted();
        let shard = shard_of(&id, self.n_shards);
        {
            // The stop flag must be read under the queues lock: shutdown
            // drains leftovers under the same lock after setting it, so
            // either this push happens before the drain (and is failed
            // there) or this check observes the flag — a job can never
            // land in a queue no worker will pop.
            let mut q = self.shared.queues.lock().unwrap();
            if self.shared.stop.load(Ordering::Acquire) {
                drop(q);
                self.registry.fail(&id, "engine shutting down".into());
                self.metrics.record_job_end(&JobState::Failed);
                return id;
            }
            q[shard].push_back(Queued { id: id.clone(), work });
        }
        self.shared.ready.notify_all();
        id
    }

    /// Submit and block until the job reaches a terminal state — how the
    /// synchronous heavy ops (`campaign`, `sweep`) flow through the same
    /// bounded pool as async jobs.  The caller's thread is a connection
    /// thread, never a pool worker, so waiting cannot starve the pool.
    pub fn run_sync(&self, op: &str, work: JobFn) -> Result<Json, String> {
        let id = self.submit(op, work);
        // wait_outcome reads the result in the same critical section as
        // the terminal observation, so registry eviction cannot race a
        // successful job's result away from its waiter.
        match self.registry.wait_outcome(&id, SYNC_WAIT) {
            Some((JobState::Done, result, _)) => {
                Ok(result.unwrap_or(Json::Null)) // Done always stores a result
            }
            Some((JobState::Failed, _, error)) => {
                Err(error.unwrap_or_else(|| "job failed".into()))
            }
            Some((JobState::Cancelled, _, _)) => Err(format!("job {id} was cancelled")),
            Some((state, _, _)) => {
                // Timed out with the job still live: cancel it so the
                // abandoned work frees its shard instead of running on
                // for hours behind a client that already gave up.
                self.registry.cancel(&id);
                Err(format!(
                    "job {id} exceeded the synchronous wait in state {:?}; cancellation requested",
                    state.as_str()
                ))
            }
            None => Err(format!("job {id} unknown to the registry")),
        }
    }

    /// Stop the pool: cancels every live job (their tokens fire, running
    /// work stops at its next checkpoint), wakes the workers and joins
    /// them.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::Release);
        self.registry.cancel_all();
        self.shared.ready.notify_all();
        let workers: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        // The last Arc<JobEngine> can be dropped *by a pool worker* (a
        // job closure owns a Context clone): never join the current
        // thread — it exits on its own once Drop returns and it sees
        // the stop flag; its handle is simply detached.
        let me = std::thread::current().id();
        for w in workers {
            if w.thread().id() == me {
                continue;
            }
            let _ = w.join();
        }
        // A submit may have raced the stop flag and enqueued after the
        // workers drained; fail anything left so no waiter hangs (and
        // count it — no worker will).
        let leftovers: Vec<String> = {
            let mut q = self.shared.queues.lock().unwrap();
            q.iter_mut().flat_map(|s| s.drain(..)).map(|j| j.id).collect()
        };
        for id in leftovers {
            self.registry.fail(&id, "engine shut down".into());
            if let Some(state) = self.registry.state(&id) {
                self.metrics.record_job_end(&state);
            }
        }
    }
}

impl Drop for JobEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for JobEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobEngine")
            .field("shards", &self.n_shards)
            .field("queued", &self.queue_depths())
            .finish()
    }
}

/// Pop the next job for `own`: own shard first (FIFO), then steal the
/// front of the next non-empty shard.
fn pop_job(queues: &mut [VecDeque<Queued>], own: usize) -> Option<Queued> {
    if let Some(j) = queues[own].pop_front() {
        return Some(j);
    }
    let n = queues.len();
    for k in 1..n {
        if let Some(j) = queues[(own + k) % n].pop_front() {
            return Some(j);
        }
    }
    None
}

fn worker_loop(
    shard: usize,
    shared: &Shared,
    registry: &Arc<JobRegistry>,
    metrics: &Metrics,
) {
    loop {
        let next = {
            let mut q = shared.queues.lock().unwrap();
            loop {
                if let Some(job) = pop_job(q.as_mut_slice(), shard) {
                    break Some(job);
                }
                if shared.stop.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let Some(Queued { id, work }) = next else { return };
        if !registry.start(&id) {
            // Cancelled while queued: the registry already holds the
            // terminal state; nothing to run.
            metrics.record_job_end(&JobState::Cancelled);
            continue;
        }
        let ctl = JobCtl {
            id: id.clone(),
            registry: Arc::clone(registry),
            cancel: registry.token(&id).expect("started job has a token"),
        };
        // A panicking job must not take the worker down with it.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| work(&ctl)));
        match outcome {
            Ok(Ok(result)) => registry.finish(&id, result),
            Ok(Err(error)) => registry.fail(&id, error),
            Err(_) => registry.fail(&id, "job panicked".into()),
        }
        // The registry owns the truth: a cancel that raced the finish
        // leaves the job cancelled, and that is what we count.
        if let Some(state) = registry.state(&id) {
            metrics.record_job_end(&state);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(shards: usize) -> JobEngine {
        JobEngine::new(shards, Arc::new(Metrics::new()))
    }

    #[test]
    fn runs_submitted_jobs_to_completion() {
        let e = engine(2);
        let id = e.submit("t", Box::new(|_| Ok(Json::num(7.0))));
        let state = e.registry().wait_terminal(&id, Duration::from_secs(5)).unwrap();
        assert_eq!(state, JobState::Done);
        assert_eq!(e.registry().result(&id), Some(Json::num(7.0)));
    }

    #[test]
    fn run_sync_returns_the_result_inline() {
        let e = engine(1);
        let out = e.run_sync("t", Box::new(|_| Ok(Json::str("hi")))).unwrap();
        assert_eq!(out.as_str(), Some("hi"));
        let err = e.run_sync("t", Box::new(|_| Err("nope".into()))).unwrap_err();
        assert_eq!(err, "nope");
    }

    #[test]
    fn panicking_job_fails_without_killing_the_worker() {
        let e = engine(1);
        let err = e.run_sync("t", Box::new(|_| panic!("kaboom"))).unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        // The single worker survived and still runs jobs.
        let out = e.run_sync("t", Box::new(|_| Ok(Json::num(1.0)))).unwrap();
        assert_eq!(out.as_f64(), Some(1.0));
    }

    #[test]
    fn cancel_fires_the_token_of_a_running_job() {
        let e = engine(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let id = e.submit(
            "t",
            Box::new(move |ctl| {
                tx.send(()).unwrap(); // signal: running
                while !ctl.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err("observed cancellation".into())
            }),
        );
        rx.recv_timeout(Duration::from_secs(5)).expect("job started");
        assert!(e.registry().cancel(&id));
        let state = e.registry().wait_terminal(&id, Duration::from_secs(5)).unwrap();
        assert_eq!(state, JobState::Cancelled, "cancel wins over the late fail()");
    }

    #[test]
    fn shutdown_cancels_queued_work_and_joins() {
        let e = engine(1);
        // Occupy the only worker, then queue more work behind it.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let _running = e.submit(
            "t",
            Box::new(move |ctl| {
                tx.send(()).unwrap();
                while !ctl.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(Json::Null)
            }),
        );
        let queued = e.submit("t", Box::new(|_| Ok(Json::Null)));
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        e.shutdown();
        assert_eq!(e.registry().state(&queued), Some(JobState::Cancelled));
        // Submissions after shutdown fail fast instead of queueing.
        let late = e.submit("t", Box::new(|_| Ok(Json::Null)));
        assert_eq!(e.registry().state(&late), Some(JobState::Failed));
    }

    #[test]
    fn dropping_the_last_engine_handle_on_a_pool_worker_does_not_deadlock() {
        // A job closure owns a Context clone in the real protocol, so
        // the last Arc<JobEngine> can die on the worker that runs the
        // job; Drop→shutdown must not join the worker's own thread.
        let e = Arc::new(engine(1));
        let registry = Arc::clone(e.registry());
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let e2 = Arc::clone(&e);
        let id = e.submit(
            "t",
            Box::new(move |_| {
                started_tx.send(()).unwrap();
                go_rx.recv().unwrap();
                drop(e2); // last Arc: Drop runs here, on the pool worker
                Ok(Json::Null)
            }),
        );
        started_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        drop(e); // release the main handle while the job is running
        go_tx.send(()).unwrap();
        // Shutdown's cancel_all marks the in-flight job cancelled; the
        // registry outlives the engine, so the waiter still wakes.
        assert_eq!(
            registry.wait_terminal(&id, Duration::from_secs(10)),
            Some(JobState::Cancelled)
        );
    }

    #[test]
    fn shard_hash_is_stable_and_in_range() {
        for n in [1usize, 2, 3, 8] {
            for i in 0..64u64 {
                let id = format!("j-{i}");
                let s = shard_of(&id, n);
                assert!(s < n);
                assert_eq!(s, shard_of(&id, n), "stable");
            }
        }
    }
}
