//! The sharded job engine: a bounded worker pool that executes every
//! coordinator job (async `submit` jobs *and* the synchronous heavy ops,
//! which block their connection on [`JobEngine::run_sync`]).
//!
//! Architecture:
//!
//! * **N shards, N workers.**  A job's id hashes (FNV-1a) onto a shard
//!   queue; each shard has one dedicated worker.
//! * **Bounded priority queues.**  Each shard queue is a priority queue
//!   bounded at `max_backlog` entries.  Jobs carry a [`JobPriority`]
//!   (`priority` 0..=9, optional relative `deadline_ms`) and pop in
//!   (priority desc, earliest-deadline, FIFO submission) order — so a
//!   priority-9 job overtakes a queued backlog of priority-0 work, and
//!   within a priority band the job with the nearest deadline runs
//!   first.  Jobs submitted without priority/deadline all share the
//!   default band, which degenerates to exactly the old FIFO order.
//! * **Admission control.**  A submit that finds its shard's queue at
//!   `max_backlog` is *rejected* with [`Busy`] (shard + backlog) instead
//!   of queuing unboundedly — the caller (wire protocol) surfaces a
//!   structured `{"error":"busy",...}` response.  Synchronous
//!   [`run_sync`](JobEngine::run_sync) callers get the same rejection as
//!   [`JobError::Busy`].
//! * **Work stealing.**  An idle worker whose own queue is empty pops
//!   the best job of the next non-empty shard (round-robin scan), so one
//!   slow shard never strands queued work while other workers idle.
//!   Stealing pops the queue's best entry — per-shard start order
//!   (priority, deadline, FIFO) holds regardless of who executes.
//! * **Bounded concurrency.**  At most N jobs run at once; everything
//!   else queues (up to the backlog bound).  This replaces the
//!   historical thread-per-job `std::thread::spawn` in the submit path.
//! * **Cooperative cancellation.**  Every job carries a
//!   [`CancelToken`] (owned by the [`JobRegistry`]); `cancel` fires it
//!   and the running work stops at its next checkpoint.
//!   Cancelled-while-queued jobs are skipped when popped.
//! * **Progress + partial results + queue-wait.**  The [`JobCtl`] handle
//!   publishes `done/total` counters and streaming partial rows into the
//!   registry; the registry also records each job's time-in-queue,
//!   surfaced as `queue_wait_ms` on `status` and aggregated in the
//!   metrics.  Per-shard depth / high-water / rejected gauges feed the
//!   `stats` op via [`JobEngine::shard_stats`].
//!
//! The engine is transport-agnostic: jobs are plain `FnOnce(&JobCtl) ->
//! Result<Json, String>` closures, so the protocol layer, tests and
//! benches submit work directly.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::{failpoint, CancelToken, Json};

use super::state::{JobRegistry, JobState};
use super::Metrics;

/// A unit of work: runs on a pool worker, returns the job's result body
/// or an error string.  Long jobs should poll `ctl` for cancellation and
/// publish progress through it.
pub type JobFn = Box<dyn FnOnce(&JobCtl) -> Result<Json, String> + Send + 'static>;

/// Upper bound a synchronous caller waits for its own job (effectively
/// "until done" — campaigns and sweeps finish far sooner; the bound only
/// guards against a wedged worker).
const SYNC_WAIT: Duration = Duration::from_secs(3600);

/// Queue placement of one job: scheduling band + optional deadline.
///
/// `priority` ranges 0..=9 (9 = most urgent; the default 0 is the band
/// every legacy request lands in, preserving plain FIFO).  `deadline_ms`
/// is *relative to submission*; within a priority band the earliest
/// absolute deadline pops first, and deadline-less jobs order after any
/// deadline-carrying job of the same priority.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobPriority {
    pub priority: u8,
    pub deadline_ms: Option<u64>,
}

impl JobPriority {
    pub fn new(priority: u8) -> Self {
        Self { priority, deadline_ms: None }
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }
}

/// Admission rejection: the target shard's queue is at its bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    pub shard: usize,
    pub backlog: usize,
}

/// Why a synchronous engine call did not produce a result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// Rejected at admission — nothing was queued; retry later or shed.
    Busy { shard: usize, backlog: usize },
    /// The job was cancelled before it produced a result (explicit
    /// cancel op, engine shutdown, or an abandoning synchronous waiter).
    Cancelled(String),
    /// The job's binding `deadline_ms` passed before it produced a
    /// result: shed while queued, aborted by the deadline sweeper while
    /// running, or timed out by its synchronous waiter.
    DeadlineExceeded(String),
    /// The job ran (or was lost) and failed with this message.
    Failed(String),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Busy { shard, backlog } => {
                write!(f, "busy: shard {shard} backlog {backlog} is at its bound")
            }
            JobError::Cancelled(e) | JobError::DeadlineExceeded(e) | JobError::Failed(e) => {
                f.write_str(e)
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Per-job control handle: cancellation + progress publishing.
#[derive(Clone)]
pub struct JobCtl {
    id: String,
    registry: Arc<JobRegistry>,
    cancel: CancelToken,
}

impl JobCtl {
    pub fn id(&self) -> &str {
        &self.id
    }

    /// A clone of the job's cancellation token (share it with nested
    /// planner/simulator loops).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Publish `done/total` progress (visible via the `status` op).
    pub fn progress(&self, done: u64, total: u64) {
        self.registry.set_progress(&self.id, done, total);
    }

    /// Stream one partial-result row (visible via the `status` op while
    /// the job is still running).
    pub fn partial(&self, row: Json) {
        self.registry.push_partial(&self.id, row);
    }
}

/// One queued job with its scheduling key.  `Ord` is arranged so the
/// `BinaryHeap` max is the next job to run: higher priority first, then
/// earlier absolute deadline, then lower submission sequence (FIFO).
struct Queued {
    priority: u8,
    deadline: Option<Instant>,
    seq: u64,
    id: String,
    work: JobFn,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == CmpOrdering::Equal
    }
}

impl Eq for Queued {}

impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Queued {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| match (self.deadline, other.deadline) {
                // Earlier deadline = more urgent = greater; a deadline
                // beats no deadline within the same priority band.
                (Some(a), Some(b)) => b.cmp(&a),
                (Some(_), None) => CmpOrdering::Greater,
                (None, Some(_)) => CmpOrdering::Less,
                (None, None) => CmpOrdering::Equal,
            })
            // Lower sequence number = submitted earlier = greater.
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One shard: its priority queue plus the gauges `stats` reports.
#[derive(Default)]
struct Shard {
    heap: BinaryHeap<Queued>,
    high_water: usize,
    rejected: u64,
}

/// Point-in-time view of one shard's queue (for the `stats` op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    pub depth: usize,
    pub high_water: usize,
    pub rejected: u64,
}

struct QueueState {
    shards: Vec<Shard>,
    /// Global FIFO tiebreak sequence (under the queues lock, so the
    /// submission order it records is the lock-acquisition order).
    next_seq: u64,
}

/// What one worker slot is executing right now (the watchdog's view).
/// `epoch` is bumped when the watchdog condemns a stuck worker: the
/// condemned thread notices the mismatch at its next slot touch and
/// exits, while a freshly spawned replacement (carrying the new epoch)
/// takes over the slot.
#[derive(Default)]
struct BusySlot {
    /// `(job id, started at)` while the slot's worker is executing.
    job: Option<(String, Instant)>,
    epoch: u64,
}

struct Shared {
    /// Every shard queue behind one short-held lock.
    queues: Mutex<QueueState>,
    ready: Condvar,
    stop: AtomicBool,
    /// One slot per worker shard, inspected by the watchdog.
    busy: Mutex<Vec<BusySlot>>,
    /// Watchdog threshold in ms; `0` disables the watchdog (the
    /// deadline sweeper in the same supervisor thread always runs).
    watchdog_ms: AtomicU64,
    /// Worker + supervisor join handles.  Lives in `Shared` (not the
    /// engine) so the supervisor can register respawned workers.
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Stuck workers condemned and replaced by the watchdog.
    respawns: AtomicU64,
}

/// The sharded worker pool.  One instance per coordinator; submit from
/// any thread.
pub struct JobEngine {
    registry: Arc<JobRegistry>,
    shared: Arc<Shared>,
    metrics: Arc<Metrics>,
    n_shards: usize,
    max_backlog: usize,
}

/// Hard ceiling on worker shards: the knob is operator/wire-adjacent
/// (`--shards`), so bound it like every other thread count in the repo.
const MAX_SHARDS: usize = 256;

/// Default per-shard backlog bound (`--max-backlog`): submits beyond it
/// are rejected with [`Busy`] instead of queuing unboundedly.
pub const DEFAULT_MAX_BACKLOG: usize = 256;

/// Ceiling on an explicitly requested backlog bound — the knob is
/// operator/wire-adjacent, and each queued entry pins a closure.
const MAX_BACKLOG_LIMIT: usize = 1 << 20;

/// Resolve a shard-count request: `0` = auto (one per available core,
/// capped at 8 — job execution itself fans out over
/// [`crate::util::parallel`], so more shards mostly add idle threads).
/// Explicit requests are clamped to [`MAX_SHARDS`].
pub fn resolve_shards(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8)
    } else {
        requested.min(MAX_SHARDS)
    }
}

/// Resolve a backlog-bound request: `0` = the default
/// ([`DEFAULT_MAX_BACKLOG`]); explicit values are clamped to
/// `[1, 2^20]`.
pub fn resolve_backlog(requested: usize) -> usize {
    if requested == 0 {
        DEFAULT_MAX_BACKLOG
    } else {
        requested.min(MAX_BACKLOG_LIMIT)
    }
}

fn shard_of(id: &str, n_shards: usize) -> usize {
    // FNV-1a over the job id.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

impl JobEngine {
    /// Start an engine with `shards` worker shards (`0` = auto) and the
    /// default per-shard backlog bound.
    pub fn new(shards: usize, metrics: Arc<Metrics>) -> Self {
        Self::with_backlog(shards, 0, metrics)
    }

    /// Start an engine with an explicit per-shard backlog bound
    /// (`0` = default [`DEFAULT_MAX_BACKLOG`]).
    pub fn with_backlog(shards: usize, max_backlog: usize, metrics: Arc<Metrics>) -> Self {
        let n_shards = resolve_shards(shards).max(1);
        let max_backlog = resolve_backlog(max_backlog);
        let registry = Arc::new(JobRegistry::new());
        let shared = Arc::new(Shared {
            queues: Mutex::new(QueueState {
                shards: (0..n_shards).map(|_| Shard::default()).collect(),
                next_seq: 0,
            }),
            ready: Condvar::new(),
            stop: AtomicBool::new(false),
            busy: Mutex::new((0..n_shards).map(|_| BusySlot::default()).collect()),
            watchdog_ms: AtomicU64::new(0),
            handles: Mutex::new(Vec::new()),
            respawns: AtomicU64::new(0),
        });
        let mut handles: Vec<_> = (0..n_shards)
            .map(|shard| spawn_worker(shard, 0, &shared, &registry, &metrics))
            .collect();
        handles.push({
            let shared = Arc::clone(&shared);
            let registry = Arc::clone(&registry);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("job-engine-supervisor".into())
                .spawn(move || supervisor_loop(&shared, &registry, &metrics))
                .expect("spawning job-engine supervisor")
        });
        shared.handles.lock().unwrap().extend(handles);
        Self { registry, shared, metrics, n_shards, max_backlog }
    }

    /// Arm (or disarm, with `None`) the stuck-worker watchdog: a worker
    /// executing one job for longer than `threshold` is condemned — its
    /// job's token fires, the job is failed, and a fresh worker takes
    /// over the shard slot.  Disabled by default: a legitimate
    /// hours-long campaign must never be shot by a default.
    pub fn set_watchdog(&self, threshold: Option<Duration>) {
        let ms = threshold.map_or(0, |d| d.as_millis().min(u128::from(u64::MAX)) as u64);
        self.shared.watchdog_ms.store(ms, Ordering::Relaxed);
    }

    /// Stuck workers condemned and replaced so far (for `stats`).
    pub fn watchdog_respawns(&self) -> u64 {
        self.shared.respawns.load(Ordering::Relaxed)
    }

    /// The registry backing `status` / `jobs` / `cancel`.
    pub fn registry(&self) -> &Arc<JobRegistry> {
        &self.registry
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The per-shard backlog bound admission control enforces.
    pub fn max_backlog(&self) -> usize {
        self.max_backlog
    }

    /// Jobs queued but not yet started, per shard (for `stats`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared.queues.lock().unwrap().shards.iter().map(|s| s.heap.len()).collect()
    }

    /// Per-shard depth / high-water / rejected gauges (for `stats`).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shared
            .queues
            .lock()
            .unwrap()
            .shards
            .iter()
            .map(|s| ShardStats {
                depth: s.heap.len(),
                high_water: s.high_water,
                rejected: s.rejected,
            })
            .collect()
    }

    /// Enqueue a job under admission control; returns its id
    /// immediately, or [`Busy`] (nothing queued, nothing registered)
    /// when the job's shard is already at the backlog bound.  The job
    /// starts when a worker for its shard (or a stealing neighbour)
    /// frees up, in (priority, deadline, FIFO) order.
    pub fn try_submit(&self, op: &str, prio: JobPriority, work: JobFn) -> Result<String, Busy> {
        self.try_submit_journaled(op, prio, None, work)
    }

    /// [`try_submit`](Self::try_submit) that also journals the accepted
    /// job when `line` (the raw request to re-execute after a crash) is
    /// given and a journal is attached.  The accept record is fsynced
    /// *under the queues lock*, after the stop/backlog checks and before
    /// the heap push — durability before visibility: no worker can
    /// observe (or finish) a job whose admission is not yet on disk,
    /// and busy-rejected submissions are never journaled.  Sync heavy
    /// ops pass `line = None` and stay off the journal entirely.
    pub fn try_submit_journaled(
        &self,
        op: &str,
        prio: JobPriority,
        line: Option<&str>,
        work: JobFn,
    ) -> Result<String, Busy> {
        // Relative deadline -> absolute instant at admission time, so
        // EDF ordering compares real urgency across submission times.
        // (The wire layer bounds deadline_ms; for direct library callers
        // an unrepresentable instant saturates ~136 years out instead of
        // panicking on Instant overflow.)
        let deadline = prio.deadline_ms.map(|ms| {
            let now = Instant::now();
            now.checked_add(Duration::from_millis(ms))
                .unwrap_or_else(|| now + Duration::from_secs(u64::from(u32::MAX)))
        });
        let id = self.registry.create_with(op, prio);
        let shard = shard_of(&id, self.n_shards);
        {
            // The stop flag must be read under the queues lock: shutdown
            // drains leftovers under the same lock after setting it, so
            // either this push happens before the drain (and is failed
            // there) or this check observes the flag — a job can never
            // land in a queue no worker will pop.
            let mut q = self.shared.queues.lock().unwrap();
            if self.shared.stop.load(Ordering::Acquire) {
                drop(q);
                self.metrics.record_job_submitted();
                self.registry.fail(&id, "engine shutting down".into());
                self.metrics.record_job_end(&JobState::Failed);
                return Ok(id);
            }
            let s = &mut q.shards[shard];
            if s.heap.len() >= self.max_backlog {
                let backlog = s.heap.len();
                s.rejected += 1;
                drop(q);
                // Nothing queued: the reserved registry entry is
                // discarded so rejected traffic cannot grow the job
                // list or leak ids.
                self.registry.discard(&id);
                self.metrics.record_job_rejected();
                return Err(Busy { shard, backlog });
            }
            if let (Some(line), Some(journal)) = (line, self.registry.journal()) {
                // Durability before visibility (see the method doc).
                journal.admit(&id, op, line, prio);
            }
            let seq = q.next_seq;
            q.next_seq += 1;
            let s = &mut q.shards[shard];
            s.heap.push(Queued { priority: prio.priority, deadline, seq, id: id.clone(), work });
            s.high_water = s.high_water.max(s.heap.len());
        }
        self.metrics.record_job_submitted();
        self.shared.ready.notify_all();
        Ok(id)
    }

    /// Default-priority [`try_submit`](Self::try_submit) that panics on
    /// a backlog rejection — a convenience for tests and benches that
    /// size their own traffic under the bound.  Production callers (the
    /// wire protocol) use `try_submit` and surface `busy` instead.
    pub fn submit(&self, op: &str, work: JobFn) -> String {
        self.try_submit(op, JobPriority::default(), work).unwrap_or_else(|b| {
            panic!("submit: shard {} is at its backlog bound ({})", b.shard, b.backlog)
        })
    }

    /// Submit and block until the job reaches a terminal state — how the
    /// synchronous heavy ops (`campaign`, `sweep`) flow through the same
    /// bounded pool as async jobs.  The caller's thread is a connection
    /// or request-executor thread, never a pool worker, so waiting
    /// cannot starve the pool.  Admission control applies: a full shard
    /// rejects with [`JobError::Busy`] instead of queueing.
    pub fn run_sync(&self, op: &str, work: JobFn) -> Result<Json, JobError> {
        self.run_sync_with(op, JobPriority::default(), work)
    }

    /// [`run_sync`](Self::run_sync) with an explicit queue placement.
    pub fn run_sync_with(
        &self,
        op: &str,
        prio: JobPriority,
        work: JobFn,
    ) -> Result<Json, JobError> {
        let id = match self.try_submit(op, prio, work) {
            Ok(id) => id,
            Err(Busy { shard, backlog }) => return Err(JobError::Busy { shard, backlog }),
        };
        // A binding deadline doubles as the server-side timeout for the
        // synchronous wait: the caller hears `deadline_exceeded` at the
        // deadline instead of blocking for the full engine bound.
        let wait = prio
            .deadline_ms
            .map_or(SYNC_WAIT, |ms| Duration::from_millis(ms).min(SYNC_WAIT));
        // wait_outcome reads the result in the same critical section as
        // the terminal observation, so registry eviction cannot race a
        // successful job's result away from its waiter.
        match self.registry.wait_outcome(&id, wait) {
            Some((JobState::Done, result, _)) => {
                Ok(result.unwrap_or(Json::Null)) // Done always stores a result
            }
            Some((JobState::Failed, _, error)) => {
                let msg = error.unwrap_or_else(|| "job failed".into());
                if msg.starts_with("deadline_exceeded") {
                    Err(JobError::DeadlineExceeded(msg))
                } else {
                    Err(JobError::Failed(msg))
                }
            }
            Some((JobState::Cancelled, _, _)) => {
                Err(JobError::Cancelled(format!("job {id} was cancelled")))
            }
            Some((state, _, _)) => {
                // Timed out with the job still live: cancel it so the
                // abandoned work frees its shard instead of running on
                // for hours behind a client that already gave up.
                let cancelled = self.registry.cancel(&id);
                if prio.deadline_ms.is_some() {
                    if cancelled {
                        self.metrics.record_deadline_exceeded();
                    }
                    Err(JobError::DeadlineExceeded(format!(
                        "deadline_exceeded: job {id} passed its deadline in state {:?}; \
                         cancellation requested",
                        state.as_str()
                    )))
                } else {
                    Err(JobError::Failed(format!(
                        "job {id} exceeded the synchronous wait in state {:?}; \
                         cancellation requested",
                        state.as_str()
                    )))
                }
            }
            None => Err(JobError::Failed(format!("job {id} unknown to the registry"))),
        }
    }

    /// Re-enqueue a journal-recovered job under its pre-crash id (the
    /// registry record must already exist via `restore`).  Replay only,
    /// at startup.  Deliberately bypasses the backlog bound: admission
    /// was granted before the crash, and recovery must not turn a full
    /// queue into data loss.  Writes no journal record — the original
    /// accept still covers this job.  Relative deadlines restart from
    /// recovery time (the original submission instant did not survive).
    pub fn resubmit_recovered(&self, id: &str, prio: JobPriority, work: JobFn) {
        let deadline = prio.deadline_ms.map(|ms| {
            let now = Instant::now();
            now.checked_add(Duration::from_millis(ms))
                .unwrap_or_else(|| now + Duration::from_secs(u64::from(u32::MAX)))
        });
        let shard = shard_of(id, self.n_shards);
        {
            let mut q = self.shared.queues.lock().unwrap();
            if self.shared.stop.load(Ordering::Acquire) {
                drop(q);
                self.metrics.record_job_submitted();
                self.registry.fail(id, "engine shutting down".into());
                self.metrics.record_job_end(&JobState::Failed);
                return;
            }
            let seq = q.next_seq;
            q.next_seq += 1;
            let s = &mut q.shards[shard];
            s.heap.push(Queued {
                priority: prio.priority,
                deadline,
                seq,
                id: id.to_string(),
                work,
            });
            s.high_water = s.high_water.max(s.heap.len());
        }
        self.metrics.record_job_submitted();
        self.shared.ready.notify_all();
    }

    /// Stop the pool: cancels every live job (their tokens fire, running
    /// work stops at its next checkpoint), wakes the workers and joins
    /// them.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::Release);
        self.registry.cancel_all();
        self.shared.ready.notify_all();
        let workers: Vec<_> = self.shared.handles.lock().unwrap().drain(..).collect();
        // The last Arc<JobEngine> can be dropped *by a pool worker* (a
        // job closure owns a Context clone): never join the current
        // thread — it exits on its own once Drop returns and it sees
        // the stop flag; its handle is simply detached.
        let me = std::thread::current().id();
        for w in workers {
            if w.thread().id() == me {
                continue;
            }
            let _ = w.join();
        }
        // A submit may have raced the stop flag and enqueued after the
        // workers drained; fail anything left so no waiter hangs (and
        // count it — no worker will).
        let leftovers: Vec<String> = {
            let mut q = self.shared.queues.lock().unwrap();
            q.shards.iter_mut().flat_map(|s| s.heap.drain()).map(|j| j.id).collect()
        };
        for id in leftovers {
            self.registry.fail(&id, "engine shut down".into());
            if let Some(state) = self.registry.state(&id) {
                self.metrics.record_job_end(&state);
            }
        }
    }
}

impl Drop for JobEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for JobEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobEngine")
            .field("shards", &self.n_shards)
            .field("max_backlog", &self.max_backlog)
            .field("queued", &self.queue_depths())
            .finish()
    }
}

/// Pop the next job for `own`: own shard first, then steal the best of
/// the next non-empty shard.  Each heap pops in (priority, deadline,
/// FIFO) order.
fn pop_job(shards: &mut [Shard], own: usize) -> Option<Queued> {
    if let Some(j) = shards[own].heap.pop() {
        return Some(j);
    }
    let n = shards.len();
    for k in 1..n {
        if let Some(j) = shards[(own + k) % n].heap.pop() {
            return Some(j);
        }
    }
    None
}

/// Spawn one worker thread for `slot` at `epoch` and return its handle.
fn spawn_worker(
    slot: usize,
    epoch: u64,
    shared: &Arc<Shared>,
    registry: &Arc<JobRegistry>,
    metrics: &Arc<Metrics>,
) -> std::thread::JoinHandle<()> {
    let shared = Arc::clone(shared);
    let registry = Arc::clone(registry);
    let metrics = Arc::clone(metrics);
    std::thread::Builder::new()
        .name(format!("job-engine-{slot}"))
        .spawn(move || worker_loop(slot, epoch, &shared, &registry, &metrics))
        .expect("spawning job-engine worker")
}

/// Extract a human-readable message from a panic payload (the two
/// shapes `panic!` produces: `&'static str` and `String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

fn worker_loop(
    slot: usize,
    epoch: u64,
    shared: &Shared,
    registry: &Arc<JobRegistry>,
    metrics: &Metrics,
) {
    loop {
        let next = {
            let mut q = shared.queues.lock().unwrap();
            loop {
                if let Some(job) = pop_job(q.shards.as_mut_slice(), slot) {
                    break Some(job);
                }
                if shared.stop.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let Some(job) = next else { return };
        // Binding deadline: a job popped past its deadline is shed
        // before any execution and fails with the `deadline_exceeded`
        // marker the API layer maps to its error code.
        if job.deadline.is_some_and(|d| Instant::now() >= d) {
            let id = job.id;
            if registry.abort(
                &id,
                format!("deadline_exceeded: job {id} passed its deadline while queued"),
            ) {
                metrics.record_deadline_exceeded();
            }
            // Nothing ran; this pop is the job's end either way (the
            // abort loses only to a cancel that raced it).
            if let Some(state) = registry.state(&id) {
                metrics.record_job_end(&state);
            }
            continue;
        }
        // Claim this worker's busy slot.  A condemned worker (the
        // watchdog bumped the epoch while it was stuck) hands the job
        // to its replacement and exits.
        {
            let mut busy = shared.busy.lock().unwrap();
            if busy[slot].epoch != epoch {
                {
                    let mut q = shared.queues.lock().unwrap();
                    let shard = shard_of(&job.id, q.shards.len());
                    q.shards[shard].heap.push(job);
                }
                drop(busy);
                shared.ready.notify_all();
                return;
            }
            busy[slot].job = Some((job.id.clone(), Instant::now()));
        }
        let Queued { id, work, .. } = job;
        if !registry.start(&id) {
            // Cancelled while queued: the registry already holds the
            // terminal state; nothing to run.
            metrics.record_job_end(&JobState::Cancelled);
            release_slot(shared, slot, epoch);
            continue;
        }
        // The registry stamped the job's time-in-queue at start.
        if let Some(wait) = registry.queue_wait(&id) {
            metrics.record_queue_wait(wait);
        }
        let ctl = JobCtl {
            id: id.clone(),
            registry: Arc::clone(registry),
            cancel: registry.token(&id).expect("started job has a token"),
        };
        // A panicking job must not take the worker down with it.  The
        // `engine.worker` failpoint fires inside this scope so an
        // injected panic exercises exactly the isolation path.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if failpoint::apply("engine.worker").is_some() {
                return Err("failpoint engine.worker: injected error".to_string());
            }
            work(&ctl)
        }));
        match outcome {
            Ok(Ok(result)) => registry.finish(&id, result),
            Ok(Err(error)) => registry.fail(&id, error),
            // The panicking job's terminal state keeps the panic
            // message, so `status` and the journal stay consistent.
            Err(payload) => {
                registry.fail(&id, format!("job panicked: {}", panic_message(payload.as_ref())))
            }
        }
        // The registry owns the truth: a cancel that raced the finish
        // leaves the job cancelled, and that is what we count.
        if let Some(state) = registry.state(&id) {
            metrics.record_job_end(&state);
        }
        if !release_slot(shared, slot, epoch) {
            // Condemned mid-job: a replacement owns the slot now.
            return;
        }
    }
}

/// Clear the worker's busy slot; returns false when the worker was
/// condemned (epoch moved on) and must exit.
fn release_slot(shared: &Shared, slot: usize, epoch: u64) -> bool {
    let mut busy = shared.busy.lock().unwrap();
    if busy[slot].epoch != epoch {
        return false;
    }
    busy[slot].job = None;
    true
}

/// Supervisor cadence: deadline sweep + stuck-worker watchdog.
const SUPERVISE_TICK: Duration = Duration::from_millis(20);

/// The engine's supervisor thread: every tick it (1) aborts running
/// jobs whose binding deadline passed, firing their tokens so the work
/// stops at its next checkpoint, and (2) when the watchdog is armed,
/// condemns workers stuck on one job past the threshold and spawns
/// replacements so the shard keeps serving.
fn supervisor_loop(shared: &Arc<Shared>, registry: &Arc<JobRegistry>, metrics: &Arc<Metrics>) {
    loop {
        {
            let q = shared.queues.lock().unwrap();
            if shared.stop.load(Ordering::Acquire) {
                return;
            }
            // Re-uses the ready condvar so shutdown wakes us instantly;
            // spurious submit wake-ups just run a cheap early sweep.
            let _ = shared.ready.wait_timeout(q, SUPERVISE_TICK).unwrap();
        }
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        for id in registry.running_deadline_expired() {
            if registry.abort(
                &id,
                format!("deadline_exceeded: job {id} passed its deadline while running"),
            ) {
                // The worker running the job observes the fired token,
                // returns, and records the job end itself.
                metrics.record_deadline_exceeded();
            }
        }
        let threshold = shared.watchdog_ms.load(Ordering::Relaxed);
        if threshold == 0 {
            continue;
        }
        let condemned: Vec<(usize, u64, String)> = {
            let mut busy = shared.busy.lock().unwrap();
            busy.iter_mut()
                .enumerate()
                .filter_map(|(slot, s)| {
                    let (id, since) = s.job.as_ref()?;
                    if since.elapsed() < Duration::from_millis(threshold) {
                        return None;
                    }
                    let id = id.clone();
                    s.epoch += 1;
                    s.job = None;
                    Some((slot, s.epoch, id))
                })
                .collect()
        };
        for (slot, epoch, id) in condemned {
            // Fail the stuck job and fire its token: if the worker is
            // merely slow it stops at the next checkpoint; if it is
            // truly wedged the replacement keeps the shard serving.
            registry.abort(
                &id,
                format!("watchdog: job {id} stuck past {threshold}ms; worker respawned"),
            );
            shared.respawns.fetch_add(1, Ordering::Relaxed);
            metrics.record_watchdog_respawn();
            let handle = spawn_worker(slot, epoch, shared, registry, metrics);
            shared.handles.lock().unwrap().push(handle);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(shards: usize) -> JobEngine {
        JobEngine::new(shards, Arc::new(Metrics::new()))
    }

    #[test]
    fn runs_submitted_jobs_to_completion() {
        let e = engine(2);
        let id = e.submit("t", Box::new(|_| Ok(Json::num(7.0))));
        let state = e.registry().wait_terminal(&id, Duration::from_secs(5)).unwrap();
        assert_eq!(state, JobState::Done);
        assert_eq!(e.registry().result(&id), Some(Json::num(7.0)));
    }

    #[test]
    fn run_sync_returns_the_result_inline() {
        let e = engine(1);
        let out = e.run_sync("t", Box::new(|_| Ok(Json::str("hi")))).unwrap();
        assert_eq!(out.as_str(), Some("hi"));
        let err = e.run_sync("t", Box::new(|_| Err("nope".into()))).unwrap_err();
        assert_eq!(err, JobError::Failed("nope".into()));
        assert_eq!(err.to_string(), "nope");
    }

    #[test]
    fn panicking_job_fails_without_killing_the_worker() {
        let e = engine(1);
        let err = e.run_sync("t", Box::new(|_| panic!("kaboom"))).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // The single worker survived and still runs jobs.
        let out = e.run_sync("t", Box::new(|_| Ok(Json::num(1.0)))).unwrap();
        assert_eq!(out.as_f64(), Some(1.0));
    }

    #[test]
    fn cancel_fires_the_token_of_a_running_job() {
        let e = engine(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let id = e.submit(
            "t",
            Box::new(move |ctl| {
                tx.send(()).unwrap(); // signal: running
                while !ctl.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err("observed cancellation".into())
            }),
        );
        rx.recv_timeout(Duration::from_secs(5)).expect("job started");
        assert!(e.registry().cancel(&id));
        let state = e.registry().wait_terminal(&id, Duration::from_secs(5)).unwrap();
        assert_eq!(state, JobState::Cancelled, "cancel wins over the late fail()");
    }

    #[test]
    fn shutdown_cancels_queued_work_and_joins() {
        let e = engine(1);
        // Occupy the only worker, then queue more work behind it.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let _running = e.submit(
            "t",
            Box::new(move |ctl| {
                tx.send(()).unwrap();
                while !ctl.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(Json::Null)
            }),
        );
        let queued = e.submit("t", Box::new(|_| Ok(Json::Null)));
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        e.shutdown();
        assert_eq!(e.registry().state(&queued), Some(JobState::Cancelled));
        // Submissions after shutdown fail fast instead of queueing.
        let late = e.submit("t", Box::new(|_| Ok(Json::Null)));
        assert_eq!(e.registry().state(&late), Some(JobState::Failed));
    }

    #[test]
    fn dropping_the_last_engine_handle_on_a_pool_worker_does_not_deadlock() {
        // A job closure owns a Context clone in the real protocol, so
        // the last Arc<JobEngine> can die on the worker that runs the
        // job; Drop→shutdown must not join the worker's own thread.
        let e = Arc::new(engine(1));
        let registry = Arc::clone(e.registry());
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let e2 = Arc::clone(&e);
        let id = e.submit(
            "t",
            Box::new(move |_| {
                started_tx.send(()).unwrap();
                go_rx.recv().unwrap();
                drop(e2); // last Arc: Drop runs here, on the pool worker
                Ok(Json::Null)
            }),
        );
        started_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        drop(e); // release the main handle while the job is running
        go_tx.send(()).unwrap();
        // Shutdown's cancel_all marks the in-flight job cancelled; the
        // registry outlives the engine, so the waiter still wakes.
        assert_eq!(
            registry.wait_terminal(&id, Duration::from_secs(10)),
            Some(JobState::Cancelled)
        );
    }

    #[test]
    fn shard_hash_is_stable_and_in_range() {
        for n in [1usize, 2, 3, 8] {
            for i in 0..64u64 {
                let id = format!("j-{i}");
                let s = shard_of(&id, n);
                assert!(s < n);
                assert_eq!(s, shard_of(&id, n), "stable");
            }
        }
    }

    #[test]
    fn queue_order_is_priority_then_deadline_then_fifo() {
        // Pure key ordering, no threads: greatest = runs first.
        let q = |priority: u8, deadline: Option<Instant>, seq: u64| Queued {
            priority,
            deadline,
            seq,
            id: String::new(),
            work: Box::new(|_| Ok(Json::Null)),
        };
        let now = Instant::now();
        let soon = now + Duration::from_millis(10);
        let later = now + Duration::from_secs(60);
        let mut heap = BinaryHeap::new();
        heap.push(q(0, None, 0)); // first in, lowest band
        heap.push(q(0, None, 1));
        heap.push(q(9, None, 2)); // urgent band
        heap.push(q(5, Some(later), 3));
        heap.push(q(5, Some(soon), 4)); // same band, nearer deadline
        heap.push(q(5, None, 5)); // same band, no deadline: after EDF jobs
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop()).map(|j| j.seq).collect();
        assert_eq!(order, vec![2, 4, 3, 5, 0, 1]);
    }

    #[test]
    fn backlog_bound_rejects_with_busy() {
        let metrics = Arc::new(Metrics::new());
        let e = JobEngine::with_backlog(1, 2, Arc::clone(&metrics));
        assert_eq!(e.max_backlog(), 2);
        // Occupy the only worker so everything else queues.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let blocker = e.submit(
            "t",
            Box::new(move |_| {
                tx.send(()).unwrap();
                go_rx.recv().unwrap();
                Ok(Json::Null)
            }),
        );
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // Two fit in the queue; the third is rejected, not queued.
        let a = e.try_submit("t", JobPriority::default(), Box::new(|_| Ok(Json::Null))).unwrap();
        let b = e.try_submit("t", JobPriority::default(), Box::new(|_| Ok(Json::Null))).unwrap();
        let busy = e
            .try_submit("t", JobPriority::default(), Box::new(|_| Ok(Json::Null)))
            .unwrap_err();
        assert_eq!(busy, Busy { shard: 0, backlog: 2 });
        // The rejected submission left no registry record behind.
        assert_eq!(e.registry().list().as_arr().unwrap().len(), 3);
        let stats = e.shard_stats();
        assert_eq!(stats[0].depth, 2);
        assert_eq!(stats[0].high_water, 2);
        assert_eq!(stats[0].rejected, 1);
        go_tx.send(()).unwrap();
        for id in [&blocker, &a, &b] {
            assert_eq!(
                e.registry().wait_terminal(id, Duration::from_secs(10)),
                Some(JobState::Done)
            );
        }
        // Queue drained: admission accepts again.
        let ok = e.try_submit("t", JobPriority::default(), Box::new(|_| Ok(Json::Null)));
        assert!(ok.is_ok());
    }

    #[test]
    fn resubmit_recovered_runs_under_the_pre_crash_id() {
        let e = engine(2);
        e.registry().restore("j-41", "plan", JobPriority::default());
        e.resubmit_recovered("j-41", JobPriority::default(), Box::new(|_| Ok(Json::num(5.0))));
        assert_eq!(
            e.registry().wait_terminal("j-41", Duration::from_secs(5)),
            Some(JobState::Done)
        );
        assert_eq!(e.registry().result("j-41"), Some(Json::num(5.0)));
    }

    #[test]
    fn panic_message_is_preserved_in_the_terminal_state() {
        let e = engine(1);
        let err = e.run_sync("t", Box::new(|_| panic!("kaboom {}", 7))).unwrap_err();
        assert_eq!(err, JobError::Failed("job panicked: kaboom 7".into()));
    }

    #[test]
    fn expired_deadline_jobs_are_shed_at_pop() {
        let e = engine(1);
        // Occupy the only worker so the deadline job waits in queue
        // past its deadline.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let _blocker = e.submit(
            "t",
            Box::new(move |_| {
                tx.send(()).unwrap();
                go_rx.recv().unwrap();
                Ok(Json::Null)
            }),
        );
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let id = e
            .try_submit(
                "t",
                JobPriority::new(0).with_deadline_ms(30),
                Box::new(|_| Ok(Json::num(1.0))),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(80));
        go_tx.send(()).unwrap();
        let state = e.registry().wait_terminal(&id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Failed, "expired job is shed, not run");
        let error = e.registry().error(&id).unwrap();
        assert!(error.starts_with("deadline_exceeded"), "{error}");
    }

    #[test]
    fn deadline_sweeper_aborts_overrunning_jobs() {
        let e = engine(1);
        let id = e
            .try_submit(
                "t",
                JobPriority::new(0).with_deadline_ms(40),
                Box::new(|ctl| {
                    while !ctl.is_cancelled() {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err("stopped at a checkpoint".into())
                }),
            )
            .unwrap();
        let state = e.registry().wait_terminal(&id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Failed);
        let error = e.registry().error(&id).unwrap();
        assert!(error.starts_with("deadline_exceeded"), "{error}");
    }

    #[test]
    fn run_sync_with_deadline_reports_deadline_exceeded() {
        let e = engine(1);
        let err = e
            .run_sync_with(
                "t",
                JobPriority::new(0).with_deadline_ms(40),
                Box::new(|ctl| {
                    while !ctl.is_cancelled() {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err("stopped at a checkpoint".into())
                }),
            )
            .unwrap_err();
        assert!(matches!(err, JobError::DeadlineExceeded(_)), "{err:?}");
        assert!(err.to_string().starts_with("deadline_exceeded"), "{err}");
    }

    #[test]
    fn watchdog_condemns_stuck_workers_and_respawns() {
        let e = engine(1);
        e.set_watchdog(Some(Duration::from_millis(50)));
        // A wedged job: ignores its token, blocks on a channel.
        let (wedge_tx, wedge_rx) = std::sync::mpsc::channel::<()>();
        let id = e
            .try_submit(
                "t",
                JobPriority::default(),
                Box::new(move |_| {
                    wedge_rx.recv().ok();
                    Ok(Json::Null)
                }),
            )
            .unwrap();
        let state = e.registry().wait_terminal(&id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Failed);
        let error = e.registry().error(&id).unwrap();
        assert!(error.starts_with("watchdog"), "{error}");
        assert!(e.watchdog_respawns() >= 1);
        // The replacement worker keeps the (single) shard serving.
        let out = e.run_sync("t", Box::new(|_| Ok(Json::num(2.0)))).unwrap();
        assert_eq!(out.as_f64(), Some(2.0));
        // Unwedge the condemned thread so shutdown can join it.
        wedge_tx.send(()).ok();
    }

    #[test]
    fn resolve_backlog_defaults_and_clamps() {
        assert_eq!(resolve_backlog(0), DEFAULT_MAX_BACKLOG);
        assert_eq!(resolve_backlog(7), 7);
        assert_eq!(resolve_backlog(usize::MAX), MAX_BACKLOG_LIMIT);
    }
}
