//! Coordinator metrics: lock-protected counters + latency reservoir,
//! snapshotted to JSON for the `stats` op and the benches.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::Json;

use super::state::JobState;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    errors: u64,
    plans: u64,
    eval_batches: u64,
    eval_candidates: u64,
    /// Jobs accepted by the engine (async submits + sync heavy ops).
    jobs_submitted: u64,
    jobs_done: u64,
    jobs_failed: u64,
    jobs_cancelled: u64,
    /// Submits rejected at admission (a shard queue at its backlog
    /// bound) — nothing was queued or registered for these.
    jobs_rejected: u64,
    /// Jobs that missed their binding deadline: shed at pop, aborted by
    /// the deadline sweeper, or timed out by a synchronous waiter.
    jobs_deadline_exceeded: u64,
    /// Stuck workers condemned and replaced by the engine watchdog.
    watchdog_respawns: u64,
    /// Client-visible retry accounting is client-side; these count the
    /// server's own degraded-mode probe reattachments.
    journal_reattaches: u64,
    /// Solve-cache accounting: `plan` lookups that hit / missed, plus
    /// inserts and capacity evictions.  All zero when the server runs
    /// without `--cache-capacity`.
    cache_hits: u64,
    cache_misses: u64,
    cache_inserts: u64,
    cache_evictions: u64,
    /// Microsecond latencies of the most recent requests (ring buffer).
    latencies_us: Vec<u64>,
    latency_pos: usize,
    /// Microsecond time-in-queue of the most recently started jobs
    /// (ring buffer, same reservoir scheme as request latencies).
    queue_waits_us: Vec<u64>,
    queue_wait_pos: usize,
}

const RESERVOIR: usize = 4096;

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency: Duration, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if !ok {
            m.errors += 1;
        }
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        if m.latencies_us.len() < RESERVOIR {
            m.latencies_us.push(us);
        } else {
            let pos = m.latency_pos;
            m.latencies_us[pos] = us;
            m.latency_pos = (pos + 1) % RESERVOIR;
        }
    }

    pub fn record_plan(&self) {
        self.inner.lock().unwrap().plans += 1;
    }

    /// One evaluator execution scoring `candidates` candidates.
    pub fn record_eval_batch(&self, candidates: usize) {
        let mut m = self.inner.lock().unwrap();
        m.eval_batches += 1;
        m.eval_candidates += candidates as u64;
    }

    /// One job accepted by the engine.
    pub fn record_job_submitted(&self) {
        self.inner.lock().unwrap().jobs_submitted += 1;
    }

    /// One submit rejected at the backlog bound.
    pub fn record_job_rejected(&self) {
        self.inner.lock().unwrap().jobs_rejected += 1;
    }

    /// One job that missed its binding deadline.
    pub fn record_deadline_exceeded(&self) {
        self.inner.lock().unwrap().jobs_deadline_exceeded += 1;
    }

    /// One stuck worker condemned and replaced by the watchdog.
    pub fn record_watchdog_respawn(&self) {
        self.inner.lock().unwrap().watchdog_respawns += 1;
    }

    /// One successful journal reattach after degraded mode.
    pub fn record_journal_reattach(&self) {
        self.inner.lock().unwrap().journal_reattaches += 1;
    }

    /// One solve-cache lookup that served a stored outcome.
    pub fn record_cache_hit(&self) {
        self.inner.lock().unwrap().cache_hits += 1;
    }

    /// One solve-cache lookup that fell through to the solver.
    pub fn record_cache_miss(&self) {
        self.inner.lock().unwrap().cache_misses += 1;
    }

    /// One outcome stored in the solve cache.
    pub fn record_cache_insert(&self) {
        self.inner.lock().unwrap().cache_inserts += 1;
    }

    /// One LRU entry evicted to make room.
    pub fn record_cache_evict(&self) {
        self.inner.lock().unwrap().cache_evictions += 1;
    }

    /// One job's time-in-queue (admission to worker pickup).
    pub fn record_queue_wait(&self, wait: Duration) {
        let mut m = self.inner.lock().unwrap();
        let us = wait.as_micros().min(u128::from(u64::MAX)) as u64;
        if m.queue_waits_us.len() < RESERVOIR {
            m.queue_waits_us.push(us);
        } else {
            let pos = m.queue_wait_pos;
            m.queue_waits_us[pos] = us;
            m.queue_wait_pos = (pos + 1) % RESERVOIR;
        }
    }

    /// One job reaching a terminal state (counted by its final registry
    /// state, so a cancel that raced a finish counts as cancelled).
    pub fn record_job_end(&self, state: &JobState) {
        let mut m = self.inner.lock().unwrap();
        match state {
            JobState::Done => m.jobs_done += 1,
            JobState::Failed => m.jobs_failed += 1,
            JobState::Cancelled => m.jobs_cancelled += 1,
            JobState::Queued | JobState::Running => {}
        }
    }

    /// The retry hint attached to v2 `busy` rejections: the queue-wait
    /// p50 (how long freshly admitted work is currently waiting for a
    /// worker), in milliseconds, clamped to [1, 60000].  With an empty
    /// reservoir (no job has started yet) a conservative 50ms default
    /// keeps clients from hammering a cold server.
    pub fn retry_after_ms(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        if m.queue_waits_us.is_empty() {
            return 50;
        }
        let waits = sorted(&m.queue_waits_us);
        let p50_us = pct(&waits, 0.50);
        ((p50_us / 1e3).ceil() as u64).clamp(1, 60_000)
    }

    pub fn snapshot(&self) -> Json {
        let m = self.inner.lock().unwrap();
        let lat = sorted(&m.latencies_us);
        let waits = sorted(&m.queue_waits_us);
        let avg_batch = if m.eval_batches == 0 {
            0.0
        } else {
            m.eval_candidates as f64 / m.eval_batches as f64
        };
        Json::obj(vec![
            ("requests", Json::num(m.requests as f64)),
            ("errors", Json::num(m.errors as f64)),
            ("plans", Json::num(m.plans as f64)),
            ("eval_batches", Json::num(m.eval_batches as f64)),
            ("eval_candidates", Json::num(m.eval_candidates as f64)),
            ("avg_batch_size", Json::num(avg_batch)),
            ("jobs_submitted", Json::num(m.jobs_submitted as f64)),
            ("jobs_done", Json::num(m.jobs_done as f64)),
            ("jobs_failed", Json::num(m.jobs_failed as f64)),
            ("jobs_cancelled", Json::num(m.jobs_cancelled as f64)),
            ("jobs_rejected", Json::num(m.jobs_rejected as f64)),
            ("jobs_deadline_exceeded", Json::num(m.jobs_deadline_exceeded as f64)),
            ("watchdog_respawns", Json::num(m.watchdog_respawns as f64)),
            ("journal_reattaches", Json::num(m.journal_reattaches as f64)),
            ("cache_hits", Json::num(m.cache_hits as f64)),
            ("cache_misses", Json::num(m.cache_misses as f64)),
            ("cache_inserts", Json::num(m.cache_inserts as f64)),
            ("cache_evictions", Json::num(m.cache_evictions as f64)),
            ("latency_us_p50", Json::num(pct(&lat, 0.50))),
            ("latency_us_p95", Json::num(pct(&lat, 0.95))),
            ("latency_us_p99", Json::num(pct(&lat, 0.99))),
            ("queue_wait_us_p50", Json::num(pct(&waits, 0.50))),
            ("queue_wait_us_p95", Json::num(pct(&waits, 0.95))),
        ])
    }
}

fn sorted(us: &[u64]) -> Vec<f64> {
    let mut v: Vec<f64> = us.iter().map(|&u| u as f64).collect();
    v.sort_by(f64::total_cmp);
    v
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        0.0
    } else {
        sorted[((sorted.len() - 1) as f64 * p) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(Duration::from_micros(100), true);
        m.record_request(Duration::from_micros(300), false);
        m.record_plan();
        m.record_eval_batch(64);
        m.record_eval_batch(16);
        m.record_job_submitted();
        m.record_job_submitted();
        m.record_job_end(&JobState::Done);
        m.record_job_end(&JobState::Cancelled);
        m.record_job_rejected();
        m.record_deadline_exceeded();
        m.record_watchdog_respawn();
        m.record_journal_reattach();
        m.record_queue_wait(Duration::from_micros(250));
        m.record_queue_wait(Duration::from_micros(750));
        m.record_cache_miss();
        m.record_cache_insert();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_evict();
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("plans").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("avg_batch_size").unwrap().as_f64(), Some(40.0));
        assert_eq!(s.get("jobs_submitted").unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("jobs_done").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("jobs_cancelled").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("jobs_failed").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("jobs_rejected").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("jobs_deadline_exceeded").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("watchdog_respawns").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("journal_reattaches").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("cache_hits").unwrap().as_f64(), Some(2.0));
        assert_eq!(s.get("cache_misses").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("cache_inserts").unwrap().as_f64(), Some(1.0));
        assert_eq!(s.get("cache_evictions").unwrap().as_f64(), Some(1.0));
        assert!(s.get("latency_us_p95").unwrap().as_f64().unwrap() >= 100.0);
        // Two samples: floor-indexed percentiles both land on the lower
        // sample (index (n-1)*p truncates to 0), like the latency pins.
        assert_eq!(s.get("queue_wait_us_p50").unwrap().as_f64(), Some(250.0));
        assert!(s.get("queue_wait_us_p95").unwrap().as_f64().unwrap() >= 250.0);
    }

    #[test]
    fn retry_after_tracks_the_queue_wait_p50() {
        let m = Metrics::new();
        assert_eq!(m.retry_after_ms(), 50, "cold default");
        for us in [2_000u64, 4_000, 900_000] {
            m.record_queue_wait(Duration::from_micros(us));
        }
        // Three samples: floor-indexed p50 lands on the middle one (4ms).
        assert_eq!(m.retry_after_ms(), 4);
        // Sub-millisecond waits round up to the 1ms floor, never 0.
        let m = Metrics::new();
        m.record_queue_wait(Duration::from_micros(10));
        assert_eq!(m.retry_after_ms(), 1);
    }

    #[test]
    fn reservoir_wraps() {
        let m = Metrics::new();
        for i in 0..(RESERVOIR + 10) {
            m.record_request(Duration::from_micros(i as u64), true);
        }
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some((RESERVOIR + 10) as f64));
    }
}
