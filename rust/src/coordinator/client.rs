//! The first-class blocking Rust client for the coordinator protocol.
//!
//! [`Client`] holds one persistent TCP connection and speaks the typed
//! [`super::api`] surface at protocol **v2**: every request is encoded
//! from an [`api::Request`], every reply decodes into the op's typed
//! response struct, and failures come back as [`ClientError`] — with
//! admission-control rejections surfaced as the typed
//! [`BusyInfo`](api::BusyInfo) (shard, backlog and the server's
//! `retry_after_ms` hint, which [`Client::submit_with_retry`] honours).
//!
//! Pipelining: the server executes at most one request per connection at
//! a time but buffers up to 64 pending lines, so [`Client::send`] /
//! [`Client::recv`] let a caller keep several requests in flight on one
//! socket; responses come back in request order.  The convenience
//! methods ([`Client::plan`], [`Client::sweep`], …) are
//! `send`-then-`recv` and therefore must not be interleaved with
//! outstanding pipelined sends — [`Client::call`] enforces that.
//!
//! ```no_run
//! use botsched::coordinator::api::PlanRequest;
//! use botsched::coordinator::Client;
//!
//! # fn main() -> Result<(), botsched::coordinator::ClientError> {
//! let addr: std::net::SocketAddr = "127.0.0.1:7077".parse().unwrap();
//! let mut client = Client::connect(&addr)?;
//! let plan = client.plan(&PlanRequest::new(80.0).with_policy("mp"))?;
//! println!("makespan {:.1}s over {} VMs", plan.makespan, plan.vms.len());
//! # Ok(())
//! # }
//! ```

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::util::Json;

use super::api::{self, ApiError, BusyInfo};

/// Connection options for [`Client::connect_with`].
#[derive(Debug, Clone, Default)]
pub struct ClientOptions {
    /// Bound on the TCP connect; `None` = the OS default.
    pub connect_timeout: Option<Duration>,
    /// Per-reply read bound; `None` = wait indefinitely (synchronous
    /// sweeps/campaigns can legitimately run for minutes).  An expired
    /// timeout *poisons* the connection — part of the reply may already
    /// be consumed, so the client refuses further use; reconnect rather
    /// than retrying on the same socket.
    pub read_timeout: Option<Duration>,
    /// Per-request write bound; `None` = the OS default.
    pub write_timeout: Option<Duration>,
}

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect, read, write, connection closed).
    Io(std::io::Error),
    /// The server rejected the request at admission control; retry
    /// after `retry_after_ms` or shed load.
    Busy(BusyInfo),
    /// The server answered with a structured protocol error.
    Api(ApiError),
    /// The reply was not something this client understands.
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Busy(b) => {
                write!(f, "busy: shard {} backlog {} is at its bound", b.shard, b.backlog)?;
                if let Some(ms) = b.retry_after_ms {
                    write!(f, " (retry after ~{ms}ms)")?;
                }
                Ok(())
            }
            ClientError::Api(e) => write!(f, "{e}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Api(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// A typed view of one job object (`status` replies and `jobs` rows);
/// `raw` keeps the full payload for fields this view does not lift.
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    pub id: String,
    pub op: String,
    pub state: String,
    /// `(done, total)` units of work, once the job published any.
    pub progress: Option<(u64, u64)>,
    /// The reply body of a finished (`"done"`) job.
    pub result: Option<Json>,
    /// The failure message of a `"failed"` job.
    pub error: Option<String>,
    /// Streaming partial rows (respecting the `partials_from` cursor).
    pub partial_results: Vec<Json>,
    /// Cursor to pass as the next poll's `partials_from`.
    pub partials_next: Option<u64>,
    pub raw: Json,
}

impl JobStatus {
    fn decode(j: &Json) -> Result<Self, ClientError> {
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ClientError::Protocol(format!("job object missing \"{k}\": {j}")))
        };
        Ok(Self {
            id: field("id")?,
            op: field("op")?,
            state: field("state")?,
            progress: match (
                j.path(&["progress", "done"]).and_then(Json::as_u64),
                j.path(&["progress", "total"]).and_then(Json::as_u64),
            ) {
                (Some(d), Some(t)) => Some((d, t)),
                _ => None,
            },
            result: j.get("result").cloned(),
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
            partial_results: j
                .get("partial_results")
                .and_then(Json::as_arr)
                .map(<[Json]>::to_vec)
                .unwrap_or_default(),
            partials_next: j.get("partials_next").and_then(Json::as_u64),
            raw: j.clone(),
        })
    }

    /// Whether the job reached a terminal state.
    pub fn is_terminal(&self) -> bool {
        matches!(self.state.as_str(), "done" | "failed" | "cancelled")
    }
}

/// A blocking coordinator client over one persistent connection.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Requests sent but not yet answered (pipelining depth).
    pending: VecDeque<&'static str>,
    /// Set when a read failed mid-reply (e.g. a `read_timeout` fired
    /// with half a line consumed): the stream position is unknowable, so
    /// every further use would misframe replies.  Poisoned clients error
    /// on every call — reconnect instead.
    poisoned: bool,
}

impl Client {
    /// Connect with default options.
    pub fn connect(addr: &SocketAddr) -> Result<Self, ClientError> {
        Self::connect_with(addr, &ClientOptions::default())
    }

    /// Connect with explicit connect/read/write timeouts.
    pub fn connect_with(addr: &SocketAddr, opts: &ClientOptions) -> Result<Self, ClientError> {
        let stream = match opts.connect_timeout {
            Some(t) => TcpStream::connect_timeout(addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(opts.read_timeout)?;
        stream.set_write_timeout(opts.write_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader, pending: VecDeque::new(), poisoned: false })
    }

    /// Requests currently in flight on this connection.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    // ----- pipelining ---------------------------------------------------

    fn check_poisoned(&self) -> Result<(), ClientError> {
        if self.poisoned {
            return Err(ClientError::Protocol(
                "connection poisoned by an earlier mid-reply read failure — reconnect".into(),
            ));
        }
        Ok(())
    }

    /// Send one request without waiting for its reply (pipelining).
    /// Replies arrive in request order via [`Client::recv`].
    pub fn send(&mut self, req: &api::Request) -> Result<(), ClientError> {
        self.check_poisoned()?;
        let line = req.encode_versioned(api::V2).to_string();
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        self.pending.push_back(req.op());
        Ok(())
    }

    /// Receive the next pipelined reply body (the `ok:true` object).
    /// Errors are classified: `busy` → [`ClientError::Busy`], other
    /// protocol errors → [`ClientError::Api`].
    ///
    /// A transport-level read failure (including an expired
    /// `read_timeout`) may leave part of the reply consumed, so it
    /// poisons the connection: the request/reply framing can no longer
    /// be trusted and every further call errors — reconnect instead.
    pub fn recv(&mut self) -> Result<Json, ClientError> {
        self.check_poisoned()?;
        let mut line = String::new();
        let n = match self.reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) => {
                self.poisoned = true;
                return Err(ClientError::Io(e));
            }
        };
        self.pending.pop_front();
        if n == 0 {
            self.poisoned = true;
            return Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        let body = Json::parse(line.trim())
            .map_err(|e| ClientError::Protocol(format!("bad reply json: {e}")))?;
        if let Some(err) = ApiError::decode(&body) {
            if let Some(busy) = err.busy_info() {
                return Err(ClientError::Busy(busy));
            }
            return Err(ClientError::Api(err));
        }
        Ok(body)
    }

    /// One synchronous round trip.  Refuses to run with pipelined
    /// requests outstanding (their replies would be misattributed).
    pub fn call(&mut self, req: &api::Request) -> Result<Json, ClientError> {
        if !self.pending.is_empty() {
            return Err(ClientError::Protocol(format!(
                "{} pipelined request(s) outstanding — drain with recv() first",
                self.pending.len()
            )));
        }
        self.send(req)?;
        self.recv()
    }

    // ----- typed ops ----------------------------------------------------

    /// `ping`: liveness probe.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.call(&api::Request::Ping).map(|_| ())
    }

    /// `plan`: solve one budget through a named policy.
    pub fn plan(&mut self, req: &api::PlanRequest) -> Result<api::PlanResponse, ClientError> {
        let body = self.call(&api::Request::Plan(req.clone()))?;
        api::PlanResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `simulate`: plan + execute once on the simulated cloud.
    pub fn simulate(
        &mut self,
        req: &api::SimulateRequest,
    ) -> Result<api::SimulateResponse, ClientError> {
        let body = self.call(&api::Request::Simulate(req.clone()))?;
        api::SimulateResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `sweep`: budget × policy sweep on the job engine.
    pub fn sweep(&mut self, req: &api::SweepRequest) -> Result<api::SweepResponse, ClientError> {
        let body = self.call(&api::Request::Sweep(req.clone()))?;
        api::SweepResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `campaign`: closed-loop execution (optionally Monte-Carlo
    /// replicated) on the job engine.
    pub fn campaign(
        &mut self,
        req: &api::CampaignRequest,
    ) -> Result<api::CampaignResponse, ClientError> {
        let body = self.call(&api::Request::Campaign(req.clone()))?;
        api::CampaignResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `estimate_perf`: bootstrap the performance matrix estimate.
    pub fn estimate_perf(
        &mut self,
        req: &api::EstimatePerfRequest,
    ) -> Result<api::EstimatePerfResponse, ClientError> {
        let body = self.call(&api::Request::EstimatePerf(req.clone()))?;
        api::EstimatePerfResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `list_policies`: the registered scheduling policies.
    pub fn list_policies(&mut self) -> Result<Vec<api::PolicyInfo>, ClientError> {
        let body = self.call(&api::Request::ListPolicies)?;
        decode_named_list(&body, "policies")
            .map(|rows| {
                rows.into_iter()
                    .map(|(name, description)| api::PolicyInfo { name, description })
                    .collect()
            })
            .map_err(ClientError::Protocol)
    }

    /// `list_scenarios`: the named workload presets.
    pub fn list_scenarios(&mut self) -> Result<Vec<api::ScenarioInfo>, ClientError> {
        let body = self.call(&api::Request::ListScenarios)?;
        decode_named_list(&body, "scenarios")
            .map(|rows| {
                rows.into_iter()
                    .map(|(name, description)| api::ScenarioInfo { name, description })
                    .collect()
            })
            .map_err(ClientError::Protocol)
    }

    /// `describe` (v2): the machine-readable op/field schema.
    pub fn describe(&mut self) -> Result<Json, ClientError> {
        let body = self.call(&api::Request::Describe)?;
        body.get("schema")
            .cloned()
            .ok_or_else(|| ClientError::Protocol(format!("describe reply missing schema: {body}")))
    }

    /// `persist` (v2): journal + solve-cache durability stats; pass
    /// `compact: true` to rewrite the journal down to its live records
    /// first.
    pub fn persist(&mut self, compact: bool) -> Result<Json, ClientError> {
        let action =
            if compact { api::PersistAction::Compact } else { api::PersistAction::Stats };
        let body = self.call(&api::Request::Persist(api::PersistRequest { action }))?;
        body.get("persist")
            .cloned()
            .ok_or_else(|| ClientError::Protocol(format!("persist reply missing persist: {body}")))
    }

    /// `stats`: request metrics + engine queue gauges.
    pub fn stats(&mut self) -> Result<api::StatsResponse, ClientError> {
        let body = self.call(&api::Request::Stats)?;
        api::StatsResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `submit`: run a typed request asynchronously; returns the job id.
    pub fn submit(
        &mut self,
        job: &api::Request,
        placement: api::Placement,
    ) -> Result<String, ClientError> {
        self.submit_raw(job.encode(), placement)
    }

    /// [`Client::submit`] for an already-encoded job object (the CLI's
    /// pass-through path).
    pub fn submit_raw(
        &mut self,
        job: Json,
        placement: api::Placement,
    ) -> Result<String, ClientError> {
        let body = self.call(&api::Request::Submit(api::SubmitRequest { job, placement }))?;
        body.get("job_id")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ClientError::Protocol(format!("submit reply missing job_id: {body}")))
    }

    /// [`Client::submit`] with bounded retries on `busy`, sleeping the
    /// server's `retry_after_ms` hint (capped at 2s per attempt) between
    /// attempts.  Returns the final `busy` error once retries run out.
    pub fn submit_with_retry(
        &mut self,
        job: &api::Request,
        placement: api::Placement,
        max_retries: usize,
    ) -> Result<String, ClientError> {
        let encoded = job.encode();
        let mut attempt = 0;
        loop {
            match self.submit_raw(encoded.clone(), placement) {
                Err(ClientError::Busy(busy)) if attempt < max_retries => {
                    attempt += 1;
                    let ms = busy.retry_after_ms.unwrap_or(50).clamp(1, 2_000);
                    std::thread::sleep(Duration::from_millis(ms));
                }
                other => return other,
            }
        }
    }

    /// `status`: one job's state/progress/partials.  `partials_from` is
    /// the previous reply's `partials_next` streaming cursor.
    pub fn status(
        &mut self,
        job_id: &str,
        partials_from: Option<u64>,
    ) -> Result<JobStatus, ClientError> {
        let body = self.call(&api::Request::Status(api::StatusRequest {
            job_id: job_id.to_string(),
            partials_from,
        }))?;
        let job = body
            .get("job")
            .ok_or_else(|| ClientError::Protocol(format!("status reply missing job: {body}")))?;
        JobStatus::decode(job)
    }

    /// `jobs`: every job with state + progress.
    pub fn jobs(&mut self) -> Result<Vec<JobStatus>, ClientError> {
        let body = self.call(&api::Request::Jobs)?;
        body.get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| ClientError::Protocol(format!("jobs reply missing jobs: {body}")))?
            .iter()
            .map(JobStatus::decode)
            .collect()
    }

    /// `cancel`: fire a job's cancel token; `true` when the job existed
    /// and had not already finished.
    pub fn cancel(&mut self, job_id: &str) -> Result<bool, ClientError> {
        let body = self
            .call(&api::Request::Cancel(api::CancelRequest { job_id: job_id.to_string() }))?;
        body.get("cancelled")
            .and_then(Json::as_bool)
            .ok_or_else(|| ClientError::Protocol(format!("cancel reply malformed: {body}")))
    }

    /// Poll `status` until the job reaches a terminal state (or
    /// `timeout` expires — then the last observed status is returned).
    pub fn wait_job(
        &mut self,
        job_id: &str,
        poll: Duration,
        timeout: Duration,
    ) -> Result<JobStatus, ClientError> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let status = self.status(job_id, None)?;
            if status.is_terminal() || std::time::Instant::now() >= deadline {
                return Ok(status);
            }
            std::thread::sleep(poll);
        }
    }

    /// `shutdown`: stop the coordinator.
    pub fn shutdown(&mut self) -> Result<(), ClientError> {
        self.call(&api::Request::Shutdown).map(|_| ())
    }
}

/// Decode a `[{"name":…,"description":…},…]` listing field.
fn decode_named_list(body: &Json, key: &str) -> Result<Vec<(String, String)>, String> {
    body.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("reply missing \"{key}\": {body}"))?
        .iter()
        .map(|row| {
            let get = |k: &str| {
                row.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| format!("listing row missing \"{k}\": {row}"))
            };
            Ok((get("name")?, get("description")?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_status_decodes_progress_and_partials() {
        let j = Json::parse(
            r#"{"id":"j-1","op":"campaign","state":"running",
                "progress":{"done":3,"total":8},
                "partial_results":[{"wall_clock":1.0}],"partials_next":3}"#,
        )
        .unwrap();
        let s = JobStatus::decode(&j).unwrap();
        assert_eq!(s.id, "j-1");
        assert_eq!(s.progress, Some((3, 8)));
        assert_eq!(s.partial_results.len(), 1);
        assert_eq!(s.partials_next, Some(3));
        assert!(!s.is_terminal());
        let done = Json::parse(r#"{"id":"j-2","op":"plan","state":"done","result":{"ok":true}}"#)
            .unwrap();
        let s = JobStatus::decode(&done).unwrap();
        assert!(s.is_terminal());
        assert!(s.result.is_some());
        assert!(JobStatus::decode(&Json::parse(r#"{"id":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn busy_error_displays_the_retry_hint() {
        let e = ClientError::Busy(BusyInfo { shard: 2, backlog: 256, retry_after_ms: Some(40) });
        let s = e.to_string();
        assert!(s.contains("shard 2") && s.contains("40ms"), "{s}");
    }
}
