//! The first-class blocking Rust client for the coordinator protocol.
//!
//! [`Client`] holds one persistent TCP connection and speaks the typed
//! [`super::api`] surface at protocol **v2**: every request is encoded
//! from an [`api::Request`], every reply decodes into the op's typed
//! response struct, and failures come back as [`ClientError`] — with
//! admission-control rejections surfaced as the typed
//! [`BusyInfo`](api::BusyInfo) (shard, backlog and the server's
//! `retry_after_ms` hint, which [`Client::submit_with_retry`] honours).
//!
//! Pipelining: the server executes at most one request per connection at
//! a time but buffers up to 64 pending lines, so [`Client::send`] /
//! [`Client::recv`] let a caller keep several requests in flight on one
//! socket; responses come back in request order, and
//! [`Client::recv_within`] drains them under a bounded wait without
//! poisoning the connection (what the open-loop [`crate::loadgen`]
//! driver uses between scheduled sends).  The convenience
//! methods ([`Client::plan`], [`Client::sweep`], …) are
//! `send`-then-`recv` and therefore must not be interleaved with
//! outstanding pipelined sends — [`Client::call`] enforces that.
//!
//! Transient failures can be retried transparently: configure a
//! [`RetryPolicy`] on [`ClientOptions::retry`] and every typed op
//! re-runs on `busy` rejections and (for idempotent ops) transport
//! errors, with jittered exponential backoff and automatic reconnects.
//! The default is [`RetryPolicy::none`] — the historical fail-fast
//! behaviour.  Accounting lands on [`Client::retry_stats`].
//!
//! ```no_run
//! use botsched::coordinator::api::PlanRequest;
//! use botsched::coordinator::Client;
//!
//! # fn main() -> Result<(), botsched::coordinator::ClientError> {
//! let addr: std::net::SocketAddr = "127.0.0.1:7077".parse().unwrap();
//! let mut client = Client::connect(&addr)?;
//! let plan = client.plan(&PlanRequest::new(80.0).with_policy("mp"))?;
//! println!("makespan {:.1}s over {} VMs", plan.makespan, plan.vms.len());
//! # Ok(())
//! # }
//! ```

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::util::Json;

use super::api::{self, ApiError, BusyInfo};

/// Connection options for [`Client::connect_with`].
#[derive(Debug, Clone, Default)]
pub struct ClientOptions {
    /// Bound on the TCP connect; `None` = the OS default.
    pub connect_timeout: Option<Duration>,
    /// Per-reply read bound; `None` = wait indefinitely (synchronous
    /// sweeps/campaigns can legitimately run for minutes).  An expired
    /// timeout *poisons* the connection — part of the reply may already
    /// be consumed, so the client refuses further use; reconnect rather
    /// than retrying on the same socket.
    pub read_timeout: Option<Duration>,
    /// Per-request write bound; `None` = the OS default.
    pub write_timeout: Option<Duration>,
    /// How typed ops retry transient failures (`busy` rejections and,
    /// for idempotent ops, transport errors).  The default
    /// [`RetryPolicy::none`] keeps the historical fail-fast behaviour.
    pub retry: RetryPolicy,
}

/// How a [`Client`] retries transient failures.
///
/// Applies to every typed op: `busy` admission rejections always
/// qualify (nothing was enqueued server-side), transport errors qualify
/// only for idempotent ops — [`Client::submit`] never re-sends after an
/// I/O failure because the server may already have accepted the job —
/// and structured API errors such as `bad_request` are never retried.
/// Delays double per attempt from `base_delay`, are capped at
/// `max_delay`, and shed a uniform downward `jitter`; a server
/// `retry_after_ms` hint replaces the computed delay (the cap still
/// applies).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (`1` = no retries).
    pub max_attempts: u32,
    /// Bound on total elapsed time across attempts; `None` = unbounded.
    pub max_elapsed: Option<Duration>,
    /// First retry delay (doubles each further attempt).
    pub base_delay: Duration,
    /// Upper bound on any single delay.
    pub max_delay: Duration,
    /// Fraction of each delay randomised away, in `[0, 1]`: the sleep
    /// is uniform in `[delay * (1 - jitter), delay]`.
    pub jitter: f64,
    /// Jitter RNG seed; `None` derives one from the clock.
    pub seed: Option<u64>,
}

impl RetryPolicy {
    /// No retries: every failure surfaces immediately (the default).
    pub fn none() -> Self {
        Self {
            max_attempts: 1,
            max_elapsed: None,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_millis(2_000),
            jitter: 0.0,
            seed: None,
        }
    }

    /// A sane interactive default: up to 5 attempts over at most 30s,
    /// 50ms → 2s exponential backoff with 20% jitter.
    pub fn standard() -> Self {
        Self {
            max_attempts: 5,
            max_elapsed: Some(Duration::from_secs(30)),
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_millis(2_000),
            jitter: 0.2,
            seed: None,
        }
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Client-side retry accounting (see [`Client::retry_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Attempt re-runs performed across all ops.
    pub retries: u64,
    /// Reconnects dialled to recover from transport errors.
    pub reconnects: u64,
    /// Calls whose retry budget ran out before the error cleared.
    pub gave_up: u64,
}

/// The delay slept after (1-based) `attempt` fails, in milliseconds.
/// `unit` is a uniform sample in `[0, 1)` driving the downward jitter.
fn backoff_ms(policy: &RetryPolicy, attempt: u32, hint_ms: Option<u64>, unit: f64) -> u64 {
    let raw = match hint_ms {
        Some(ms) => ms as f64,
        None => {
            let exp = attempt.saturating_sub(1).min(20);
            policy.base_delay.as_millis() as f64 * (1u64 << exp) as f64
        }
    };
    let capped = raw.min(policy.max_delay.as_millis() as f64);
    let jittered = capped * (1.0 - policy.jitter.clamp(0.0, 1.0) * unit);
    jittered.max(1.0) as u64
}

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect, read, write, connection closed).
    Io(std::io::Error),
    /// The server rejected the request at admission control; retry
    /// after `retry_after_ms` or shed load.
    Busy(BusyInfo),
    /// The server answered with a structured protocol error.
    Api(ApiError),
    /// The reply was not something this client understands.
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Busy(b) => {
                write!(f, "busy: shard {} backlog {} is at its bound", b.shard, b.backlog)?;
                if let Some(ms) = b.retry_after_ms {
                    write!(f, " (retry after ~{ms}ms)")?;
                }
                Ok(())
            }
            ClientError::Api(e) => write!(f, "{e}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Api(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// A typed view of one job object (`status` replies and `jobs` rows);
/// `raw` keeps the full payload for fields this view does not lift.
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    pub id: String,
    pub op: String,
    pub state: String,
    /// `(done, total)` units of work, once the job published any.
    pub progress: Option<(u64, u64)>,
    /// The reply body of a finished (`"done"`) job.
    pub result: Option<Json>,
    /// The failure message of a `"failed"` job.
    pub error: Option<String>,
    /// Streaming partial rows (respecting the `partials_from` cursor).
    pub partial_results: Vec<Json>,
    /// Cursor to pass as the next poll's `partials_from`.
    pub partials_next: Option<u64>,
    pub raw: Json,
}

impl JobStatus {
    fn decode(j: &Json) -> Result<Self, ClientError> {
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ClientError::Protocol(format!("job object missing \"{k}\": {j}")))
        };
        Ok(Self {
            id: field("id")?,
            op: field("op")?,
            state: field("state")?,
            progress: match (
                j.path(&["progress", "done"]).and_then(Json::as_u64),
                j.path(&["progress", "total"]).and_then(Json::as_u64),
            ) {
                (Some(d), Some(t)) => Some((d, t)),
                _ => None,
            },
            result: j.get("result").cloned(),
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
            partial_results: j
                .get("partial_results")
                .and_then(Json::as_arr)
                .map(<[Json]>::to_vec)
                .unwrap_or_default(),
            partials_next: j.get("partials_next").and_then(Json::as_u64),
            raw: j.clone(),
        })
    }

    /// Whether the job reached a terminal state.
    pub fn is_terminal(&self) -> bool {
        matches!(self.state.as_str(), "done" | "failed" | "cancelled")
    }
}

/// A typed view of the `health` reply; `raw` keeps the full report for
/// subsystem fields this view does not lift.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthReport {
    /// `"ok"`, or `"degraded"` when a subsystem is running impaired
    /// (e.g. the journal detached after write failures).
    pub status: String,
    pub uptime_ms: u64,
    /// Whether the journal is attached; `None` when the server runs
    /// without `--journal`.
    pub journal_attached: Option<bool>,
    pub raw: Json,
}

impl HealthReport {
    fn decode(j: &Json) -> Result<Self, ClientError> {
        let status = j
            .get("status")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ClientError::Protocol(format!("health reply missing status: {j}")))?;
        let journal_attached = match j.path(&["journal", "enabled"]).and_then(Json::as_bool) {
            Some(true) => j.path(&["journal", "attached"]).and_then(Json::as_bool),
            _ => None,
        };
        Ok(Self {
            status,
            uptime_ms: j.get("uptime_ms").and_then(Json::as_u64).unwrap_or(0),
            journal_attached,
            raw: j.clone(),
        })
    }

    /// Whether every subsystem reports healthy.
    pub fn is_ok(&self) -> bool {
        self.status == "ok"
    }
}

/// A blocking coordinator client over one persistent connection.
pub struct Client {
    addr: SocketAddr,
    opts: ClientOptions,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Requests sent but not yet answered (pipelining depth).
    pending: VecDeque<&'static str>,
    /// Partially read reply line carried across [`Client::recv_within`]
    /// timeouts.  A bounded wait can expire with half a line consumed
    /// from the socket; the fragment stays here so the next receive
    /// resumes the same line instead of misframing (or poisoning) the
    /// connection.
    partial: String,
    /// Set when a read failed mid-reply (e.g. a `read_timeout` fired
    /// with half a line consumed): the stream position is unknowable, so
    /// every further use would misframe replies.  Poisoned clients error
    /// on every call — reconnect instead.
    poisoned: bool,
    /// xorshift64 state for retry jitter.
    rng: u64,
    retry_stats: RetryStats,
}

impl Client {
    /// Connect with default options.
    pub fn connect(addr: &SocketAddr) -> Result<Self, ClientError> {
        Self::connect_with(addr, &ClientOptions::default())
    }

    /// Connect with explicit connect/read/write timeouts.
    pub fn connect_with(addr: &SocketAddr, opts: &ClientOptions) -> Result<Self, ClientError> {
        let (stream, reader) = Self::open(addr, opts)?;
        let seed = opts.retry.seed.unwrap_or_else(|| {
            let clock = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| u64::from(d.subsec_nanos()) ^ d.as_secs())
                .unwrap_or(0);
            clock ^ (u64::from(addr.port()) << 32)
        });
        Ok(Self {
            addr: *addr,
            opts: opts.clone(),
            stream,
            reader,
            pending: VecDeque::new(),
            partial: String::new(),
            poisoned: false,
            // xorshift64 has a fixed point at 0; force a nonzero state.
            rng: seed | 1,
            retry_stats: RetryStats::default(),
        })
    }

    fn open(
        addr: &SocketAddr,
        opts: &ClientOptions,
    ) -> Result<(TcpStream, BufReader<TcpStream>), ClientError> {
        let stream = match opts.connect_timeout {
            Some(t) => TcpStream::connect_timeout(addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(opts.read_timeout)?;
        stream.set_write_timeout(opts.write_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok((stream, reader))
    }

    /// Drop the current socket and dial a fresh one, clearing poisoning
    /// and any (now unanswerable) pipelined requests.
    pub fn reconnect(&mut self) -> Result<(), ClientError> {
        let (stream, reader) = Self::open(&self.addr, &self.opts)?;
        self.stream = stream;
        self.reader = reader;
        self.pending.clear();
        self.partial.clear();
        self.poisoned = false;
        self.retry_stats.reconnects += 1;
        Ok(())
    }

    /// Retry accounting accumulated over this client's lifetime.
    pub fn retry_stats(&self) -> RetryStats {
        self.retry_stats
    }

    /// Requests currently in flight on this connection.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    fn next_unit(&mut self) -> f64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        (x >> 11) as f64 / (1u64 << 53) as f64
    }

    // ----- pipelining ---------------------------------------------------

    fn check_poisoned(&self) -> Result<(), ClientError> {
        if self.poisoned {
            return Err(ClientError::Protocol(
                "connection poisoned by an earlier mid-reply read failure — reconnect".into(),
            ));
        }
        Ok(())
    }

    /// Send one request without waiting for its reply (pipelining).
    /// Replies arrive in request order via [`Client::recv`].
    pub fn send(&mut self, req: &api::Request) -> Result<(), ClientError> {
        self.check_poisoned()?;
        let line = req.encode_versioned(api::V2).to_string();
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        self.pending.push_back(req.op());
        Ok(())
    }

    /// Receive the next pipelined reply body (the `ok:true` object).
    /// Errors are classified: `busy` → [`ClientError::Busy`], other
    /// protocol errors → [`ClientError::Api`].
    ///
    /// A transport-level read failure (including an expired
    /// `read_timeout`) may leave part of the reply consumed, so it
    /// poisons the connection: the request/reply framing can no longer
    /// be trusted and every further call errors — reconnect instead.
    pub fn recv(&mut self) -> Result<Json, ClientError> {
        self.check_poisoned()?;
        // Resume into the shared partial-line buffer: an earlier
        // `recv_within` may have consumed part of this reply already.
        let n = match self.reader.read_line(&mut self.partial) {
            Ok(n) => n,
            Err(e) => {
                self.poisoned = true;
                return Err(ClientError::Io(e));
            }
        };
        self.pending.pop_front();
        if n == 0 && self.partial.is_empty() {
            self.poisoned = true;
            return Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        let line = std::mem::take(&mut self.partial);
        Self::classify(line.trim())
    }

    /// Wait up to `wait` for the next pipelined reply.  `Ok(None)` means
    /// no complete reply arrived in time — unlike [`Client::recv`] under
    /// `read_timeout`, this does **not** poison the connection: any
    /// half-read line is kept in an internal buffer and the next receive
    /// resumes it.  This is what lets an open-loop load generator drain
    /// replies opportunistically between scheduled sends.
    ///
    /// Returns `Ok(None)` immediately when nothing is pending.
    pub fn recv_within(&mut self, wait: Duration) -> Result<Option<Json>, ClientError> {
        self.check_poisoned()?;
        if self.pending.is_empty() {
            return Ok(None);
        }
        // A zero timeout means "blocking" to the OS; clamp up instead.
        let bounded = wait.max(Duration::from_millis(1));
        self.reader.get_ref().set_read_timeout(Some(bounded))?;
        let res = self.reader.read_line(&mut self.partial);
        // Restore the configured timeout before interpreting the result;
        // failing to restore would make later `recv` calls time out (and
        // poison) unexpectedly, so treat that as fatal for this socket.
        if let Err(e) = self.reader.get_ref().set_read_timeout(self.opts.read_timeout) {
            self.poisoned = true;
            return Err(ClientError::Io(e));
        }
        let n = match res {
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Expired quietly — the fragment (if any) stays buffered.
                return Ok(None);
            }
            Err(e) => {
                self.poisoned = true;
                return Err(ClientError::Io(e));
            }
        };
        self.pending.pop_front();
        if n == 0 && self.partial.is_empty() {
            self.poisoned = true;
            return Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        let line = std::mem::take(&mut self.partial);
        Self::classify(line.trim()).map(Some)
    }

    /// Parse and classify one reply line: `busy` → [`ClientError::Busy`],
    /// other structured errors → [`ClientError::Api`].
    fn classify(line: &str) -> Result<Json, ClientError> {
        let body = Json::parse(line)
            .map_err(|e| ClientError::Protocol(format!("bad reply json: {e}")))?;
        if let Some(err) = ApiError::decode(&body) {
            if let Some(busy) = err.busy_info() {
                return Err(ClientError::Busy(busy));
            }
            return Err(ClientError::Api(err));
        }
        Ok(body)
    }

    /// One synchronous round trip.  Refuses to run with pipelined
    /// requests outstanding (their replies would be misattributed).
    pub fn call(&mut self, req: &api::Request) -> Result<Json, ClientError> {
        if !self.pending.is_empty() {
            return Err(ClientError::Protocol(format!(
                "{} pipelined request(s) outstanding — drain with recv() first",
                self.pending.len()
            )));
        }
        self.send(req)?;
        self.recv()
    }

    /// [`Client::call`] under the configured [`RetryPolicy`]: `busy`
    /// rejections always re-run (nothing was enqueued), transport
    /// errors re-run after a reconnect only when `idempotent`, and
    /// structured API errors surface immediately.
    fn call_retrying(&mut self, req: &api::Request, idempotent: bool) -> Result<Json, ClientError> {
        let policy = self.opts.retry.clone();
        let start = std::time::Instant::now();
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            if self.poisoned && idempotent {
                self.reconnect()?;
            }
            let err = match self.call(req) {
                Ok(body) => return Ok(body),
                Err(e) => e,
            };
            let (retryable, reconnect, hint) = match &err {
                ClientError::Busy(b) => (true, false, b.retry_after_ms),
                ClientError::Io(_) => (idempotent, true, None),
                _ => (false, false, None),
            };
            let budget_left = attempt < policy.max_attempts.max(1)
                && policy.max_elapsed.is_none_or(|bound| start.elapsed() < bound);
            if !retryable || !budget_left {
                if retryable && policy.max_attempts > 1 {
                    self.retry_stats.gave_up += 1;
                }
                return Err(err);
            }
            if reconnect {
                self.reconnect()?;
            }
            self.retry_stats.retries += 1;
            let unit = self.next_unit();
            std::thread::sleep(Duration::from_millis(backoff_ms(&policy, attempt, hint, unit)));
        }
    }

    // ----- typed ops ----------------------------------------------------

    /// `ping`: liveness probe.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.call_retrying(&api::Request::Ping, true).map(|_| ())
    }

    /// `plan`: solve one budget through a named policy.
    pub fn plan(&mut self, req: &api::PlanRequest) -> Result<api::PlanResponse, ClientError> {
        let body = self.call_retrying(&api::Request::Plan(req.clone()), true)?;
        api::PlanResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `simulate`: plan + execute once on the simulated cloud.
    pub fn simulate(
        &mut self,
        req: &api::SimulateRequest,
    ) -> Result<api::SimulateResponse, ClientError> {
        let body = self.call_retrying(&api::Request::Simulate(req.clone()), true)?;
        api::SimulateResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `sweep`: budget × policy sweep on the job engine.
    pub fn sweep(&mut self, req: &api::SweepRequest) -> Result<api::SweepResponse, ClientError> {
        let body = self.call_retrying(&api::Request::Sweep(req.clone()), true)?;
        api::SweepResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `campaign`: closed-loop execution (optionally Monte-Carlo
    /// replicated) on the job engine.
    pub fn campaign(
        &mut self,
        req: &api::CampaignRequest,
    ) -> Result<api::CampaignResponse, ClientError> {
        let body = self.call_retrying(&api::Request::Campaign(req.clone()), true)?;
        api::CampaignResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `estimate_perf`: bootstrap the performance matrix estimate.
    pub fn estimate_perf(
        &mut self,
        req: &api::EstimatePerfRequest,
    ) -> Result<api::EstimatePerfResponse, ClientError> {
        let body = self.call_retrying(&api::Request::EstimatePerf(req.clone()), true)?;
        api::EstimatePerfResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `list_policies`: the registered scheduling policies.
    pub fn list_policies(&mut self) -> Result<Vec<api::PolicyInfo>, ClientError> {
        let body = self.call_retrying(&api::Request::ListPolicies, true)?;
        decode_named_list(&body, "policies")
            .map(|rows| {
                rows.into_iter()
                    .map(|(name, description)| api::PolicyInfo { name, description })
                    .collect()
            })
            .map_err(ClientError::Protocol)
    }

    /// `list_scenarios`: the named workload presets.
    pub fn list_scenarios(&mut self) -> Result<Vec<api::ScenarioInfo>, ClientError> {
        let body = self.call_retrying(&api::Request::ListScenarios, true)?;
        decode_named_list(&body, "scenarios")
            .map(|rows| {
                rows.into_iter()
                    .map(|(name, description)| api::ScenarioInfo { name, description })
                    .collect()
            })
            .map_err(ClientError::Protocol)
    }

    /// `describe` (v2): the machine-readable op/field schema.
    pub fn describe(&mut self) -> Result<Json, ClientError> {
        let body = self.call_retrying(&api::Request::Describe, true)?;
        body.get("schema")
            .cloned()
            .ok_or_else(|| ClientError::Protocol(format!("describe reply missing schema: {body}")))
    }

    /// `persist` (v2): journal + solve-cache durability stats; pass
    /// `compact: true` to rewrite the journal down to its live records
    /// first.
    pub fn persist(&mut self, compact: bool) -> Result<Json, ClientError> {
        let action =
            if compact { api::PersistAction::Compact } else { api::PersistAction::Stats };
        let req = api::Request::Persist(api::PersistRequest { action });
        let body = self.call_retrying(&req, true)?;
        body.get("persist")
            .cloned()
            .ok_or_else(|| ClientError::Protocol(format!("persist reply missing persist: {body}")))
    }

    /// `stats`: request metrics + engine queue gauges.
    pub fn stats(&mut self) -> Result<api::StatsResponse, ClientError> {
        let body = self.call_retrying(&api::Request::Stats, true)?;
        api::StatsResponse::decode(&body).map_err(ClientError::Protocol)
    }

    /// `health` (v2): overall server status + per-subsystem detail
    /// (journal attachment, cache, shard liveness, uptime).
    pub fn health(&mut self) -> Result<HealthReport, ClientError> {
        let body = self.call_retrying(&api::Request::Health, true)?;
        let report = body
            .get("health")
            .ok_or_else(|| ClientError::Protocol(format!("health reply missing health: {body}")))?;
        HealthReport::decode(report)
    }

    /// `submit`: run a typed request asynchronously; returns the job id.
    pub fn submit(
        &mut self,
        job: &api::Request,
        placement: api::Placement,
    ) -> Result<String, ClientError> {
        self.submit_raw(job.encode(), placement)
    }

    /// [`Client::submit`] for an already-encoded job object (the CLI's
    /// pass-through path).
    pub fn submit_raw(
        &mut self,
        job: Json,
        placement: api::Placement,
    ) -> Result<String, ClientError> {
        let req = api::Request::Submit(api::SubmitRequest { job, placement });
        // Not idempotent: an I/O failure after the send leaves the job's
        // fate unknown, so only `busy` (never-enqueued) is retried.
        let body = self.call_retrying(&req, false)?;
        body.get("job_id")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ClientError::Protocol(format!("submit reply missing job_id: {body}")))
    }

    /// [`Client::submit`] with bounded retries on `busy`, sleeping the
    /// server's `retry_after_ms` hint (capped at 2s per attempt) between
    /// attempts.  Returns the final `busy` error once retries run out.
    pub fn submit_with_retry(
        &mut self,
        job: &api::Request,
        placement: api::Placement,
        max_retries: usize,
    ) -> Result<String, ClientError> {
        let encoded = job.encode();
        let mut attempt = 0;
        loop {
            match self.submit_raw(encoded.clone(), placement) {
                Err(ClientError::Busy(busy)) if attempt < max_retries => {
                    attempt += 1;
                    self.retry_stats.retries += 1;
                    let ms = busy.retry_after_ms.unwrap_or(50).clamp(1, 2_000);
                    std::thread::sleep(Duration::from_millis(ms));
                }
                other => return other,
            }
        }
    }

    /// `status`: one job's state/progress/partials.  `partials_from` is
    /// the previous reply's `partials_next` streaming cursor.
    pub fn status(
        &mut self,
        job_id: &str,
        partials_from: Option<u64>,
    ) -> Result<JobStatus, ClientError> {
        let req = api::Request::Status(api::StatusRequest {
            job_id: job_id.to_string(),
            partials_from,
        });
        let body = self.call_retrying(&req, true)?;
        let job = body
            .get("job")
            .ok_or_else(|| ClientError::Protocol(format!("status reply missing job: {body}")))?;
        JobStatus::decode(job)
    }

    /// `jobs`: every job with state + progress.
    pub fn jobs(&mut self) -> Result<Vec<JobStatus>, ClientError> {
        let body = self.call_retrying(&api::Request::Jobs, true)?;
        body.get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| ClientError::Protocol(format!("jobs reply missing jobs: {body}")))?
            .iter()
            .map(JobStatus::decode)
            .collect()
    }

    /// `cancel`: fire a job's cancel token; `true` when the job existed
    /// and had not already finished.
    pub fn cancel(&mut self, job_id: &str) -> Result<bool, ClientError> {
        let req = api::Request::Cancel(api::CancelRequest { job_id: job_id.to_string() });
        let body = self.call_retrying(&req, true)?;
        body.get("cancelled")
            .and_then(Json::as_bool)
            .ok_or_else(|| ClientError::Protocol(format!("cancel reply malformed: {body}")))
    }

    /// Poll `status` until the job reaches a terminal state (or
    /// `timeout` expires — then the last observed status is returned).
    pub fn wait_job(
        &mut self,
        job_id: &str,
        poll: Duration,
        timeout: Duration,
    ) -> Result<JobStatus, ClientError> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let status = self.status(job_id, None)?;
            if status.is_terminal() || std::time::Instant::now() >= deadline {
                return Ok(status);
            }
            std::thread::sleep(poll);
        }
    }

    /// `shutdown`: stop the coordinator.
    pub fn shutdown(&mut self) -> Result<(), ClientError> {
        self.call(&api::Request::Shutdown).map(|_| ())
    }
}

/// Decode a `[{"name":…,"description":…},…]` listing field.
fn decode_named_list(body: &Json, key: &str) -> Result<Vec<(String, String)>, String> {
    body.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("reply missing \"{key}\": {body}"))?
        .iter()
        .map(|row| {
            let get = |k: &str| {
                row.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| format!("listing row missing \"{k}\": {row}"))
            };
            Ok((get("name")?, get("description")?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_status_decodes_progress_and_partials() {
        let j = Json::parse(
            r#"{"id":"j-1","op":"campaign","state":"running",
                "progress":{"done":3,"total":8},
                "partial_results":[{"wall_clock":1.0}],"partials_next":3}"#,
        )
        .unwrap();
        let s = JobStatus::decode(&j).unwrap();
        assert_eq!(s.id, "j-1");
        assert_eq!(s.progress, Some((3, 8)));
        assert_eq!(s.partial_results.len(), 1);
        assert_eq!(s.partials_next, Some(3));
        assert!(!s.is_terminal());
        let done = Json::parse(r#"{"id":"j-2","op":"plan","state":"done","result":{"ok":true}}"#)
            .unwrap();
        let s = JobStatus::decode(&done).unwrap();
        assert!(s.is_terminal());
        assert!(s.result.is_some());
        assert!(JobStatus::decode(&Json::parse(r#"{"id":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn busy_error_displays_the_retry_hint() {
        let e = ClientError::Busy(BusyInfo { shard: 2, backlog: 256, retry_after_ms: Some(40) });
        let s = e.to_string();
        assert!(s.contains("shard 2") && s.contains("40ms"), "{s}");
    }

    #[test]
    fn backoff_grows_caps_and_respects_hints() {
        let p = RetryPolicy { jitter: 0.0, ..RetryPolicy::standard() };
        assert_eq!(backoff_ms(&p, 1, None, 0.0), 50);
        assert_eq!(backoff_ms(&p, 2, None, 0.0), 100);
        assert_eq!(backoff_ms(&p, 3, None, 0.0), 200);
        assert_eq!(backoff_ms(&p, 12, None, 0.0), 2_000, "capped at max_delay");
        assert_eq!(backoff_ms(&p, 1, Some(700), 0.0), 700, "server hint wins");
        assert_eq!(backoff_ms(&p, 1, Some(60_000), 0.0), 2_000, "hints are capped too");
        let jittered = RetryPolicy { jitter: 0.5, ..p };
        assert_eq!(backoff_ms(&jittered, 1, None, 1.0), 25, "full jitter sheds half");
        assert_eq!(backoff_ms(&jittered, 1, None, 0.0), 50);
        assert_eq!(backoff_ms(&RetryPolicy::none(), 1, Some(0), 0.0), 1, "1ms floor");
    }

    #[test]
    fn default_retry_policy_is_fail_fast() {
        let p = RetryPolicy::default();
        assert_eq!(p.max_attempts, 1, "defaults must preserve pre-retry behaviour");
        let s = RetryPolicy::standard();
        assert!(s.max_attempts > 1 && s.jitter > 0.0 && s.max_elapsed.is_some());
    }

    #[test]
    fn health_report_decodes_both_shapes() {
        let degraded = Json::parse(
            r#"{"cache":{"enabled":true},
                "engine":{"queued":0,"shards":4,"watchdog_respawns":0},
                "journal":{"attached":false,"enabled":true,"write_errors":2},
                "status":"degraded","uptime_ms":1234}"#,
        )
        .unwrap();
        let h = HealthReport::decode(&degraded).unwrap();
        assert_eq!(h.status, "degraded");
        assert!(!h.is_ok());
        assert_eq!(h.uptime_ms, 1234);
        assert_eq!(h.journal_attached, Some(false));
        let no_journal =
            Json::parse(r#"{"journal":{"enabled":false},"status":"ok","uptime_ms":5}"#).unwrap();
        let h = HealthReport::decode(&no_journal).unwrap();
        assert!(h.is_ok());
        assert_eq!(h.journal_attached, None, "journal-less servers report no attachment");
        assert!(HealthReport::decode(&Json::parse(r#"{"uptime_ms":5}"#).unwrap()).is_err());
    }
}
