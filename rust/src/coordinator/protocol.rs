//! Request handling for the coordinator's line-delimited JSON protocol.
//!
//! Pure functions from a parsed request to a response object — the TCP
//! server is a thin transport around [`handle`], and the protocol tests
//! drive it without sockets.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::analysis::report::{run_policy_sweep_ctl, CORE_POLICIES};
use crate::cloudsim::{
    run_campaign_ctl, run_campaign_replications_ctl, sample_runs, summarise_replications,
    CampaignOutcome, CampaignSpec, SimConfig, SimOutcome, Simulator,
};
use crate::config;
use crate::eval::PlanEvaluator;
use crate::model::System;
use crate::scheduler::{PolicyRegistry, SolveOutcome};
use crate::util::{CancelToken, Json};

use super::engine::{JobCtl, JobEngine, JobError};
use super::state::JobRegistry;
use super::Metrics;

/// Shared coordinator state handed to every request.
pub struct Context {
    pub evaluator: Arc<dyn PlanEvaluator>,
    pub metrics: Arc<Metrics>,
    /// The sharded worker pool every job (async submit or synchronous
    /// heavy op) executes on.
    pub engine: Arc<JobEngine>,
    /// Name → policy resolution for `plan` / `simulate` / `campaign`.
    pub registry: Arc<PolicyRegistry>,
    /// Set when this request is already running *inside* the engine (as
    /// a job): heavy ops then execute inline with this handle's cancel
    /// token and progress sink instead of re-submitting to the pool.
    pub job: Option<JobCtl>,
}

impl Context {
    /// A context with its own auto-sized engine (tests, embedding).
    pub fn new(evaluator: Arc<dyn PlanEvaluator>, metrics: Arc<Metrics>) -> Self {
        let engine = Arc::new(JobEngine::new(0, Arc::clone(&metrics)));
        Self::with_engine(evaluator, metrics, engine)
    }

    /// A context sharing an existing engine (one per server; job ids are
    /// visible across every connection).
    pub fn with_engine(
        evaluator: Arc<dyn PlanEvaluator>,
        metrics: Arc<Metrics>,
        engine: Arc<JobEngine>,
    ) -> Self {
        Self {
            evaluator,
            metrics,
            engine,
            registry: Arc::new(PolicyRegistry::builtin()),
            job: None,
        }
    }

    /// The job registry backing `status` / `jobs` / `cancel`.
    pub fn jobs(&self) -> &Arc<JobRegistry> {
        self.engine.registry()
    }

    fn clone_shared(&self) -> Self {
        Self {
            evaluator: Arc::clone(&self.evaluator),
            metrics: Arc::clone(&self.metrics),
            engine: Arc::clone(&self.engine),
            registry: Arc::clone(&self.registry),
            job: None,
        }
    }

    /// The cancel token governing this request (the job's token inside
    /// the engine; an inert default token otherwise).
    fn cancel_token(&self) -> CancelToken {
        self.job.as_ref().map(JobCtl::cancel_token).unwrap_or_default()
    }
}

/// Outcome of one request: the response plus whether the server should
/// shut down afterwards.
pub struct Reply {
    pub body: Json,
    pub shutdown: bool,
}

fn ok(mut fields: Vec<(&str, Json)>) -> Reply {
    fields.insert(0, ("ok", Json::Bool(true)));
    Reply { body: Json::obj(fields), shutdown: false }
}

/// The structured admission-control rejection: the target shard's queue
/// is at its backlog bound.  Built directly (not through the anyhow
/// error path) so the shape is exactly
/// `{"ok":false,"error":"busy","shard":…,"backlog":…}` — clients key on
/// `error == "busy"` to back off or shed load.
fn busy_reply(shard: usize, backlog: usize) -> Reply {
    Reply {
        body: Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str("busy")),
            ("shard", Json::num(shard as f64)),
            ("backlog", Json::num(backlog as f64)),
        ]),
        shutdown: false,
    }
}

/// Handle one request line.  Errors are mapped to `{"ok":false,...}` by
/// the caller so the connection survives malformed input; every error is
/// prefixed with the offending request's `op` (and `policy`, when one was
/// given) so wire clients can diagnose bad requests.
pub fn handle(ctx: &Context, line: &str) -> Result<Reply> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing \"op\""))?;
    dispatch(ctx, op, &req).map_err(|e| match policy_name(&req) {
        Some(p) => anyhow!("op {op:?} (policy {p:?}): {e:#}"),
        None => anyhow!("op {op:?}: {e:#}"),
    })
}

fn dispatch(ctx: &Context, op: &str, req: &Json) -> Result<Reply> {
    match op {
        "ping" => Ok(ok(vec![("pong", Json::Bool(true))])),
        "stats" => {
            let shard_stats = ctx.engine.shard_stats();
            Ok(ok(vec![
                ("stats", ctx.metrics.snapshot()),
                (
                    "engine",
                    Json::obj(vec![
                        ("shards", Json::num(ctx.engine.n_shards() as f64)),
                        (
                            "queued",
                            Json::num(shard_stats.iter().map(|s| s.depth).sum::<usize>() as f64),
                        ),
                        ("max_backlog", Json::num(ctx.engine.max_backlog() as f64)),
                        (
                            "shard_stats",
                            Json::arr(shard_stats.iter().enumerate().map(|(i, s)| {
                                Json::obj(vec![
                                    ("shard", Json::num(i as f64)),
                                    ("depth", Json::num(s.depth as f64)),
                                    ("high_water", Json::num(s.high_water as f64)),
                                    ("rejected", Json::num(s.rejected as f64)),
                                ])
                            })),
                        ),
                    ]),
                ),
            ]))
        }
        "shutdown" => Ok(Reply {
            body: Json::obj(vec![("ok", Json::Bool(true)), ("bye", Json::Bool(true))]),
            shutdown: true,
        }),
        "list_policies" => Ok(ok(vec![(
            "policies",
            Json::arr(ctx.registry.iter().map(|p| {
                Json::obj(vec![
                    ("name", Json::str(p.name())),
                    ("description", Json::str(p.description())),
                ])
            })),
        )])),
        "plan" => op_plan(ctx, req),
        "sweep" => op_sweep(ctx, req),
        "simulate" => op_simulate(ctx, req),
        "campaign" => op_campaign(ctx, req),
        "estimate_perf" => op_estimate_perf(req),
        "submit" => op_submit(ctx, req),
        "status" => op_status(ctx, req),
        "jobs" => Ok(ok(vec![("jobs", ctx.jobs().list())])),
        "cancel" => op_cancel(ctx, req),
        _ => Err(anyhow!("no such op (try list_policies, plan, sweep, simulate, campaign, estimate_perf, submit, status, jobs, cancel, stats, ping, shutdown)")),
    }
}

/// The request's policy name: `"policy"`, or the legacy `"approach"`.
fn policy_name(req: &Json) -> Option<&str> {
    req.get("policy")
        .or_else(|| req.get("approach"))
        .and_then(Json::as_str)
}

/// `submit`: run any other request asynchronously on the sharded
/// engine; poll with `status`, stop with `cancel`.  No thread is
/// spawned here — the job queues onto its shard (in `priority` /
/// `deadline_ms` / FIFO order; both fields ride on the *outer* submit
/// object) and runs when a pool worker frees up.  A shard at its
/// backlog bound rejects the submit with the structured `busy` reply
/// instead of queueing.
fn op_submit(ctx: &Context, req: &Json) -> Result<Reply> {
    let inner = req
        .get("job")
        .ok_or_else(|| anyhow!("submit: missing \"job\" object"))?
        .clone();
    let inner_op = inner
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("submit: job missing \"op\""))?;
    if matches!(inner_op, "submit" | "shutdown" | "status" | "jobs" | "cancel") {
        return Err(anyhow!("submit: op {inner_op:?} cannot run as a job"));
    }
    let prio = config::job_priority_from_json(req)?;
    let worker_ctx = ctx.clone_shared();
    let line = inner.to_string();
    let submitted = ctx.engine.try_submit(
        inner_op,
        prio,
        Box::new(move |ctl| {
            let mut job_ctx = worker_ctx;
            job_ctx.job = Some(ctl.clone());
            match handle(&job_ctx, &line) {
                Ok(reply) => Ok(reply.body),
                Err(e) => Err(format!("{e:#}")),
            }
        }),
    );
    match submitted {
        Ok(job_id) => Ok(ok(vec![("job_id", Json::str(job_id))])),
        Err(busy) => Ok(busy_reply(busy.shard, busy.backlog)),
    }
}

/// `status`: current state, progress and streaming partial results.
/// Pass `"partials_from"` (the previous reply's `partials_next`) to
/// receive only new partial rows instead of the whole backlog.
fn op_status(ctx: &Context, req: &Json) -> Result<Reply> {
    let id = req
        .get("job_id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("status: missing \"job_id\""))?;
    let from = u64_field(req, "partials_from")?.unwrap_or(0);
    let status = ctx
        .jobs()
        .status_from(id, from)
        .ok_or_else(|| anyhow!("unknown job {id:?}"))?;
    Ok(ok(vec![("job", status)]))
}

/// `cancel`: fires the job's cancel token; queued jobs never start and
/// running jobs stop at their next cooperative checkpoint (replication
/// boundary, sweep cell, FIND iteration).
fn op_cancel(ctx: &Context, req: &Json) -> Result<Reply> {
    let id = req
        .get("job_id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("cancel: missing \"job_id\""))?;
    Ok(ok(vec![("cancelled", Json::Bool(ctx.jobs().cancel(id)))]))
}

fn parse_system(req: &Json) -> Result<System> {
    match req.get("system") {
        None => Ok(crate::workload::paper::table1_system(
            req.get("overhead").and_then(Json::as_f64).unwrap_or(0.0),
        )),
        Some(Json::Str(s)) => config::load_system(s),
        Some(obj) => config::system_from_json(obj),
    }
}

fn budget_of(req: &Json) -> Result<f64> {
    req.get("budget")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing \"budget\""))
}

/// Resolve the request's policy and solve it through the shared
/// evaluator.  All planning ops (`plan`, `simulate`) funnel through here.
fn solve_with(ctx: &Context, sys: &System, req: &Json) -> Result<SolveOutcome> {
    let name = match policy_name(req) {
        Some(n) => n,
        // Deadline + remaining with no policy is ambiguous (the deadline
        // search ignores residual sets, dynamic ignores deadlines) —
        // refuse rather than guess and then blame the guess.
        None if req.get("deadline").is_some() && req.get("remaining").is_some() => {
            return Err(anyhow!(
                "both \"deadline\" and \"remaining\" given without a \"policy\" — \
                 name the policy explicitly"
            ));
        }
        // A deadline with no explicit policy selects the deadline search
        // (mirrors the CLI) — the budget heuristic would silently ignore it.
        None if req.get("deadline").is_some() => "deadline",
        // A residual task set with no explicit policy selects dynamic
        // re-planning for the same reason.
        None if req.get("remaining").is_some() => "dynamic",
        None => "budget-heuristic",
    };
    // Resolve first so a typoed policy name reports as unknown-policy,
    // not as a misleading knob error.
    let policy = ctx.registry.resolve(name).map_err(anyhow::Error::new)?;
    let sreq = config::solve_request_from_json(req)?
        .with_evaluator(ctx.evaluator.as_ref())
        .with_cancel(ctx.cancel_token());
    if let Some(remaining) = &sreq.remaining {
        // `remaining` drives dynamic re-planning; every other policy
        // would silently plan the full workload, so reject it rather
        // than mislead the client.
        if policy.name() != "dynamic" {
            return Err(anyhow!(
                "\"remaining\" is only honoured by the \"dynamic\" policy (got {name:?})"
            ));
        }
        let n = sys.tasks().len();
        let mut seen = vec![false; n];
        for t in remaining {
            let i = t.index();
            if i >= n {
                return Err(anyhow!("\"remaining\" names unknown task {i} (system has {n})"));
            }
            if seen[i] {
                return Err(anyhow!("\"remaining\" lists task {i} twice"));
            }
            seen[i] = true;
        }
    }
    Ok(policy.solve(sys, &sreq))
}

fn plan_json(sys: &System, plan: &crate::model::Plan) -> Json {
    Json::arr(plan.vms.iter().map(|vm| {
        Json::obj(vec![
            ("instance_type", Json::str(&sys.instance_type(vm.it).name)),
            ("tasks", Json::num(vm.len() as f64)),
            ("exec", Json::num(vm.exec(sys))),
            ("cost", Json::num(vm.cost(sys))),
        ])
    }))
}

fn op_plan(ctx: &Context, req: &Json) -> Result<Reply> {
    let sys = parse_system(req)?;
    let budget = budget_of(req)?;
    let outcome = solve_with(ctx, &sys, req)?;
    ctx.metrics.record_plan();
    let mut fields = vec![
        ("policy", Json::str(outcome.policy)),
        // Legacy field name and spelling, kept for wire compatibility.
        ("approach", Json::str(crate::scheduler::legacy_name(outcome.policy))),
        ("budget", Json::num(budget)),
        ("effective_budget", Json::num(outcome.effective_budget)),
        ("makespan", Json::num(outcome.score.makespan)),
        ("cost", Json::num(outcome.score.cost)),
        ("feasible", Json::Bool(outcome.feasible)),
        ("iterations", Json::num(outcome.iterations as f64)),
        ("probes", Json::num(outcome.probes as f64)),
        ("n_vms", Json::num(outcome.plan.n_vms() as f64)),
        ("vms", plan_json(&sys, &outcome.plan)),
    ];
    // Full task-level assignment on request (importable via
    // config::plan_from_json for external execution engines).
    if req.get("detail").and_then(Json::as_bool).unwrap_or(false) {
        fields.push(("plan", config::plan_to_json(&sys, &outcome.plan)));
    }
    Ok(ok(fields))
}

/// A fully validated sweep, ready to execute on a pool worker.
struct SweepJob {
    sys: System,
    budgets: Vec<f64>,
    threads: usize,
    evaluator: Arc<dyn PlanEvaluator>,
    registry: Arc<PolicyRegistry>,
}

/// Run a validated sweep, publishing per-cell progress and streaming
/// each finished cell as a partial result.
fn exec_sweep(job: &SweepJob, ctl: &JobCtl) -> Reply {
    let total = (job.budgets.len() * CORE_POLICIES.len()) as u64;
    ctl.progress(0, total);
    let done = AtomicU64::new(0);
    let report = run_policy_sweep_ctl(
        &job.sys,
        &job.budgets,
        CORE_POLICIES,
        &job.registry,
        job.evaluator.as_ref(),
        job.threads,
        &ctl.cancel_token(),
        &|_idx, row| {
            ctl.progress(done.fetch_add(1, Ordering::Relaxed) + 1, total);
            ctl.partial(row.to_json());
        },
    )
    .expect("core policies are builtin");
    // Final authoritative count (observers race under parallelism;
    // set_progress is max-monotonic).
    ctl.progress(report.rows.len() as u64, total);
    ok(vec![("sweep", report.to_json())])
}

fn op_sweep(ctx: &Context, req: &Json) -> Result<Reply> {
    let sys = parse_system(req)?;
    let budgets: Vec<f64> = match req.get("budgets").and_then(Json::as_arr) {
        Some(arr) => arr.iter().filter_map(Json::as_f64).collect(),
        None => crate::workload::paper::BUDGETS.to_vec(),
    };
    if budgets.is_empty() {
        return Err(anyhow!("empty budgets"));
    }
    let threads = bounded_threads(u64_field(req, "threads")?.unwrap_or(1))?;
    let job = SweepJob {
        sys,
        budgets,
        threads,
        evaluator: Arc::clone(&ctx.evaluator),
        registry: Arc::clone(&ctx.registry),
    };
    ctx.metrics.record_plan();
    match &ctx.job {
        // Already on a pool worker (async submit): run inline.
        Some(ctl) => Ok(exec_sweep(&job, ctl)),
        // Synchronous call: the same execution, behind the same bounded
        // pool — the caller's thread just waits for its own job, and a
        // shard at its backlog bound rejects with `busy` like a submit.
        None => {
            let prio = config::job_priority_from_json(req)?;
            match ctx
                .engine
                .run_sync_with("sweep", prio, Box::new(move |ctl| Ok(exec_sweep(&job, ctl).body)))
            {
                Ok(body) => Ok(Reply { body, shutdown: false }),
                Err(JobError::Busy { shard, backlog }) => Ok(busy_reply(shard, backlog)),
                Err(JobError::Failed(e)) => Err(anyhow!("{e}")),
            }
        }
    }
}

/// Bound a wire-controlled worker-thread count (0 = auto is allowed;
/// `parallel_map` caps auto at the machine's core count).
fn bounded_threads(threads: u64) -> Result<usize> {
    const MAX_THREADS: u64 = 256;
    if threads > MAX_THREADS {
        return Err(anyhow!("threads {threads} exceeds the limit of {MAX_THREADS}"));
    }
    Ok(threads as usize)
}

/// A strictly-typed optional u64 field: present-but-mistyped is an
/// error, never a silent default.
fn u64_field(req: &Json, key: &str) -> Result<Option<u64>> {
    req.get(key)
        .map(|v| {
            v.as_u64()
                .ok_or_else(|| anyhow!("\"{key}\" must be a non-negative integer, got {v}"))
        })
        .transpose()
}

fn op_simulate(ctx: &Context, req: &Json) -> Result<Reply> {
    let sys = parse_system(req)?;
    let outcome = solve_with(ctx, &sys, req)?;
    ctx.metrics.record_plan();
    let noise = req.get("noise").map(config::noise_from_json).unwrap_or_else(
        crate::cloudsim::NoiseModel::none,
    );
    let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(0);
    let sim = Simulator::run_plan(&sys, &outcome.plan, &SimConfig { noise, seed });
    Ok(ok(vec![
        ("policy", Json::str(outcome.policy)),
        ("planned_feasible", Json::Bool(outcome.feasible)),
        ("makespan", Json::num(sim.makespan)),
        ("cost", Json::num(sim.cost)),
        ("completed", Json::num(sim.completed.len() as f64)),
        ("stranded", Json::num(sim.stranded.len() as f64)),
        ("failures", Json::num(sim.failures as f64)),
    ]))
}

/// A fully validated campaign, ready to execute on a pool worker.
struct CampaignJob {
    sys: System,
    spec: CampaignSpec,
    replications: usize,
    threads: usize,
}

/// One finished replication as a partial/summary row.
fn replication_row(out: &CampaignOutcome) -> Json {
    Json::obj(vec![
        ("wall_clock", Json::num(out.wall_clock)),
        ("spent", Json::num(out.spent)),
        ("complete", Json::Bool(out.complete)),
        ("within_budget", Json::Bool(out.within_budget)),
        ("rounds", Json::num(out.rounds.len() as f64)),
    ])
}

/// One finished campaign round as a partial row.
fn round_row(round: usize, sim: &SimOutcome) -> Json {
    Json::obj(vec![
        ("round", Json::num(round as f64)),
        ("completed", Json::num(sim.completed.len() as f64)),
        ("stranded", Json::num(sim.stranded.len() as f64)),
        ("failures", Json::num(sim.failures as f64)),
        ("cost", Json::num(sim.cost)),
        ("makespan", Json::num(sim.makespan)),
    ])
}

/// Run a validated campaign, publishing progress (replications done, or
/// rounds done for a single run) and streaming partial rows.  A cancel
/// stops the fan-out at the next replication/round boundary; the reply
/// then covers only the work that ran (`cancelled: true`).
fn exec_campaign(job: &CampaignJob, ctl: &JobCtl) -> Reply {
    let cancel = ctl.cancel_token();
    if job.replications > 1 {
        // Monte-Carlo mode: fan the replications out and report the
        // aggregate (plus per-replication rows for downstream tooling).
        let total = job.replications as u64;
        ctl.progress(0, total);
        let done = AtomicU64::new(0);
        let outs = run_campaign_replications_ctl(
            &job.sys,
            &job.spec,
            job.replications,
            job.threads,
            &cancel,
            &|_r, out| {
                ctl.progress(done.fetch_add(1, Ordering::Relaxed) + 1, total);
                ctl.partial(replication_row(out));
            },
        );
        let outs: Vec<CampaignOutcome> = outs.into_iter().flatten().collect();
        // Final authoritative count: racing observers may have published
        // out of order (set_progress is max-monotonic, never regressing).
        ctl.progress(outs.len() as u64, total);
        let mut fields = vec![
            ("policy", Json::str(job.spec.policy.name())),
            ("replications", Json::num(outs.len() as f64)),
        ];
        if cancel.is_cancelled() {
            fields.push(("cancelled", Json::Bool(true)));
        }
        if outs.is_empty() {
            // Cancelled before any replication completed: nothing to
            // aggregate (only reachable through a cancelled job, whose
            // result is discarded anyway).
            return ok(fields);
        }
        let s = summarise_replications(&outs);
        let n = s.replications as f64;
        fields.extend([
            ("complete_frac", Json::num(s.complete as f64 / n)),
            ("within_budget_frac", Json::num(s.within_budget as f64 / n)),
            ("mean_wall_clock", Json::num(s.mean_wall_clock)),
            ("mean_spent", Json::num(s.mean_spent)),
            ("runs", Json::arr(outs.iter().map(replication_row))),
        ]);
        return ok(fields);
    }
    // Single campaign: progress over re-planning rounds.
    let total = job.spec.max_rounds as u64;
    ctl.progress(0, total);
    let out = run_campaign_ctl(&job.sys, &job.spec, &cancel, &mut |round, sim| {
        ctl.progress(round as u64 + 1, total);
        ctl.partial(round_row(round, sim));
    });
    let mut fields = vec![
        ("policy", Json::str(job.spec.policy.name())),
        ("wall_clock", Json::num(out.wall_clock)),
        ("spent", Json::num(out.spent)),
        ("complete", Json::Bool(out.complete)),
        ("within_budget", Json::Bool(out.within_budget)),
        ("rounds", Json::num(out.rounds.len() as f64)),
        ("planned_makespan", Json::num(out.planned.makespan)),
    ];
    if cancel.is_cancelled() {
        fields.push(("cancelled", Json::Bool(true)));
    }
    ok(fields)
}

/// Validate a campaign request into a [`CampaignJob`] (every error
/// surfaces here, synchronously, before anything queues).
fn parse_campaign(ctx: &Context, req: &Json) -> Result<CampaignJob> {
    let sys = parse_system(req)?;
    let budget = budget_of(req)?;
    let mut spec = CampaignSpec::new(budget);
    match policy_name(req) {
        Some(name) => {
            spec.policy = ctx.registry.resolve_arc(name).map_err(anyhow::Error::new)?;
        }
        // Same rule as plan/simulate: an orphan deadline selects the
        // deadline policy rather than being silently ignored.
        None if req.get("deadline").is_some() => {
            spec.policy = ctx.registry.get_arc("deadline").expect("builtin");
        }
        None => {}
    }
    // Policy knobs (deadline, n_starts, sample_frac, planner, ...) ride
    // on the per-round request template; budget and seed are overridden
    // by the campaign loop itself.
    spec.base_request = config::solve_request_from_json(req)?;
    if spec.base_request.remaining.is_some() {
        return Err(anyhow!(
            "\"remaining\" is not accepted on campaigns (each round re-plans its own residual)"
        ));
    }
    spec.evaluator = Some(Arc::clone(&ctx.evaluator));
    if let Some(n) = req.get("noise") {
        spec.sim.noise = config::noise_from_json(n);
    }
    spec.sim.seed = req.get("seed").and_then(Json::as_u64).unwrap_or(0);
    if let Some(r) = req.get("max_rounds").and_then(Json::as_u64) {
        spec.max_rounds = r as usize;
    }
    // A campaign is expensive; bound the wire-driven fan-out so a tiny
    // request cannot trigger unbounded work or thread allocation.
    const MAX_REPLICATIONS: u64 = 4096;
    let replications = u64_field(req, "replications")?.unwrap_or(1).max(1);
    if replications > MAX_REPLICATIONS {
        return Err(anyhow!(
            "replications {replications} exceeds the limit of {MAX_REPLICATIONS}"
        ));
    }
    let threads = bounded_threads(u64_field(req, "threads")?.unwrap_or(1))?;
    if replications > 1 {
        // The outer fan-out owns the parallelism — the single "threads"
        // field must not also multiply into every round's inner solver.
        spec.base_request.threads = 1;
    }
    Ok(CampaignJob { sys, spec, replications: replications as usize, threads })
}

fn op_campaign(ctx: &Context, req: &Json) -> Result<Reply> {
    let job = parse_campaign(ctx, req)?;
    match &ctx.job {
        // Already on a pool worker (async submit): run inline.
        Some(ctl) => Ok(exec_campaign(&job, ctl)),
        // Synchronous call: identical execution behind the same bounded
        // pool; the caller's thread waits for its own job, and a shard
        // at its backlog bound rejects with `busy` like a submit.
        None => {
            let prio = config::job_priority_from_json(req)?;
            match ctx.engine.run_sync_with(
                "campaign",
                prio,
                Box::new(move |ctl| Ok(exec_campaign(&job, ctl).body)),
            ) {
                Ok(body) => Ok(Reply { body, shutdown: false }),
                Err(JobError::Busy { shard, backlog }) => Ok(busy_reply(shard, backlog)),
                Err(JobError::Failed(e)) => Err(anyhow!("{e}")),
            }
        }
    }
}

fn op_estimate_perf(req: &Json) -> Result<Reply> {
    let sys = parse_system(req)?;
    let per_cell = req.get("per_cell").and_then(Json::as_u64).unwrap_or(10) as usize;
    let noise = req.get("noise").map(config::noise_from_json).unwrap_or_else(
        crate::cloudsim::NoiseModel::none,
    );
    let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(0);
    let obs = sample_runs(&sys, per_cell, &noise, seed);
    let cells = sys.n_types() * sys.n_apps();
    let prior = vec![0.0; cells];
    // Prefer the XLA artifact; fall back to the native mirror.
    let est = match crate::runtime::XlaPerfEstimator::load() {
        Ok(e) => e.estimate(&sys, &obs, &prior, 1e-9).unwrap_or_else(|_| {
            crate::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-9)
        }),
        Err(_) => crate::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-9),
    };
    // Report estimated vs true matrix error.
    let mut max_rel = 0.0f64;
    for it in &sys.instance_types {
        for app in &sys.apps {
            let truth = sys.perf.get(it.id, app.id);
            let got = est[it.id.index() * sys.n_apps() + app.id.index()];
            max_rel = max_rel.max((got - truth).abs() / truth);
        }
    }
    Ok(ok(vec![
        ("samples", Json::num(obs.len() as f64)),
        ("estimate", Json::arr(est.iter().map(|p| Json::num(*p)))),
        ("max_rel_error", Json::num(max_rel)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;

    fn ctx() -> Context {
        Context::new(Arc::new(NativeEvaluator), Arc::new(Metrics::new()))
    }

    #[test]
    fn ping_and_stats() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"ping"}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert!(!r.shutdown);
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        assert!(r.body.get("stats").is_some());
    }

    #[test]
    fn shutdown_flag() {
        let r = handle(&ctx(), r#"{"op":"shutdown"}"#).unwrap();
        assert!(r.shutdown);
    }

    #[test]
    fn plan_over_paper_system() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert!(r.body.get("makespan").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.body.get("feasible"), Some(&Json::Bool(true)));
        let n_vms = r.body.get("n_vms").unwrap().as_f64().unwrap();
        assert!(n_vms >= 1.0);
        assert_eq!(
            r.body.get("vms").unwrap().as_arr().unwrap().len(),
            n_vms as usize
        );
    }

    #[test]
    fn plan_with_inline_system_and_baselines() {
        let c = ctx();
        let line = r#"{"op":"plan","budget":20,"approach":"mp","system":{
            "apps":[{"task_sizes":[1,2,3,4]}],
            "instance_types":[{"cost_per_hour":5,"perf":[10]},
                               {"cost_per_hour":9,"perf":[5]}]}}"#;
        let r = handle(&c, line).unwrap();
        assert_eq!(r.body.get("approach").unwrap().as_str(), Some("mp"));
    }

    #[test]
    fn simulate_and_campaign() {
        let c = ctx();
        let r = handle(
            &c,
            r#"{"op":"simulate","budget":80,"noise":{"task_sigma":0.05},"seed":3}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("completed").unwrap().as_f64(), Some(750.0));
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":150,"noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
        )
        .unwrap();
        assert!(r.body.get("rounds").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn estimate_perf_roundtrip() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"estimate_perf","per_cell":8}"#).unwrap();
        // Noiseless sampling recovers Table I exactly.
        assert!(r.body.get("max_rel_error").unwrap().as_f64().unwrap() < 1e-6);
        assert_eq!(r.body.get("estimate").unwrap().as_arr().unwrap().len(), 12);
    }

    #[test]
    fn errors_are_reported() {
        let c = ctx();
        assert!(handle(&c, "not json").is_err());
        assert!(handle(&c, r#"{"op":"nope"}"#).is_err());
        assert!(handle(&c, r#"{"op":"plan"}"#).is_err()); // no budget
        assert!(handle(&c, r#"{"op":"plan","budget":10,"approach":"x"}"#).is_err());
    }

    #[test]
    fn errors_name_the_offending_op_and_policy() {
        let c = ctx();
        // Unknown policy: the error names the op, the policy and the
        // known alternatives.
        let e = handle(&c, r#"{"op":"plan","budget":10,"policy":"warp"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"plan\""), "{msg}");
        assert!(msg.contains("\"warp\""), "{msg}");
        assert!(msg.contains("budget-heuristic"), "{msg}");
        // Missing budget: the error still names the op.
        let e = handle(&c, r#"{"op":"simulate"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"simulate\""), "{msg}");
        assert!(msg.contains("budget"), "{msg}");
        // Unknown op: the error names it and lists the known ops.
        let e = handle(&c, r#"{"op":"nope"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"nope\""), "{msg}");
        assert!(msg.contains("list_policies"), "{msg}");
    }

    #[test]
    fn list_policies_covers_the_registry() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"list_policies"}"#).unwrap();
        let policies = r.body.get("policies").unwrap().as_arr().unwrap();
        let names: Vec<&str> = policies
            .iter()
            .map(|p| p.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, crate::scheduler::BUILTIN_POLICIES);
        for p in policies {
            assert!(!p.get("description").unwrap().as_str().unwrap().is_empty());
        }
    }

    #[test]
    fn plan_accepts_policy_field_for_every_builtin() {
        let c = ctx();
        for name in crate::scheduler::BUILTIN_POLICIES {
            let line = format!(
                r#"{{"op":"plan","budget":80,"deadline":7200,"policy":"{name}"}}"#
            );
            let r = handle(&c, &line).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)), "{name}");
            assert_eq!(r.body.get("policy").unwrap().as_str(), Some(*name));
            assert!(r.body.get("makespan").unwrap().as_f64().unwrap() > 0.0, "{name}");
        }
    }

    #[test]
    fn campaign_accepts_policy_field() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"campaign","budget":120,"policy":"mp"}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("mp"));
        assert_eq!(r.body.get("complete"), Some(&Json::Bool(true)));
        assert!(handle(&c, r#"{"op":"campaign","budget":120,"policy":"zz"}"#).is_err());
        // Policy knobs reach the per-round solver: a deadline campaign
        // must plan within the deadline, not just within the budget.
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":200,"policy":"deadline","deadline":3600}"#,
        )
        .unwrap();
        let planned = r.body.get("planned_makespan").unwrap().as_f64().unwrap();
        assert!(planned <= 3600.0 + 1e-6, "deadline ignored: {planned}");
    }

    #[test]
    fn plan_accepts_remaining_for_dynamic_re_planning() {
        let c = ctx();
        // Explicit residual set + dynamic policy: the plan covers
        // exactly those tasks.
        let r = handle(
            &c,
            r#"{"op":"plan","budget":40,"policy":"dynamic","remaining":[0,1,2,3,4,5,6,7,8,9]}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("dynamic"));
        let vms = r.body.get("vms").unwrap().as_arr().unwrap();
        let tasks: f64 = vms
            .iter()
            .map(|vm| vm.get("tasks").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(tasks, 10.0, "plan must cover exactly the residual set");
        // An orphan remaining selects the dynamic policy, like an orphan
        // deadline selects the deadline search.
        let r = handle(&c, r#"{"op":"plan","budget":40,"remaining":[0,1,2]}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("dynamic"));
        // Orphan deadline + remaining is ambiguous: refuse, don't guess.
        let e = handle(
            &c,
            r#"{"op":"plan","budget":40,"deadline":3600,"remaining":[0,1]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("explicitly"), "{e:#}");
    }

    #[test]
    fn remaining_is_rejected_where_it_would_be_ignored() {
        let c = ctx();
        // Policies that ignore the residual set must refuse it.
        for policy in ["budget-heuristic", "mi", "mp", "multistart"] {
            let line = format!(
                r#"{{"op":"plan","budget":80,"policy":"{policy}","remaining":[0,1]}}"#
            );
            let e = handle(&c, &line).unwrap_err();
            assert!(format!("{e:#}").contains("remaining"), "{policy}: {e:#}");
        }
        // Unknown / duplicate task ids are named in the error.
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamic","remaining":[99999]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("unknown task"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamic","remaining":[3,3]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("twice"), "{e:#}");
        // Campaigns manage their own residuals.
        let e = handle(
            &c,
            r#"{"op":"campaign","budget":80,"policy":"dynamic","remaining":[1]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("remaining"), "{e:#}");
    }

    #[test]
    fn sweep_threads_field_keeps_results_identical() {
        let c = ctx();
        let a = handle(&c, r#"{"op":"sweep","budgets":[60,80],"threads":1}"#).unwrap();
        let b = handle(&c, r#"{"op":"sweep","budgets":[60,80],"threads":4}"#).unwrap();
        let rows = |r: &Reply| {
            r.body
                .path(&["sweep", "rows"])
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|row| {
                    (
                        row.get("policy").unwrap().as_str().unwrap().to_string(),
                        row.get("makespan").unwrap().as_f64().unwrap().to_bits(),
                        row.get("cost").unwrap().as_f64().unwrap().to_bits(),
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(rows(&a), rows(&b));
        assert!(handle(&c, r#"{"op":"sweep","budgets":[60],"threads":"x"}"#).is_err());
    }

    #[test]
    fn campaign_replications_aggregate() {
        let c = ctx();
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":150,"replications":3,"threads":2,
                "noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("replications").unwrap().as_f64(), Some(3.0));
        let runs = r.body.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 3);
        // Every per-run row carries the flags the aggregate summarises.
        for run in runs {
            assert!(run.get("within_budget").is_some());
            assert!(run.get("complete").is_some());
        }
        let frac = r.body.get("complete_frac").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&frac));
        assert!(r.body.get("mean_wall_clock").unwrap().as_f64().unwrap() > 0.0);
        // Wire-driven fan-out is bounded: absurd knobs are rejected, not
        // executed.
        let e = handle(&c, r#"{"op":"campaign","budget":80,"replications":1000000000}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("limit"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"campaign","budget":80,"replications":2,"threads":100000}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("limit"), "{e:#}");
    }

    #[test]
    fn unknown_policy_wins_over_remaining_complaint() {
        // A typoed policy name plus `remaining` must report unknown
        // policy, not tell the client to drop `remaining`.
        let c = ctx();
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamc","remaining":[0]}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown policy"), "{msg}");
        assert!(!msg.contains("honoured"), "{msg}");
    }

    #[test]
    fn orphan_deadline_selects_the_deadline_policy() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":200,"deadline":3600}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("deadline"));
        assert!(r.body.get("makespan").unwrap().as_f64().unwrap() <= 3600.0 + 1e-6);
    }

    #[test]
    fn submit_status_jobs_cancel_roundtrip() {
        let c = ctx();
        // Submit an async plan job and poll it to completion.
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        let id = r.body.get("job_id").unwrap().as_str().unwrap().to_string();
        let mut state = String::new();
        for _ in 0..200 {
            let s = handle(&c, &format!(r#"{{"op":"status","job_id":"{id}"}}"#)).unwrap();
            state = s.body.path(&["job", "state"]).unwrap().as_str().unwrap().to_string();
            if state == "done" || state == "failed" {
                assert_eq!(state, "done");
                let makespan = s
                    .body
                    .path(&["job", "result", "makespan"])
                    .unwrap()
                    .as_f64()
                    .unwrap();
                assert!(makespan > 0.0);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(state, "done", "job never finished");
        // Listing contains it.
        let l = handle(&c, r#"{"op":"jobs"}"#).unwrap();
        assert!(!l.body.get("jobs").unwrap().as_arr().unwrap().is_empty());
        // Cancelling a finished job is a no-op.
        let r = handle(&c, &format!(r#"{{"op":"cancel","job_id":"{id}"}}"#)).unwrap();
        assert_eq!(r.body.get("cancelled"), Some(&Json::Bool(false)));
    }

    #[test]
    fn submit_rejects_recursive_and_control_ops() {
        let c = ctx();
        for op in ["submit", "shutdown", "status", "jobs", "cancel"] {
            let line = format!(r#"{{"op":"submit","job":{{"op":"{op}"}}}}"#);
            assert!(handle(&c, &line).is_err(), "{op} must be rejected");
        }
        assert!(handle(&c, r#"{"op":"submit"}"#).is_err());
        assert!(handle(&c, r#"{"op":"status","job_id":"nope"}"#).is_err());
    }

    #[test]
    fn plan_detail_roundtrips_through_config() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":70,"detail":true}"#).unwrap();
        let plan_json = r.body.get("plan").unwrap();
        let sys = crate::workload::paper::table1_system(0.0);
        let plan = crate::config::plan_from_json(&sys, plan_json).unwrap();
        assert!(plan.validate_partition(&sys).is_ok());
        assert_eq!(
            plan.score(&sys).makespan,
            r.body.get("makespan").unwrap().as_f64().unwrap()
        );
    }

    #[test]
    fn sweep_short() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"sweep","budgets":[60,80]}"#).unwrap();
        let rows = r.body.path(&["sweep", "rows"]).unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 6);
    }

    #[test]
    fn stats_reports_backlog_bound_and_per_shard_gauges() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        let engine = r.body.get("engine").unwrap();
        let shards = engine.get("shards").unwrap().as_f64().unwrap() as usize;
        assert!(shards >= 1);
        assert!(engine.get("max_backlog").unwrap().as_f64().unwrap() >= 1.0);
        let per_shard = engine.get("shard_stats").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), shards);
        for (i, s) in per_shard.iter().enumerate() {
            assert_eq!(s.get("shard").unwrap().as_f64(), Some(i as f64));
            assert_eq!(s.get("depth").unwrap().as_f64(), Some(0.0));
            assert!(s.get("high_water").is_some());
            assert_eq!(s.get("rejected").unwrap().as_f64(), Some(0.0));
        }
        assert_eq!(r.body.path(&["stats", "jobs_rejected"]).unwrap().as_f64(), Some(0.0));
        assert!(r.body.path(&["stats", "queue_wait_us_p50"]).is_some());
    }

    #[test]
    fn submit_validates_priority_and_deadline_fields() {
        let c = ctx();
        let e = handle(
            &c,
            r#"{"op":"submit","priority":12,"job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("0..=9"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"submit","priority":"high","job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("priority"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"submit","deadline_ms":"soon","job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("deadline_ms"), "{e:#}");
        // A valid placement is accepted and echoed through status, along
        // with the job's recorded queue wait.
        let r = handle(
            &c,
            r#"{"op":"submit","priority":4,"deadline_ms":60000,"job":{"op":"plan","budget":80}}"#,
        )
        .unwrap();
        let id = r.body.get("job_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(
            c.jobs().wait_terminal(&id, std::time::Duration::from_secs(60)),
            Some(crate::coordinator::JobState::Done)
        );
        let job = c.jobs().status(&id).unwrap();
        assert_eq!(job.get("priority").unwrap().as_f64(), Some(4.0));
        assert_eq!(job.get("deadline_ms").unwrap().as_f64(), Some(60000.0));
        assert!(job.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn saturated_shard_rejects_with_structured_busy() {
        use std::time::Duration;
        let metrics = Arc::new(Metrics::new());
        // One shard, backlog bound of one: trivially saturated.
        let engine = Arc::new(JobEngine::with_backlog(1, 1, Arc::clone(&metrics)));
        let c = Context::with_engine(Arc::new(NativeEvaluator), metrics, Arc::clone(&engine));
        // Occupy the worker, then fill the single queue slot.
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let blocker = engine.submit(
            "block",
            Box::new(move |_| {
                started_tx.send(()).unwrap();
                go_rx.recv().unwrap();
                Ok(Json::Null)
            }),
        );
        started_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let filler = engine.submit("fill", Box::new(|_| Ok(Json::Null)));
        // Async submit is rejected with the structured shape, not an
        // opaque error string and not a hang.
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.body.get("error").unwrap().as_str(), Some("busy"));
        assert_eq!(r.body.get("shard").unwrap().as_f64(), Some(0.0));
        assert_eq!(r.body.get("backlog").unwrap().as_f64(), Some(1.0));
        // Synchronous heavy ops get the same rejection.
        let r = handle(&c, r#"{"op":"sweep","budgets":[60]}"#).unwrap();
        assert_eq!(r.body.get("error").unwrap().as_str(), Some("busy"));
        let r = handle(&c, r#"{"op":"campaign","budget":120}"#).unwrap();
        assert_eq!(r.body.get("error").unwrap().as_str(), Some("busy"));
        // The rejections are visible in stats.
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        assert!(r.body.path(&["stats", "jobs_rejected"]).unwrap().as_f64().unwrap() >= 3.0);
        let shard0 = &r.body.path(&["engine", "shard_stats"]).unwrap().as_arr().unwrap()[0];
        assert!(shard0.get("rejected").unwrap().as_f64().unwrap() >= 3.0);
        assert_eq!(shard0.get("high_water").unwrap().as_f64(), Some(1.0));
        // Drain: the saturated server recovers without restarts.
        go_tx.send(()).unwrap();
        for id in [&blocker, &filler] {
            assert_eq!(
                c.jobs().wait_terminal(id, Duration::from_secs(10)),
                Some(crate::coordinator::JobState::Done)
            );
        }
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
    }
}
