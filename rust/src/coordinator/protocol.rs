//! Request handling for the coordinator's line-delimited JSON protocol:
//! a thin `decode → dispatch(typed) → encode` pipeline over the
//! [`super::api`] types.
//!
//! [`handle`] parses one request line into an [`api::Request`], runs the
//! typed dispatcher, and encodes the typed [`api::Response`] (or the
//! [`api::ApiError`]) back to a wire body.  The TCP server is a thin
//! transport around [`handle_line`], and the protocol tests drive the
//! pipeline without sockets.
//!
//! Version negotiation (see [`super::api`] for the full rules): a
//! version-less request gets v1 semantics — success bodies are the
//! historical shapes, non-`busy` errors surface as `Err` here (the
//! transport encodes them as `{"ok":false,"error":"<string>"}`), and
//! `busy` keeps its legacy reply shape.  A `"v":2` request never gets an
//! `Err`: every failure is encoded as the structured
//! `{"ok":false,"error":{"code":…,"message":…,"detail":…?}}` body, with
//! `busy` carrying a `retry_after_ms` hint derived from the queue-wait
//! p50 reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::analysis::report::{run_policy_sweep_ctl, CORE_POLICIES};
use crate::cloudsim::{
    run_campaign_ctl, run_campaign_replications_ctl, sample_runs, summarise_replications,
    CampaignOutcome, CampaignSpec, NoiseModel, SimConfig, SimOutcome, Simulator,
};
use crate::config;
use crate::eval::PlanEvaluator;
use crate::model::System;
use crate::scheduler::{PolicyRegistry, SolveOutcome};
use crate::util::{CancelToken, Json};

use super::api::{self, ApiError};
use super::engine::{JobCtl, JobEngine, JobError, JobFn};
use super::state::JobRegistry;
use super::Metrics;

/// Shared coordinator state handed to every request.
pub struct Context {
    pub evaluator: Arc<dyn PlanEvaluator>,
    pub metrics: Arc<Metrics>,
    /// The sharded worker pool every job (async submit or synchronous
    /// heavy op) executes on.
    pub engine: Arc<JobEngine>,
    /// Name → policy resolution for `plan` / `simulate` / `campaign`.
    pub registry: Arc<PolicyRegistry>,
    /// Set when this request is already running *inside* the engine (as
    /// a job): heavy ops then execute inline with this handle's cancel
    /// token and progress sink instead of re-submitting to the pool.
    pub job: Option<JobCtl>,
    /// Content-addressed solve cache consulted by `plan` (see
    /// [`crate::persist::SolveCache`]).  `None` when the server runs
    /// without `--cache-capacity` — every plan then solves fresh.
    pub cache: Option<Arc<crate::persist::SolveCache>>,
    /// Durable job journal backing crash recovery (see
    /// [`crate::persist::Journal`]).  Present only when the server was
    /// started with `--journal`; the `persist` op reports on it.
    pub journal: Option<Arc<crate::persist::Journal>>,
    /// Whether the `chaos` op may drive the failpoint registry
    /// ([`crate::util::failpoint`]).  Off by default; a production
    /// server must opt in with `--chaos-allowed`.
    pub chaos_allowed: bool,
    /// When this coordinator came up (the `health` op reports uptime).
    pub started: std::time::Instant,
}

impl Context {
    /// A context with its own auto-sized engine (tests, embedding).
    pub fn new(evaluator: Arc<dyn PlanEvaluator>, metrics: Arc<Metrics>) -> Self {
        let engine = Arc::new(JobEngine::new(0, Arc::clone(&metrics)));
        Self::with_engine(evaluator, metrics, engine)
    }

    /// A context sharing an existing engine (one per server; job ids are
    /// visible across every connection).
    pub fn with_engine(
        evaluator: Arc<dyn PlanEvaluator>,
        metrics: Arc<Metrics>,
        engine: Arc<JobEngine>,
    ) -> Self {
        Self {
            evaluator,
            metrics,
            engine,
            registry: Arc::new(PolicyRegistry::builtin()),
            job: None,
            cache: None,
            journal: None,
            chaos_allowed: false,
            started: std::time::Instant::now(),
        }
    }

    /// The job registry backing `status` / `jobs` / `cancel`.
    pub fn jobs(&self) -> &Arc<JobRegistry> {
        self.engine.registry()
    }

    fn clone_shared(&self) -> Self {
        Self {
            evaluator: Arc::clone(&self.evaluator),
            metrics: Arc::clone(&self.metrics),
            engine: Arc::clone(&self.engine),
            registry: Arc::clone(&self.registry),
            job: None,
            cache: self.cache.clone(),
            journal: self.journal.clone(),
            chaos_allowed: self.chaos_allowed,
            started: self.started,
        }
    }

    /// The cancel token governing this request (the job's token inside
    /// the engine; an inert default token otherwise).
    fn cancel_token(&self) -> CancelToken {
        self.job.as_ref().map(JobCtl::cancel_token).unwrap_or_default()
    }

    /// The admission-control busy rejection.  The queue-wait-derived
    /// retry hint is computed only for v2 requests — the byte-pinned v1
    /// busy reply never carries it, and rejections are the load-shed
    /// path (no point sorting the reservoir for a discarded value).
    fn busy_error(&self, shard: usize, backlog: usize, version: u8) -> ApiError {
        let hint = (version >= api::V2).then(|| self.metrics.retry_after_ms());
        ApiError::busy(shard, backlog, hint)
    }
}

/// Outcome of one request: the response plus whether the server should
/// shut down afterwards.
pub struct Reply {
    pub body: Json,
    pub shutdown: bool,
}

impl Reply {
    fn new(resp: api::Response) -> Self {
        let shutdown = resp.is_shutdown();
        Self { body: resp.encode(), shutdown }
    }
}

/// Handle one request line.  v1 (version-less) errors other than `busy`
/// are returned as `Err` — the transport maps them to
/// `{"ok":false,...}` so the connection survives malformed input; every
/// such error is prefixed with the offending request's `op` (and
/// `policy`, when one was given) so wire clients can diagnose bad
/// requests.  v2 requests never produce an `Err`: their failures come
/// back as structured error bodies.
pub fn handle(ctx: &Context, line: &str) -> Result<Reply> {
    match run(ctx, line) {
        Ok(reply) => Ok(reply),
        Err((version, e)) => {
            if version >= api::V2 {
                Ok(Reply { body: e.encode_v2(), shutdown: false })
            } else if e.code == api::ErrorCode::Busy {
                // The legacy busy reply is an `ok:false` body, not an
                // error: clients key on `error == "busy"` to back off.
                Ok(Reply { body: e.encode_v1(), shutdown: false })
            } else {
                Err(anyhow!("{}", e.message))
            }
        }
    }
}

/// [`handle`] with every failure encoded into a reply body — the single
/// error-shape funnel the transport uses, so server-side decode failures
/// and protocol-level failures produce identical wire bytes.
pub fn handle_line(ctx: &Context, line: &str) -> Reply {
    match handle(ctx, line) {
        Ok(reply) => reply,
        Err(e) => Reply {
            body: Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("{e:#}"))),
            ]),
            shutdown: false,
        },
    }
}

fn run(ctx: &Context, line: &str) -> Result<Reply, (u8, ApiError)> {
    let raw = Json::parse(line)
        .map_err(|e| (api::V1, ApiError::bad_request(format!("bad json: {e}"))))?;
    let version = api::version_of(&raw).map_err(|e| (api::V1, e))?;
    let op = raw
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| (version, ApiError::bad_request("missing \"op\"")))?
        .to_string();
    // Errors are prefixed with the op (and the policy, when one was
    // given) — except `busy`, whose v1 encoding is field-keyed.
    let prefix = |e: ApiError| -> (u8, ApiError) {
        if e.code == api::ErrorCode::Busy {
            return (version, e);
        }
        let message = match policy_name(&raw) {
            Some(p) => format!("op {op:?} (policy {p:?}): {}", e.message),
            None => format!("op {op:?}: {}", e.message),
        };
        (version, ApiError { message, ..e })
    };
    let req = api::Request::decode(&raw).map_err(&prefix)?;
    dispatch(ctx, &req, version).map_err(prefix)
}

/// The request's policy name: `"policy"`, or the legacy `"approach"`.
fn policy_name(req: &Json) -> Option<&str> {
    req.get("policy")
        .or_else(|| req.get("approach"))
        .and_then(Json::as_str)
}

fn dispatch(ctx: &Context, req: &api::Request, version: u8) -> Result<Reply, ApiError> {
    use api::Request as R;
    match req {
        R::Ping => Ok(Reply::new(api::Response::Pong)),
        R::Shutdown => Ok(Reply::new(api::Response::Bye)),
        R::Stats => Ok(Reply::new(op_stats(ctx))),
        R::Jobs => Ok(Reply::new(api::Response::Jobs { jobs: ctx.jobs().list() })),
        R::ListPolicies => Ok(Reply::new(api::Response::Policies(
            ctx.registry
                .iter()
                .map(|p| api::PolicyInfo {
                    name: p.name().to_string(),
                    description: p.description().to_string(),
                })
                .collect(),
        ))),
        R::ListScenarios => Ok(Reply::new(api::Response::Scenarios(
            crate::workload::SCENARIOS
                .iter()
                .map(|s| api::ScenarioInfo {
                    name: s.name.to_string(),
                    description: s.description.to_string(),
                })
                .collect(),
        ))),
        R::Describe => {
            if version < api::V2 {
                return Err(ApiError::bad_request(
                    "\"describe\" requires protocol version 2 (send \"v\":2)",
                ));
            }
            Ok(Reply::new(api::Response::Schema(api::describe_schema())))
        }
        R::Persist(r) => {
            if version < api::V2 {
                return Err(ApiError::bad_request(
                    "\"persist\" requires protocol version 2 (send \"v\":2)",
                ));
            }
            op_persist(ctx, r).map(Reply::new)
        }
        R::Health => {
            if version < api::V2 {
                return Err(ApiError::bad_request(
                    "\"health\" requires protocol version 2 (send \"v\":2)",
                ));
            }
            Ok(Reply::new(op_health(ctx)))
        }
        R::Chaos(r) => {
            if version < api::V2 {
                return Err(ApiError::bad_request(
                    "\"chaos\" requires protocol version 2 (send \"v\":2)",
                ));
            }
            op_chaos(ctx, r).map(Reply::new)
        }
        R::Plan(r) => op_plan(ctx, r).map(Reply::new),
        R::Simulate(r) => op_simulate(ctx, r).map(Reply::new),
        R::Sweep(r) => op_sweep(ctx, r, version),
        R::Campaign(r) => op_campaign(ctx, r, version),
        R::EstimatePerf(r) => op_estimate_perf(r).map(Reply::new),
        R::Submit(r) => op_submit(ctx, r, version),
        R::Status(r) => op_status(ctx, r).map(Reply::new),
        R::Cancel(r) => Ok(Reply::new(api::Response::Cancelled {
            cancelled: ctx.jobs().cancel(&r.job_id),
        })),
    }
}

fn op_stats(ctx: &Context) -> api::Response {
    let shard_stats = ctx.engine.shard_stats();
    let mut stats = ctx.metrics.snapshot();
    // Degraded-journal visibility rides on `stats` too (not just
    // `health`): a journal-less server's reply is unchanged.
    if let (Json::Obj(m), Some(j)) = (&mut stats, &ctx.journal) {
        m.insert("journal_degraded".into(), Json::Bool(j.is_degraded()));
    }
    api::Response::Stats(api::StatsResponse {
        stats,
        engine: api::EngineInfo {
            shards: ctx.engine.n_shards() as u64,
            queued: shard_stats.iter().map(|s| s.depth).sum::<usize>() as u64,
            max_backlog: ctx.engine.max_backlog() as u64,
            shard_stats: shard_stats
                .iter()
                .enumerate()
                .map(|(i, s)| api::ShardRow {
                    shard: i as u64,
                    depth: s.depth as u64,
                    high_water: s.high_water as u64,
                    rejected: s.rejected,
                })
                .collect(),
        },
    })
}

/// `submit`: run any other request asynchronously on the sharded
/// engine; poll with `status`, stop with `cancel`.  No thread is
/// spawned here — the job queues onto its shard (in `priority` /
/// `deadline_ms` / FIFO order; both fields ride on the *outer* submit
/// object) and runs when a pool worker frees up.  A shard at its
/// backlog bound rejects the submit with the `busy` rejection instead
/// of queueing.
fn op_submit(ctx: &Context, r: &api::SubmitRequest, version: u8) -> Result<Reply, ApiError> {
    if crate::util::failpoint::apply("engine.submit").is_some() {
        return Err(ApiError::internal("failpoint engine.submit: injected error"));
    }
    // Decode validated the inner op's presence and rejected control ops.
    let inner_op = r.job.get("op").and_then(Json::as_str).unwrap_or("?").to_string();
    let prio = r.placement.job_priority();
    let line = r.job.to_string();
    let submitted = ctx.engine.try_submit_journaled(
        &inner_op,
        prio,
        Some(&line),
        job_work(ctx.clone_shared(), line.clone()),
    );
    match submitted {
        Ok(job_id) => Ok(Reply::new(api::Response::Submitted { job_id })),
        Err(busy) => Err(ctx.busy_error(busy.shard, busy.backlog, version)),
    }
}

/// The pool-worker closure executing one submitted (or journal-replayed)
/// request line: re-enters [`handle`] with the job's control handle
/// installed, so heavy ops run inline with its cancel token.
fn job_work(worker_ctx: Context, line: String) -> JobFn {
    Box::new(move |ctl| {
        let mut job_ctx = worker_ctx;
        job_ctx.job = Some(ctl.clone());
        match handle(&job_ctx, &line) {
            // A v2 job encodes its failures into the body; surface
            // them as job failures so `status` reports `"failed"`.
            Ok(reply) if reply.body.get("ok") == Some(&Json::Bool(false)) => {
                let msg = reply
                    .body
                    .path(&["error", "message"])
                    .or_else(|| reply.body.get("error"))
                    .and_then(Json::as_str)
                    .unwrap_or("job failed")
                    .to_string();
                Err(msg)
            }
            Ok(reply) => Ok(reply.body),
            Err(e) => Err(format!("{e:#}")),
        }
    })
}

/// `status`: current state, progress and streaming partial results.
/// Pass `"partials_from"` (the previous reply's `partials_next`) to
/// receive only new partial rows instead of the whole backlog.
fn op_status(ctx: &Context, r: &api::StatusRequest) -> Result<api::Response, ApiError> {
    let from = r.partials_from.unwrap_or(0);
    let status = ctx
        .jobs()
        .status_from(&r.job_id, from)
        .ok_or_else(|| ApiError::evicted(format!("unknown job {:?}", r.job_id)))?;
    Ok(api::Response::Status { job: status })
}

/// Resolve the request's policy and solve it through the shared
/// evaluator.  All planning ops (`plan`, `simulate`) funnel through here.
fn solve_with(
    ctx: &Context,
    sys: &System,
    params: &api::SolveParams,
) -> Result<SolveOutcome, ApiError> {
    let name = match params.policy.as_deref() {
        Some(n) => n,
        // Deadline + remaining with no policy is ambiguous (the deadline
        // search ignores residual sets, dynamic ignores deadlines) —
        // refuse rather than guess and then blame the guess.
        None if params.deadline.is_some() && params.remaining.is_some() => {
            return Err(ApiError::bad_request(
                "both \"deadline\" and \"remaining\" given without a \"policy\" — \
                 name the policy explicitly",
            ));
        }
        // A deadline with no explicit policy selects the deadline search
        // (mirrors the CLI) — the budget heuristic would silently ignore it.
        None if params.deadline.is_some() => "deadline",
        // A residual task set with no explicit policy selects dynamic
        // re-planning for the same reason.
        None if params.remaining.is_some() => "dynamic",
        None => "budget-heuristic",
    };
    // Resolve before the remaining-validation below so a typoed policy
    // name reports as unknown-policy, not as a misleading complaint
    // about `remaining`.  (Knob *type/bound* errors surface earlier, at
    // Request::decode — see its doc on error precedence.)
    let policy = ctx
        .registry
        .resolve(name)
        .map_err(|e| ApiError::unknown_policy(format!("{e}")))?;
    let sreq = params
        .solve_request()
        .with_evaluator(ctx.evaluator.as_ref())
        .with_cancel(ctx.cancel_token());
    if let Some(remaining) = &sreq.remaining {
        // `remaining` drives dynamic re-planning; every other policy
        // would silently plan the full workload, so reject it rather
        // than mislead the client.
        if policy.name() != "dynamic" {
            return Err(ApiError::bad_request(format!(
                "\"remaining\" is only honoured by the \"dynamic\" policy (got {name:?})"
            )));
        }
        let n = sys.tasks().len();
        let mut seen = vec![false; n];
        for t in remaining {
            let i = t.index();
            if i >= n {
                return Err(ApiError::bad_request(format!(
                    "\"remaining\" names unknown task {i} (system has {n})"
                )));
            }
            if seen[i] {
                return Err(ApiError::bad_request(format!(
                    "\"remaining\" lists task {i} twice"
                )));
            }
            seen[i] = true;
        }
    }
    Ok(policy.solve(sys, &sreq))
}

fn op_plan(ctx: &Context, r: &api::PlanRequest) -> Result<api::Response, ApiError> {
    let sys = r.target.resolve()?;
    // Consult the solve cache first: the key canonicalises the target
    // and the outcome-relevant params (response-only knobs like
    // `detail` and `threads` are excluded — see PlanRequest::cache_key),
    // so a hit serves the exact prior outcome without re-solving.
    let key = ctx.cache.as_ref().map(|_| r.cache_key());
    if let (Some(cache), Some(key)) = (&ctx.cache, &key) {
        if let Some(outcome) = cache.get(key) {
            ctx.metrics.record_cache_hit();
            ctx.metrics.record_plan();
            return Ok(plan_response(&sys, r, &outcome));
        }
        ctx.metrics.record_cache_miss();
    }
    let outcome = solve_with(ctx, &sys, &r.params)?;
    // Only successful solves are cached: errors must re-validate.
    if let (Some(cache), Some(key)) = (&ctx.cache, key) {
        let evicted = cache.insert(key, outcome.clone());
        ctx.metrics.record_cache_insert();
        if evicted {
            ctx.metrics.record_cache_evict();
        }
    }
    ctx.metrics.record_plan();
    Ok(plan_response(&sys, r, &outcome))
}

/// Assemble the `plan` reply from a (fresh or cached) solve outcome.
/// Response-only knobs (`detail`) are applied here, after the cache, so
/// cached outcomes serve every presentation variant.
fn plan_response(sys: &System, r: &api::PlanRequest, outcome: &SolveOutcome) -> api::Response {
    api::Response::Plan(Box::new(api::PlanResponse {
        policy: outcome.policy.to_string(),
        approach: crate::scheduler::legacy_name(outcome.policy).to_string(),
        budget: r.params.budget,
        effective_budget: outcome.effective_budget,
        makespan: outcome.score.makespan,
        cost: outcome.score.cost,
        feasible: outcome.feasible,
        iterations: outcome.iterations as u64,
        probes: outcome.probes as u64,
        vms: outcome
            .plan
            .vms
            .iter()
            .map(|vm| api::VmRow {
                instance_type: sys.instance_type(vm.it).name.clone(),
                tasks: vm.len() as u64,
                exec: vm.exec(sys),
                cost: vm.cost(sys),
            })
            .collect(),
        // Full task-level assignment on request (importable via
        // config::plan_from_json for external execution engines).
        plan: r.detail.then(|| config::plan_to_json(sys, &outcome.plan)),
    }))
}

fn op_simulate(ctx: &Context, r: &api::SimulateRequest) -> Result<api::Response, ApiError> {
    let sys = r.target.resolve()?;
    let outcome = solve_with(ctx, &sys, &r.params)?;
    ctx.metrics.record_plan();
    let noise = r.noise.map(|n| n.model()).unwrap_or_else(NoiseModel::none);
    let seed = r.params.seed.unwrap_or(0);
    let sim = Simulator::run_plan(&sys, &outcome.plan, &SimConfig { noise, seed });
    Ok(api::Response::Simulate(api::SimulateResponse {
        policy: outcome.policy.to_string(),
        planned_feasible: outcome.feasible,
        makespan: sim.makespan,
        cost: sim.cost,
        completed: sim.completed.len() as u64,
        stranded: sim.stranded.len() as u64,
        failures: sim.failures as u64,
    }))
}

/// A fully validated sweep, ready to execute on a pool worker.
struct SweepJob {
    sys: System,
    budgets: Vec<f64>,
    threads: usize,
    evaluator: Arc<dyn PlanEvaluator>,
    registry: Arc<PolicyRegistry>,
}

/// Run a validated sweep, publishing per-cell progress and streaming
/// each finished cell as a partial result; returns the report payload.
fn exec_sweep(job: &SweepJob, ctl: &JobCtl) -> Json {
    let total = (job.budgets.len() * CORE_POLICIES.len()) as u64;
    ctl.progress(0, total);
    let done = AtomicU64::new(0);
    let report = run_policy_sweep_ctl(
        &job.sys,
        &job.budgets,
        CORE_POLICIES,
        &job.registry,
        job.evaluator.as_ref(),
        job.threads,
        &ctl.cancel_token(),
        &|_idx, row| {
            ctl.progress(done.fetch_add(1, Ordering::Relaxed) + 1, total);
            ctl.partial(row.to_json());
        },
    )
    .expect("core policies are builtin");
    // Final authoritative count (observers race under parallelism;
    // set_progress is max-monotonic).
    ctl.progress(report.rows.len() as u64, total);
    report.to_json()
}

fn op_sweep(ctx: &Context, r: &api::SweepRequest, version: u8) -> Result<Reply, ApiError> {
    let sys = r.target.resolve()?;
    let budgets = r
        .budgets
        .clone()
        .unwrap_or_else(|| crate::workload::paper::BUDGETS.to_vec());
    if budgets.is_empty() {
        return Err(ApiError::bad_request("empty budgets"));
    }
    let threads = r.threads.unwrap_or(1) as usize;
    let job = SweepJob {
        sys,
        budgets,
        threads,
        evaluator: Arc::clone(&ctx.evaluator),
        registry: Arc::clone(&ctx.registry),
    };
    ctx.metrics.record_plan();
    match &ctx.job {
        // Already on a pool worker (async submit): run inline.
        Some(ctl) => Ok(Reply::new(api::Response::Sweep(api::SweepResponse {
            sweep: exec_sweep(&job, ctl),
        }))),
        // Synchronous call: the same execution, behind the same bounded
        // pool — the caller's thread just waits for its own job, and a
        // shard at its backlog bound rejects with `busy` like a submit.
        None => {
            let prio = r.placement.job_priority();
            match ctx.engine.run_sync_with(
                "sweep",
                prio,
                Box::new(move |ctl| {
                    let sweep = exec_sweep(&job, ctl);
                    Ok(api::Response::Sweep(api::SweepResponse { sweep }).encode())
                }),
            ) {
                Ok(body) => Ok(Reply { body, shutdown: false }),
                Err(JobError::Busy { shard, backlog }) => {
                    Err(ctx.busy_error(shard, backlog, version))
                }
                Err(JobError::Cancelled(e)) => Err(ApiError::cancelled(e)),
                Err(JobError::DeadlineExceeded(e)) => Err(ApiError::deadline_exceeded(e)),
                Err(JobError::Failed(e)) => Err(ApiError::internal(e)),
            }
        }
    }
}

/// A fully validated campaign, ready to execute on a pool worker.
struct CampaignJob {
    sys: System,
    spec: CampaignSpec,
    replications: usize,
    threads: usize,
}

/// One finished replication as a streaming partial row.
fn replication_row(out: &CampaignOutcome) -> Json {
    Json::obj(vec![
        ("wall_clock", Json::num(out.wall_clock)),
        ("spent", Json::num(out.spent)),
        ("complete", Json::Bool(out.complete)),
        ("within_budget", Json::Bool(out.within_budget)),
        ("rounds", Json::num(out.rounds.len() as f64)),
    ])
}

/// One finished campaign round as a streaming partial row.
fn round_row(round: usize, sim: &SimOutcome) -> Json {
    Json::obj(vec![
        ("round", Json::num(round as f64)),
        ("completed", Json::num(sim.completed.len() as f64)),
        ("stranded", Json::num(sim.stranded.len() as f64)),
        ("failures", Json::num(sim.failures as f64)),
        ("cost", Json::num(sim.cost)),
        ("makespan", Json::num(sim.makespan)),
    ])
}

/// Run a validated campaign, publishing progress (replications done, or
/// rounds done for a single run) and streaming partial rows.  A cancel
/// stops the fan-out at the next replication/round boundary; the reply
/// then covers only the work that ran (`cancelled: true`).
fn exec_campaign(job: &CampaignJob, ctl: &JobCtl) -> Json {
    let cancel = ctl.cancel_token();
    if job.replications > 1 {
        // Monte-Carlo mode: fan the replications out and report the
        // aggregate (plus per-replication rows for downstream tooling).
        let total = job.replications as u64;
        ctl.progress(0, total);
        let done = AtomicU64::new(0);
        let outs = run_campaign_replications_ctl(
            &job.sys,
            &job.spec,
            job.replications,
            job.threads,
            &cancel,
            &|_r, out| {
                ctl.progress(done.fetch_add(1, Ordering::Relaxed) + 1, total);
                ctl.partial(replication_row(out));
            },
        );
        let outs: Vec<CampaignOutcome> = outs.into_iter().flatten().collect();
        // Final authoritative count: racing observers may have published
        // out of order (set_progress is max-monotonic, never regressing).
        ctl.progress(outs.len() as u64, total);
        let summary = if outs.is_empty() {
            // Cancelled before any replication completed: nothing to
            // aggregate (only reachable through a cancelled job, whose
            // result is discarded anyway).
            None
        } else {
            let s = summarise_replications(&outs);
            let n = s.replications as f64;
            Some(api::ReplicationSummary {
                complete_frac: s.complete as f64 / n,
                within_budget_frac: s.within_budget as f64 / n,
                mean_wall_clock: s.mean_wall_clock,
                mean_spent: s.mean_spent,
                runs: outs
                    .iter()
                    .map(|o| api::RunRow {
                        wall_clock: o.wall_clock,
                        spent: o.spent,
                        complete: o.complete,
                        within_budget: o.within_budget,
                        rounds: o.rounds.len() as u64,
                    })
                    .collect(),
            })
        };
        return api::Response::Campaign(api::CampaignResponse::Replicated {
            policy: job.spec.policy.name().to_string(),
            replications: outs.len() as u64,
            cancelled: cancel.is_cancelled(),
            summary,
        })
        .encode();
    }
    // Single campaign: progress over re-planning rounds.
    let total = job.spec.max_rounds as u64;
    ctl.progress(0, total);
    let out = run_campaign_ctl(&job.sys, &job.spec, &cancel, &mut |round, sim| {
        ctl.progress(round as u64 + 1, total);
        ctl.partial(round_row(round, sim));
    });
    api::Response::Campaign(api::CampaignResponse::Single {
        policy: job.spec.policy.name().to_string(),
        wall_clock: out.wall_clock,
        spent: out.spent,
        complete: out.complete,
        within_budget: out.within_budget,
        rounds: out.rounds.len() as u64,
        planned_makespan: out.planned.makespan,
        cancelled: cancel.is_cancelled(),
    })
    .encode()
}

/// Validate a campaign request into a [`CampaignJob`] (every error
/// surfaces here, synchronously, before anything queues).
fn parse_campaign(ctx: &Context, r: &api::CampaignRequest) -> Result<CampaignJob, ApiError> {
    let sys = r.target.resolve()?;
    let mut spec = CampaignSpec::new(r.params.budget);
    match r.params.policy.as_deref() {
        Some(name) => {
            spec.policy = ctx
                .registry
                .resolve_arc(name)
                .map_err(|e| ApiError::unknown_policy(format!("{e}")))?;
        }
        // Same rule as plan/simulate: an orphan deadline selects the
        // deadline policy rather than being silently ignored.
        None if r.params.deadline.is_some() => {
            spec.policy = ctx.registry.get_arc("deadline").expect("builtin");
        }
        None => {}
    }
    // Policy knobs (deadline, n_starts, sample_frac, planner, ...) ride
    // on the per-round request template; budget and seed are overridden
    // by the campaign loop itself.
    spec.base_request = r.params.solve_request();
    if spec.base_request.remaining.is_some() {
        return Err(ApiError::bad_request(
            "\"remaining\" is not accepted on campaigns (each round re-plans its own residual)",
        ));
    }
    spec.evaluator = Some(Arc::clone(&ctx.evaluator));
    if let Some(n) = &r.noise {
        spec.sim.noise = n.model();
    }
    spec.sim.seed = r.params.seed.unwrap_or(0);
    if let Some(m) = r.max_rounds {
        spec.max_rounds = m as usize;
    }
    // Replications are wire-bounded at decode time (4096); the single
    // "threads" field must not also multiply into every round's inner
    // solver, so the outer fan-out owns the parallelism.
    let replications = r.replications.unwrap_or(1).max(1) as usize;
    let threads = r.params.threads.unwrap_or(1) as usize;
    if replications > 1 {
        spec.base_request.threads = 1;
    }
    Ok(CampaignJob { sys, spec, replications, threads })
}

fn op_campaign(ctx: &Context, r: &api::CampaignRequest, version: u8) -> Result<Reply, ApiError> {
    let job = parse_campaign(ctx, r)?;
    match &ctx.job {
        // Already on a pool worker (async submit): run inline.
        Some(ctl) => Ok(Reply { body: exec_campaign(&job, ctl), shutdown: false }),
        // Synchronous call: identical execution behind the same bounded
        // pool; the caller's thread waits for its own job, and a shard
        // at its backlog bound rejects with `busy` like a submit.
        None => {
            let prio = r.placement.job_priority();
            match ctx.engine.run_sync_with(
                "campaign",
                prio,
                Box::new(move |ctl| Ok(exec_campaign(&job, ctl))),
            ) {
                Ok(body) => Ok(Reply { body, shutdown: false }),
                Err(JobError::Busy { shard, backlog }) => {
                    Err(ctx.busy_error(shard, backlog, version))
                }
                Err(JobError::Cancelled(e)) => Err(ApiError::cancelled(e)),
                Err(JobError::DeadlineExceeded(e)) => Err(ApiError::deadline_exceeded(e)),
                Err(JobError::Failed(e)) => Err(ApiError::internal(e)),
            }
        }
    }
}

fn op_estimate_perf(r: &api::EstimatePerfRequest) -> Result<api::Response, ApiError> {
    let sys = r.target.resolve()?;
    let per_cell = r.per_cell.unwrap_or(10) as usize;
    let noise = r.noise.map(|n| n.model()).unwrap_or_else(NoiseModel::none);
    let seed = r.seed.unwrap_or(0);
    let obs = sample_runs(&sys, per_cell, &noise, seed);
    let cells = sys.n_types() * sys.n_apps();
    let prior = vec![0.0; cells];
    // Prefer the XLA artifact; fall back to the native mirror.
    let est = match crate::runtime::XlaPerfEstimator::load() {
        Ok(e) => e.estimate(&sys, &obs, &prior, 1e-9).unwrap_or_else(|_| {
            crate::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-9)
        }),
        Err(_) => crate::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1e-9),
    };
    // Report estimated vs true matrix error.
    let mut max_rel = 0.0f64;
    for it in &sys.instance_types {
        for app in &sys.apps {
            let truth = sys.perf.get(it.id, app.id);
            let got = est[it.id.index() * sys.n_apps() + app.id.index()];
            max_rel = max_rel.max((got - truth).abs() / truth);
        }
    }
    Ok(api::Response::EstimatePerf(api::EstimatePerfResponse {
        samples: obs.len() as u64,
        estimate: est,
        max_rel_error: max_rel,
    }))
}

/// `persist` (v2 only): durability introspection — journal + cache
/// stats, and on-demand journal compaction.
fn op_persist(ctx: &Context, r: &api::PersistRequest) -> Result<api::Response, ApiError> {
    if r.action == api::PersistAction::Compact {
        let j = ctx.journal.as_ref().ok_or_else(|| {
            ApiError::bad_request(
                "\"compact\" requires a journal (start the server with --journal <path>)",
            )
        })?;
        j.compact()
            .map_err(|e| ApiError::internal(format!("journal compaction failed: {e}")))?;
    }
    let journal = match &ctx.journal {
        Some(j) => j.stats(),
        None => Json::obj(vec![("enabled", Json::Bool(false))]),
    };
    let cache = match &ctx.cache {
        Some(c) => {
            let (capacity, entries) = c.stats();
            Json::obj(vec![
                ("capacity", Json::num(capacity as f64)),
                ("enabled", Json::Bool(true)),
                ("entries", Json::num(entries as f64)),
            ])
        }
        None => Json::obj(vec![("enabled", Json::Bool(false))]),
    };
    Ok(api::Response::Persist {
        persist: Json::obj(vec![("cache", cache), ("journal", journal)]),
    })
}

/// `health` (v2 only): overall status plus per-subsystem detail.  The
/// top-level `status` is `"degraded"` exactly when the journal lost its
/// backing file and is running memory-only (see `docs/OPERATIONS.md`);
/// everything else is detail for operators and probes.
fn op_health(ctx: &Context) -> api::Response {
    let degraded = ctx.journal.as_ref().is_some_and(|j| j.is_degraded());
    let journal = match &ctx.journal {
        Some(j) => Json::obj(vec![
            ("attached", Json::Bool(!j.is_degraded())),
            ("enabled", Json::Bool(true)),
            ("write_errors", Json::num(j.write_errors() as f64)),
        ]),
        None => Json::obj(vec![("enabled", Json::Bool(false))]),
    };
    let cache = Json::obj(vec![("enabled", Json::Bool(ctx.cache.is_some()))]);
    let shard_stats = ctx.engine.shard_stats();
    let engine = Json::obj(vec![
        ("queued", Json::num(shard_stats.iter().map(|s| s.depth).sum::<usize>() as f64)),
        ("shards", Json::num(ctx.engine.n_shards() as f64)),
        ("watchdog_respawns", Json::num(ctx.engine.watchdog_respawns() as f64)),
    ]);
    let uptime_ms = ctx.started.elapsed().as_millis().min(u128::from(u64::MAX)) as u64;
    api::Response::Health {
        health: Json::obj(vec![
            ("cache", cache),
            ("engine", engine),
            ("journal", journal),
            ("status", Json::str(if degraded { "degraded" } else { "ok" })),
            ("uptime_ms", Json::num(uptime_ms as f64)),
        ]),
    }
}

/// `chaos` (v2 only, and only when the server opted in with
/// `--chaos-allowed`): inspect, arm and disarm fault-injection points.
/// Every action returns the resulting failpoint table, so an `arm` is
/// its own confirmation.  The spec grammar is documented in
/// [`crate::util::failpoint`] and `docs/OPERATIONS.md`.
fn op_chaos(ctx: &Context, r: &api::ChaosRequest) -> Result<api::Response, ApiError> {
    use crate::util::failpoint;
    if !ctx.chaos_allowed {
        return Err(ApiError::bad_request(
            "chaos is disabled (start the server with --chaos-allowed)",
        ));
    }
    match &r.action {
        api::ChaosAction::List => {}
        api::ChaosAction::Arm(spec) => failpoint::arm(spec).map_err(ApiError::bad_request)?,
        api::ChaosAction::Disarm(point) => {
            failpoint::disarm(point.as_deref());
        }
    }
    let points = failpoint::list();
    Ok(api::Response::Chaos {
        chaos: Json::obj(vec![
            ("armed", Json::Bool(!points.is_empty())),
            (
                "points",
                Json::arr(points.iter().map(|p| {
                    let mut fields = vec![
                        ("config", Json::str(&p.config)),
                        ("fired", Json::num(p.fired as f64)),
                        ("hits", Json::num(p.hits as f64)),
                        ("name", Json::str(&p.name)),
                    ];
                    if let Some(n) = p.remaining {
                        fields.push(("remaining", Json::num(n as f64)));
                    }
                    Json::obj(fields)
                })),
            ),
        ]),
    })
}

/// Re-install the journal's recovered jobs on startup: terminal jobs
/// become servable `status` entries with their pre-crash results;
/// jobs that were accepted but never finished re-enqueue under their
/// original ids (admission was granted before the crash, so the replay
/// deliberately bypasses the backlog bound).  Relative deadlines
/// restart from recovery time — wall-clock elapsed during the outage
/// is not charged against them.
pub fn replay_journal(ctx: &Context, recovered: Vec<crate::persist::RecoveredJob>) {
    let registry = ctx.engine.registry();
    // Reserve past the highest recovered id so new jobs never collide.
    let max_id = recovered
        .iter()
        .filter_map(|j| j.id.strip_prefix("j-").and_then(|s| s.parse::<u64>().ok()))
        .max();
    if let Some(m) = max_id {
        registry.reserve_ids(m + 1);
    }
    for job in recovered {
        match job.terminal {
            Some(t) => {
                let state = match t.state.as_str() {
                    "done" => super::JobState::Done,
                    "cancelled" => super::JobState::Cancelled,
                    _ => super::JobState::Failed,
                };
                registry.install_terminal(&job.id, &job.op, job.priority, state, t.result, t.error);
            }
            None => {
                registry.restore(&job.id, &job.op, job.priority);
                ctx.engine.resubmit_recovered(
                    &job.id,
                    job.priority,
                    job_work(ctx.clone_shared(), job.line.clone()),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;

    fn ctx() -> Context {
        Context::new(Arc::new(NativeEvaluator), Arc::new(Metrics::new()))
    }

    #[test]
    fn ping_and_stats() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"ping"}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert!(!r.shutdown);
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        assert!(r.body.get("stats").is_some());
    }

    #[test]
    fn shutdown_flag() {
        let r = handle(&ctx(), r#"{"op":"shutdown"}"#).unwrap();
        assert!(r.shutdown);
    }

    #[test]
    fn v1_reply_bytes_are_pinned() {
        // Exact wire bytes of the fixed-shape v1 replies: the typed
        // pipeline must not move a byte.  (These raw strings are the
        // explicit v1-parity fixtures.)
        let c = ctx();
        let body = |line: &str| handle(&c, line).unwrap().body.to_string();
        assert_eq!(body(r#"{"op":"ping"}"#), r#"{"ok":true,"pong":true}"#);
        assert_eq!(body(r#"{"op":"shutdown"}"#), r#"{"bye":true,"ok":true}"#);
        assert_eq!(
            body(r#"{"op":"cancel","job_id":"j-999"}"#),
            r#"{"cancelled":false,"ok":true}"#
        );
        // Error strings keep their exact v1 text through handle_line
        // (the transport funnel).
        let err = handle_line(&c, r#"{"op":"plan"}"#).body.to_string();
        assert_eq!(err, r#"{"error":"op \"plan\": missing \"budget\"","ok":false}"#);
        let err = handle_line(&c, "not json").body;
        assert!(err.get("error").unwrap().as_str().unwrap().starts_with("bad json:"));
    }

    #[test]
    fn plan_over_paper_system() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert!(r.body.get("makespan").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.body.get("feasible"), Some(&Json::Bool(true)));
        let n_vms = r.body.get("n_vms").unwrap().as_f64().unwrap();
        assert!(n_vms >= 1.0);
        assert_eq!(
            r.body.get("vms").unwrap().as_arr().unwrap().len(),
            n_vms as usize
        );
    }

    #[test]
    fn plan_with_inline_system_and_baselines() {
        let c = ctx();
        let line = r#"{"op":"plan","budget":20,"approach":"mp","system":{
            "apps":[{"task_sizes":[1,2,3,4]}],
            "instance_types":[{"cost_per_hour":5,"perf":[10]},
                               {"cost_per_hour":9,"perf":[5]}]}}"#;
        let r = handle(&c, line).unwrap();
        assert_eq!(r.body.get("approach").unwrap().as_str(), Some("mp"));
    }

    #[test]
    fn simulate_and_campaign() {
        let c = ctx();
        let r = handle(
            &c,
            r#"{"op":"simulate","budget":80,"noise":{"task_sigma":0.05},"seed":3}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("completed").unwrap().as_f64(), Some(750.0));
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":150,"noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
        )
        .unwrap();
        assert!(r.body.get("rounds").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn estimate_perf_roundtrip() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"estimate_perf","per_cell":8}"#).unwrap();
        // Noiseless sampling recovers Table I exactly.
        assert!(r.body.get("max_rel_error").unwrap().as_f64().unwrap() < 1e-6);
        assert_eq!(r.body.get("estimate").unwrap().as_arr().unwrap().len(), 12);
    }

    #[test]
    fn errors_are_reported() {
        let c = ctx();
        assert!(handle(&c, "not json").is_err());
        assert!(handle(&c, r#"{"op":"nope"}"#).is_err());
        assert!(handle(&c, r#"{"op":"plan"}"#).is_err()); // no budget
        assert!(handle(&c, r#"{"op":"plan","budget":10,"approach":"x"}"#).is_err());
    }

    #[test]
    fn errors_name_the_offending_op_and_policy() {
        let c = ctx();
        // Unknown policy: the error names the op, the policy and the
        // known alternatives.
        let e = handle(&c, r#"{"op":"plan","budget":10,"policy":"warp"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"plan\""), "{msg}");
        assert!(msg.contains("\"warp\""), "{msg}");
        assert!(msg.contains("budget-heuristic"), "{msg}");
        // Missing budget: the error still names the op.
        let e = handle(&c, r#"{"op":"simulate"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"simulate\""), "{msg}");
        assert!(msg.contains("budget"), "{msg}");
        // Unknown op: the error names it and lists the known ops.
        let e = handle(&c, r#"{"op":"nope"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("\"nope\""), "{msg}");
        assert!(msg.contains("list_policies"), "{msg}");
    }

    #[test]
    fn v2_errors_are_structured_bodies() {
        let c = ctx();
        // Same failure, v2: no Err — a structured error body instead.
        let r = handle(&c, r#"{"op":"plan","v":2}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            r.body.path(&["error", "code"]).unwrap().as_str(),
            Some("bad_request")
        );
        let msg = r.body.path(&["error", "message"]).unwrap().as_str().unwrap();
        assert!(msg.contains("\"plan\"") && msg.contains("budget"), "{msg}");
        // Code taxonomy: unknown policy / unknown op get their codes.
        let r = handle(&c, r#"{"op":"plan","budget":10,"policy":"warp","v":2}"#).unwrap();
        assert_eq!(
            r.body.path(&["error", "code"]).unwrap().as_str(),
            Some("unknown_policy")
        );
        let r = handle(&c, r#"{"op":"nope","v":2}"#).unwrap();
        assert_eq!(
            r.body.path(&["error", "code"]).unwrap().as_str(),
            Some("unknown_op")
        );
        // Unknown job ids report as evicted.
        let r = handle(&c, r#"{"op":"status","job_id":"j-9","v":2}"#).unwrap();
        assert_eq!(r.body.path(&["error", "code"]).unwrap().as_str(), Some("evicted"));
        // Bad version values are rejected, not treated as v1.
        let r = handle(&c, r#"{"op":"ping","v":3}"#);
        assert!(r.is_err(), "unsupported version must error");
        // v2 success bodies are byte-identical to v1.
        let v1 = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap().body.to_string();
        let v2 = handle(&c, r#"{"op":"plan","budget":80,"v":2}"#).unwrap().body.to_string();
        assert_eq!(v1, v2);
    }

    #[test]
    fn describe_is_v2_only_and_lists_every_op() {
        let c = ctx();
        let e = handle(&c, r#"{"op":"describe"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("\"v\":2"), "{e:#}");
        let r = handle(&c, r#"{"op":"describe","v":2}"#).unwrap();
        let schema = r.body.get("schema").unwrap();
        assert_eq!(schema, &api::describe_schema());
        let ops: Vec<&str> = schema
            .get("ops")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|o| o.get("op").unwrap().as_str().unwrap())
            .collect();
        for op in ["plan", "sweep", "simulate", "campaign", "submit", "describe"] {
            assert!(ops.contains(&op), "{op} missing from describe");
        }
    }

    #[test]
    fn scenarios_are_listable_and_plannable() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"list_scenarios"}"#).unwrap();
        let names: Vec<&str> = r
            .body
            .get("scenarios")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, crate::workload::scenario_names());
        // The "paper" scenario plans identically to the default system.
        let a = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap().body.to_string();
        let b = handle(&c, r#"{"op":"plan","budget":80,"scenario":"paper"}"#)
            .unwrap()
            .body
            .to_string();
        assert_eq!(a, b);
        // A generated scenario is solvable end-to-end.
        let r = handle(&c, r#"{"op":"plan","budget":500,"scenario":"heavy-tail"}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert!(r.body.get("makespan").unwrap().as_f64().unwrap() > 0.0);
        // Conflicts and unknown names are named in the error.
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"scenario":"paper","system":"paper"}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("mutually exclusive"), "{e:#}");
        let e = handle(&c, r#"{"op":"plan","budget":80,"scenario":"warp9"}"#).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown scenario") && msg.contains("heavy-tail"), "{msg}");
        // Scenario presets work on simulate and campaign too.
        let r = handle(
            &c,
            r#"{"op":"simulate","budget":400,"scenario":"uniform-small","seed":1}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":600,"scenario":"uniform-small","max_rounds":4}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn list_policies_covers_the_registry() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"list_policies"}"#).unwrap();
        let policies = r.body.get("policies").unwrap().as_arr().unwrap();
        let names: Vec<&str> = policies
            .iter()
            .map(|p| p.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, crate::scheduler::BUILTIN_POLICIES);
        for p in policies {
            assert!(!p.get("description").unwrap().as_str().unwrap().is_empty());
        }
    }

    #[test]
    fn plan_accepts_policy_field_for_every_builtin() {
        let c = ctx();
        for name in crate::scheduler::BUILTIN_POLICIES {
            let line = format!(
                r#"{{"op":"plan","budget":80,"deadline":7200,"policy":"{name}"}}"#
            );
            let r = handle(&c, &line).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)), "{name}");
            assert_eq!(r.body.get("policy").unwrap().as_str(), Some(*name));
            assert!(r.body.get("makespan").unwrap().as_f64().unwrap() > 0.0, "{name}");
        }
    }

    #[test]
    fn campaign_accepts_policy_field() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"campaign","budget":120,"policy":"mp"}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("mp"));
        assert_eq!(r.body.get("complete"), Some(&Json::Bool(true)));
        assert!(handle(&c, r#"{"op":"campaign","budget":120,"policy":"zz"}"#).is_err());
        // Policy knobs reach the per-round solver: a deadline campaign
        // must plan within the deadline, not just within the budget.
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":200,"policy":"deadline","deadline":3600}"#,
        )
        .unwrap();
        let planned = r.body.get("planned_makespan").unwrap().as_f64().unwrap();
        assert!(planned <= 3600.0 + 1e-6, "deadline ignored: {planned}");
    }

    #[test]
    fn plan_accepts_remaining_for_dynamic_re_planning() {
        let c = ctx();
        // Explicit residual set + dynamic policy: the plan covers
        // exactly those tasks.
        let r = handle(
            &c,
            r#"{"op":"plan","budget":40,"policy":"dynamic","remaining":[0,1,2,3,4,5,6,7,8,9]}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("dynamic"));
        let vms = r.body.get("vms").unwrap().as_arr().unwrap();
        let tasks: f64 = vms
            .iter()
            .map(|vm| vm.get("tasks").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(tasks, 10.0, "plan must cover exactly the residual set");
        // An orphan remaining selects the dynamic policy, like an orphan
        // deadline selects the deadline search.
        let r = handle(&c, r#"{"op":"plan","budget":40,"remaining":[0,1,2]}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("dynamic"));
        // Orphan deadline + remaining is ambiguous: refuse, don't guess.
        let e = handle(
            &c,
            r#"{"op":"plan","budget":40,"deadline":3600,"remaining":[0,1]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("explicitly"), "{e:#}");
    }

    #[test]
    fn remaining_is_rejected_where_it_would_be_ignored() {
        let c = ctx();
        // Policies that ignore the residual set must refuse it.
        for policy in ["budget-heuristic", "mi", "mp", "multistart"] {
            let line = format!(
                r#"{{"op":"plan","budget":80,"policy":"{policy}","remaining":[0,1]}}"#
            );
            let e = handle(&c, &line).unwrap_err();
            assert!(format!("{e:#}").contains("remaining"), "{policy}: {e:#}");
        }
        // Unknown / duplicate task ids are named in the error.
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamic","remaining":[99999]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("unknown task"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamic","remaining":[3,3]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("twice"), "{e:#}");
        // Campaigns manage their own residuals.
        let e = handle(
            &c,
            r#"{"op":"campaign","budget":80,"policy":"dynamic","remaining":[1]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("remaining"), "{e:#}");
    }

    #[test]
    fn sweep_threads_field_keeps_results_identical() {
        let c = ctx();
        let a = handle(&c, r#"{"op":"sweep","budgets":[60,80],"threads":1}"#).unwrap();
        let b = handle(&c, r#"{"op":"sweep","budgets":[60,80],"threads":4}"#).unwrap();
        let rows = |r: &Reply| {
            r.body
                .path(&["sweep", "rows"])
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|row| {
                    (
                        row.get("policy").unwrap().as_str().unwrap().to_string(),
                        row.get("makespan").unwrap().as_f64().unwrap().to_bits(),
                        row.get("cost").unwrap().as_f64().unwrap().to_bits(),
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(rows(&a), rows(&b));
        assert!(handle(&c, r#"{"op":"sweep","budgets":[60],"threads":"x"}"#).is_err());
    }

    #[test]
    fn campaign_replications_aggregate() {
        let c = ctx();
        let r = handle(
            &c,
            r#"{"op":"campaign","budget":150,"replications":3,"threads":2,
                "noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
        )
        .unwrap();
        assert_eq!(r.body.get("replications").unwrap().as_f64(), Some(3.0));
        let runs = r.body.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 3);
        // Every per-run row carries the flags the aggregate summarises.
        for run in runs {
            assert!(run.get("within_budget").is_some());
            assert!(run.get("complete").is_some());
        }
        let frac = r.body.get("complete_frac").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&frac));
        assert!(r.body.get("mean_wall_clock").unwrap().as_f64().unwrap() > 0.0);
        // Wire-driven fan-out is bounded: absurd knobs are rejected, not
        // executed.
        let e = handle(&c, r#"{"op":"campaign","budget":80,"replications":1000000000}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("limit"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"campaign","budget":80,"replications":2,"threads":100000}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("limit"), "{e:#}");
    }

    #[test]
    fn unknown_policy_wins_over_remaining_complaint() {
        // A typoed policy name plus `remaining` must report unknown
        // policy, not tell the client to drop `remaining`.
        let c = ctx();
        let e = handle(
            &c,
            r#"{"op":"plan","budget":80,"policy":"dynamc","remaining":[0]}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown policy"), "{msg}");
        assert!(!msg.contains("honoured"), "{msg}");
    }

    #[test]
    fn orphan_deadline_selects_the_deadline_policy() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":200,"deadline":3600}"#).unwrap();
        assert_eq!(r.body.get("policy").unwrap().as_str(), Some("deadline"));
        assert!(r.body.get("makespan").unwrap().as_f64().unwrap() <= 3600.0 + 1e-6);
    }

    #[test]
    fn submit_status_jobs_cancel_roundtrip() {
        let c = ctx();
        // Submit an async plan job and poll it to completion.
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        let id = r.body.get("job_id").unwrap().as_str().unwrap().to_string();
        let mut state = String::new();
        for _ in 0..200 {
            let s = handle(&c, &format!(r#"{{"op":"status","job_id":"{id}"}}"#)).unwrap();
            state = s.body.path(&["job", "state"]).unwrap().as_str().unwrap().to_string();
            if state == "done" || state == "failed" {
                assert_eq!(state, "done");
                let makespan = s
                    .body
                    .path(&["job", "result", "makespan"])
                    .unwrap()
                    .as_f64()
                    .unwrap();
                assert!(makespan > 0.0);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(state, "done", "job never finished");
        // Listing contains it.
        let l = handle(&c, r#"{"op":"jobs"}"#).unwrap();
        assert!(!l.body.get("jobs").unwrap().as_arr().unwrap().is_empty());
        // Cancelling a finished job is a no-op.
        let r = handle(&c, &format!(r#"{{"op":"cancel","job_id":"{id}"}}"#)).unwrap();
        assert_eq!(r.body.get("cancelled"), Some(&Json::Bool(false)));
    }

    #[test]
    fn submit_rejects_recursive_and_control_ops() {
        let c = ctx();
        for op in ["submit", "shutdown", "status", "jobs", "cancel"] {
            let line = format!(r#"{{"op":"submit","job":{{"op":"{op}"}}}}"#);
            assert!(handle(&c, &line).is_err(), "{op} must be rejected");
        }
        assert!(handle(&c, r#"{"op":"submit"}"#).is_err());
        assert!(handle(&c, r#"{"op":"status","job_id":"nope"}"#).is_err());
    }

    #[test]
    fn submitted_v2_job_failures_report_as_failed() {
        let c = ctx();
        // The inner job is v2 and invalid: its error is encoded into a
        // body, which the submit closure must surface as a job failure.
        let r = handle(
            &c,
            r#"{"op":"submit","job":{"op":"plan","v":2,"policy":"warp","budget":10}}"#,
        )
        .unwrap();
        let id = r.body.get("job_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(
            c.jobs().wait_terminal(&id, std::time::Duration::from_secs(30)),
            Some(crate::coordinator::JobState::Failed)
        );
        let err = c.jobs().error(&id).unwrap();
        assert!(err.contains("unknown policy"), "{err}");
    }

    #[test]
    fn plan_detail_roundtrips_through_config() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"plan","budget":70,"detail":true}"#).unwrap();
        let plan_json = r.body.get("plan").unwrap();
        let sys = crate::workload::paper::table1_system(0.0);
        let plan = crate::config::plan_from_json(&sys, plan_json).unwrap();
        assert!(plan.validate_partition(&sys).is_ok());
        assert_eq!(
            plan.score(&sys).makespan,
            r.body.get("makespan").unwrap().as_f64().unwrap()
        );
    }

    #[test]
    fn sweep_short() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"sweep","budgets":[60,80]}"#).unwrap();
        let rows = r.body.path(&["sweep", "rows"]).unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 6);
    }

    #[test]
    fn stats_reports_backlog_bound_and_per_shard_gauges() {
        let c = ctx();
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        let engine = r.body.get("engine").unwrap();
        let shards = engine.get("shards").unwrap().as_f64().unwrap() as usize;
        assert!(shards >= 1);
        assert!(engine.get("max_backlog").unwrap().as_f64().unwrap() >= 1.0);
        let per_shard = engine.get("shard_stats").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), shards);
        for (i, s) in per_shard.iter().enumerate() {
            assert_eq!(s.get("shard").unwrap().as_f64(), Some(i as f64));
            assert_eq!(s.get("depth").unwrap().as_f64(), Some(0.0));
            assert!(s.get("high_water").is_some());
            assert_eq!(s.get("rejected").unwrap().as_f64(), Some(0.0));
        }
        assert_eq!(r.body.path(&["stats", "jobs_rejected"]).unwrap().as_f64(), Some(0.0));
        assert!(r.body.path(&["stats", "queue_wait_us_p50"]).is_some());
    }

    #[test]
    fn submit_validates_priority_and_deadline_fields() {
        let c = ctx();
        let e = handle(
            &c,
            r#"{"op":"submit","priority":12,"job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("0..=9"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"submit","priority":"high","job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("priority"), "{e:#}");
        let e = handle(
            &c,
            r#"{"op":"submit","deadline_ms":"soon","job":{"op":"plan","budget":80}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("deadline_ms"), "{e:#}");
        // A valid placement is accepted and echoed through status, along
        // with the job's recorded queue wait.
        let r = handle(
            &c,
            r#"{"op":"submit","priority":4,"deadline_ms":60000,"job":{"op":"plan","budget":80}}"#,
        )
        .unwrap();
        let id = r.body.get("job_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(
            c.jobs().wait_terminal(&id, std::time::Duration::from_secs(60)),
            Some(crate::coordinator::JobState::Done)
        );
        let job = c.jobs().status(&id).unwrap();
        assert_eq!(job.get("priority").unwrap().as_f64(), Some(4.0));
        assert_eq!(job.get("deadline_ms").unwrap().as_f64(), Some(60000.0));
        assert!(job.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn persist_is_v2_only_and_reports_disabled_stores() {
        let c = ctx();
        // v1 request: versioned-op gate, same wording as describe's.
        let e = handle(&c, r#"{"op":"persist"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("\"v\":2"), "{e:#}");
        // No journal, no cache configured: both stores report disabled.
        let r = handle(&c, r#"{"op":"persist","v":2}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            r.body.path(&["persist", "journal", "enabled"]),
            Some(&Json::Bool(false))
        );
        assert_eq!(
            r.body.path(&["persist", "cache", "enabled"]),
            Some(&Json::Bool(false))
        );
        // Compaction without a journal is a client error, not a panic.
        let r = handle(&c, r#"{"op":"persist","action":"compact","v":2}"#).unwrap();
        assert_eq!(
            r.body.path(&["error", "code"]).unwrap().as_str(),
            Some("bad_request")
        );
        let msg = r.body.path(&["error", "message"]).unwrap().as_str().unwrap();
        assert!(msg.contains("--journal"), "{msg}");
        // Unknown actions are named in the error.
        let r = handle(&c, r#"{"op":"persist","action":"wipe","v":2}"#).unwrap();
        let msg = r.body.path(&["error", "message"]).unwrap().as_str().unwrap();
        assert!(msg.contains("\"wipe\"") && msg.contains("compact"), "{msg}");
    }

    #[test]
    fn health_is_v2_only_and_reports_subsystems() {
        let c = ctx();
        let e = handle(&c, r#"{"op":"health"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("\"v\":2"), "{e:#}");
        let r = handle(&c, r#"{"op":"health","v":2}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
        let h = r.body.get("health").unwrap();
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(h.path(&["journal", "enabled"]), Some(&Json::Bool(false)));
        assert_eq!(h.path(&["cache", "enabled"]), Some(&Json::Bool(false)));
        assert!(h.path(&["engine", "shards"]).unwrap().as_f64().unwrap() >= 1.0);
        assert!(h.get("uptime_ms").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn chaos_is_gated_and_drives_the_registry() {
        let c = ctx();
        // The v2 gate first, then the --chaos-allowed gate.
        let e = handle(&c, r#"{"op":"chaos"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("\"v\":2"), "{e:#}");
        let r = handle(&c, r#"{"op":"chaos","v":2}"#).unwrap();
        let msg = r.body.path(&["error", "message"]).unwrap().as_str().unwrap();
        assert!(msg.contains("--chaos-allowed"), "{msg}");
        // Opted in: arm → list → disarm, against a test-unique point
        // name at probability 0 (the registry is process-global and lib
        // tests run in parallel — this point must never actually fire).
        let mut c = ctx();
        c.chaos_allowed = true;
        let named = |r: &Reply, name: &str| {
            r.body
                .path(&["chaos", "points"])
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .any(|p| p.get("name").unwrap().as_str() == Some(name))
        };
        let r = handle(
            &c,
            r#"{"op":"chaos","action":"arm","spec":"fp.proto.chaos=error@0.0x3","v":2}"#,
        )
        .unwrap();
        assert!(named(&r, "fp.proto.chaos"), "{}", r.body);
        assert_eq!(r.body.path(&["chaos", "armed"]), Some(&Json::Bool(true)));
        let r = handle(&c, r#"{"op":"chaos","v":2}"#).unwrap();
        assert!(named(&r, "fp.proto.chaos"), "list shows armed points");
        let r = handle(
            &c,
            r#"{"op":"chaos","action":"disarm","point":"fp.proto.chaos","v":2}"#,
        )
        .unwrap();
        assert!(!named(&r, "fp.proto.chaos"), "{}", r.body);
        // Malformed specs come back as bad_request naming the problem.
        let r = handle(
            &c,
            r#"{"op":"chaos","action":"arm","spec":"fp.proto.chaos=warp","v":2}"#,
        )
        .unwrap();
        assert_eq!(
            r.body.path(&["error", "code"]).unwrap().as_str(),
            Some("bad_request")
        );
    }

    #[test]
    fn plan_cache_hit_returns_identical_bytes_and_counts() {
        let mut c = ctx();
        c.cache = Some(Arc::new(crate::persist::SolveCache::new(8)));
        let stat = |c: &Context, key: &str| {
            handle(c, r#"{"op":"stats"}"#)
                .unwrap()
                .body
                .path(&["stats", key])
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let a = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap().body.to_string();
        assert_eq!(stat(&c, "cache_misses"), 1.0);
        assert_eq!(stat(&c, "cache_inserts"), 1.0);
        // The identical request is served from the cache, byte-for-byte.
        let b = handle(&c, r#"{"op":"plan","budget":80}"#).unwrap().body.to_string();
        assert_eq!(a, b);
        assert_eq!(stat(&c, "cache_hits"), 1.0);
        // A different budget is a different key.
        handle(&c, r#"{"op":"plan","budget":90}"#).unwrap();
        assert_eq!(stat(&c, "cache_misses"), 2.0);
        // Response-only knobs don't fragment the key: `detail` hits the
        // cached outcome and still gets its plan payload, and `threads`
        // hits too.
        let r = handle(&c, r#"{"op":"plan","budget":80,"detail":true}"#).unwrap();
        assert!(r.body.get("plan").is_some());
        handle(&c, r#"{"op":"plan","budget":80,"threads":2}"#).unwrap();
        assert_eq!(stat(&c, "cache_hits"), 3.0);
        assert_eq!(stat(&c, "cache_evictions"), 0.0);
    }

    #[test]
    fn saturated_shard_rejects_with_structured_busy() {
        use std::time::Duration;
        let metrics = Arc::new(Metrics::new());
        // One shard, backlog bound of one: trivially saturated.
        let engine = Arc::new(JobEngine::with_backlog(1, 1, Arc::clone(&metrics)));
        let c = Context::with_engine(Arc::new(NativeEvaluator), metrics, Arc::clone(&engine));
        // Occupy the worker, then fill the single queue slot.
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let blocker = engine.submit(
            "block",
            Box::new(move |_| {
                started_tx.send(()).unwrap();
                go_rx.recv().unwrap();
                Ok(Json::Null)
            }),
        );
        started_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let filler = engine.submit("fill", Box::new(|_| Ok(Json::Null)));
        // Async submit is rejected with the exact legacy v1 shape, not
        // an opaque error string and not a hang.
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        assert_eq!(
            r.body.to_string(),
            r#"{"backlog":1,"error":"busy","ok":false,"shard":0}"#
        );
        // The same rejection under v2 is a structured error carrying
        // the queue-wait-derived retry hint.
        let r = handle(&c, r#"{"op":"submit","v":2,"job":{"op":"plan","budget":80}}"#).unwrap();
        assert_eq!(r.body.path(&["error", "code"]).unwrap().as_str(), Some("busy"));
        assert_eq!(r.body.path(&["error", "detail", "shard"]).unwrap().as_f64(), Some(0.0));
        assert_eq!(r.body.path(&["error", "detail", "backlog"]).unwrap().as_f64(), Some(1.0));
        assert!(
            r.body.path(&["error", "detail", "retry_after_ms"]).unwrap().as_u64().unwrap() >= 1
        );
        // Synchronous heavy ops get the same rejection.
        let r = handle(&c, r#"{"op":"sweep","budgets":[60]}"#).unwrap();
        assert_eq!(r.body.get("error").unwrap().as_str(), Some("busy"));
        let r = handle(&c, r#"{"op":"campaign","budget":120}"#).unwrap();
        assert_eq!(r.body.get("error").unwrap().as_str(), Some("busy"));
        let r = handle(&c, r#"{"op":"sweep","budgets":[60],"v":2}"#).unwrap();
        assert_eq!(r.body.path(&["error", "code"]).unwrap().as_str(), Some("busy"));
        // The rejections are visible in stats.
        let r = handle(&c, r#"{"op":"stats"}"#).unwrap();
        assert!(r.body.path(&["stats", "jobs_rejected"]).unwrap().as_f64().unwrap() >= 3.0);
        let shard0 = &r.body.path(&["engine", "shard_stats"]).unwrap().as_arr().unwrap()[0];
        assert!(shard0.get("rejected").unwrap().as_f64().unwrap() >= 3.0);
        assert_eq!(shard0.get("high_water").unwrap().as_f64(), Some(1.0));
        // Drain: the saturated server recovers without restarts.
        go_tx.send(()).unwrap();
        for id in [&blocker, &filler] {
            assert_eq!(
                c.jobs().wait_terminal(id, Duration::from_secs(10)),
                Some(crate::coordinator::JobState::Done)
            );
        }
        let r = handle(&c, r#"{"op":"submit","job":{"op":"plan","budget":80}}"#).unwrap();
        assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
    }
}
