//! The long-running coordinator (leader) process: an
//! **admission-controlled serving core**.
//!
//! ## Connection layer (non-blocking, fixed threads)
//!
//! The TCP server speaks line-delimited JSON through a *fixed* pool of
//! readiness-driven connection workers (`--conn-workers`, default one
//! per core capped at 4) built on a dependency-free `poll(2)` wrapper
//! ([`crate::util::netpoll`]).  Each worker owns its connections'
//! non-blocking sockets and per-connection line buffers; a small
//! request-executor pool (2× the workers) runs the protocol handlers,
//! so a slow request parks an executor, never a connection worker.
//! Thousands of idle clients cost a poll slot each — **zero threads** —
//! and `shutdown` completes even with idle connections still open.  At
//! most one request per connection executes at a time, so pipelined
//! lines keep the one-JSON-line-per-request framing and response order.
//!
//! ## Queue layer (bounded, priority/deadline-aware)
//!
//! Job execution is unified behind one sharded [`JobEngine`]: a bounded
//! worker pool (`--shards`, default one per core capped at 8) onto
//! which job ids hash, with work stealing across shards.  Shard queues
//! are **bounded priority queues**:
//!
//! * Every engine-bound request (`submit`, and synchronous
//!   `campaign`/`sweep`) may carry `"priority"` (0..=9, default 0,
//!   9 = most urgent) and `"deadline_ms"` (relative to submission).
//!   Queues pop in (priority, earliest-deadline, FIFO) order; requests
//!   with neither field get exactly the legacy FIFO behaviour.
//! * Each shard's backlog is bounded (`--max-backlog`, default 256).
//!   A submit that finds its shard full is **rejected** with the
//!   structured response `{"ok":false,"error":"busy","shard":S,
//!   "backlog":N}` instead of queuing unboundedly — synchronous
//!   campaign/sweep callers get the same `busy` reply.
//!
//! (Single-request `plan`/`simulate` ops still solve inline on their
//! executor — they are the latency-sensitive request path; their
//! `threads` knob is wire-bounded at 256 per request.)  All
//! candidate-plan scoring funnels through one shared evaluator — the
//! PJRT/XLA artifact when built, with a [`BatchingEvaluator`] in front
//! of it that coalesces scoring requests from concurrent planner
//! threads into single padded XLA executions.
//!
//! ## Observability
//!
//! `stats` reports request metrics (now including `jobs_rejected` and
//! queue-wait percentiles) plus per-shard `depth` / `high_water` /
//! `rejected` gauges and the configured `max_backlog`; `status` reports
//! each job's `queue_wait_ms` (time from admission to worker pickup)
//! and echoes non-default `priority`/`deadline_ms`.
//!
//! Jobs are **cancellable mid-flight**: `cancel` fires the job's
//! [`CancelToken`](crate::util::CancelToken), and the running work stops
//! cooperatively at its next checkpoint — a campaign replication/round
//! boundary, a sweep cell, a FIND iteration, a bisection probe.  Long
//! jobs publish **progress** (`done/total` replications or sweep cells)
//! and **streaming partial results** (finished replication/round/cell
//! rows), pollable via `status` while the job is still running.
//!
//! Python never runs here; the request path is rust + the AOT artifact.
//!
//! ## Durability (`--journal`, `--cache-capacity`)
//!
//! With `--journal <path>` the coordinator writes an append-only,
//! checksummed job journal ([`crate::persist::Journal`]) and **survives
//! crashes**:
//!
//! * An async submit is fsynced to the journal *before* its job id is
//!   returned (durability before visibility), and every terminal result
//!   (`done`/`failed`) is fsynced when it lands.  `start` and `cancel`
//!   records ride the OS buffer — losing one costs a re-run or a
//!   re-cancel, never a wrong answer.
//! * On restart the journal replays: jobs that finished before the
//!   crash are servable from `status` with their original result bytes;
//!   jobs that were accepted but unfinished **re-enqueue under their
//!   original ids** and run again.  Queue deadlines restart from
//!   recovery time.
//! * The journal compacts automatically (rewrite-and-swap) once
//!   obsolete records dominate; `{"op":"persist","action":"compact",
//!   "v":2}` forces a pass.  A torn tail from a mid-write crash is
//!   detected by length/checksum framing and truncated on open.
//! * Journal I/O failures *after* open degrade to lost durability, not
//!   lost availability: the op still executes, with a warning on
//!   stderr.
//!
//! Synchronous heavy ops (`campaign`/`sweep` without `submit`) are
//! never journaled — their caller's connection dies with the crash, so
//! there is nobody to deliver a recovered result to.
//!
//! ## Robustness (deadlines, degradation, chaos)
//!
//! Operational behaviour under failure is documented in depth in
//! `docs/OPERATIONS.md`; the short version:
//!
//! * **Deadlines are binding.**  A job whose `deadline_ms` expires
//!   while queued is shed at pop time with the structured v2 error
//!   `deadline_exceeded`; a running job has its cancel token fired by
//!   the engine supervisor, and synchronous `campaign`/`sweep` waits
//!   are bounded by the same deadline.  Requests without `deadline_ms`
//!   are untouched — their replies stay byte-identical.
//! * **Journal failures degrade, never crash.**  A write error flips
//!   the journal into a visible *degraded* (memory-only) mode — `stats`
//!   gains `journal_degraded:true`, `health` reports
//!   `status:"degraded"` — and a background prober periodically
//!   attempts to reattach, rolling the file back to the last intact
//!   record boundary first.
//! * **Stuck workers are respawned.**  With `--watchdog-stuck-ms` the
//!   engine supervisor condemns a worker pinned on one job past the
//!   bound, fires that job's cancel token, and spawns a replacement
//!   (`watchdog_respawns` on `stats`/`health`).
//! * **`health` (v2)** reports overall `ok`/`degraded` plus
//!   per-subsystem detail (journal attachment, cache, shard liveness,
//!   uptime); [`client::Client::health`] is the typed view.
//! * **Fault injection is built in.**  `--chaos
//!   "point=action[@prob][xN];…"` arms named failpoints
//!   ([`crate::util::failpoint`]) across the journal, cache, engine and
//!   connection layers; the v2 `chaos` op (gated behind
//!   `--chaos-allowed`) lists/arms/disarms them over the wire.  With
//!   nothing armed the instrumentation is a single relaxed atomic load.
//! * **Clients retry transiently.**  [`client::RetryPolicy`] gives
//!   every typed client op jittered exponential backoff on `busy` (and
//!   transport errors for idempotent ops); the default stays fail-fast.
//!
//! With `--cache-capacity N` repeated identical `plan` requests are
//! answered from a bounded LRU solve cache
//! ([`crate::persist::SolveCache`]) keyed by a canonical,
//! version-stamped encoding of the request (presentation knobs like
//! `detail`/`threads` excluded).  Cache traffic shows up in `stats`
//! (`cache_hits` / `cache_misses` / `cache_inserts` /
//! `cache_evictions`), and `{"op":"persist","v":2}` reports both
//! stores' state.
//!
//! ## The typed, versioned wire API
//!
//! The protocol's single source of truth is [`api`]: a typed
//! [`api::Request`] / [`api::Response`] pair per op, a structured
//! [`api::ApiError`] taxonomy (`bad_request`, `unknown_policy`,
//! `unknown_op`, `busy`, `cancelled`, `evicted`, `internal`,
//! `deadline_exceeded`), and
//! encode/decode through [`crate::util::Json`].  [`protocol::handle`]
//! is a thin `decode → dispatch(typed) → encode` pipeline over it, and
//! [`client::Client`] is the first-class blocking Rust client (typed
//! methods per op, pipelining via `send`/`recv`, typed
//! [`api::BusyInfo`] rejections with retry helpers).
//!
//! Requests may carry `"v"`: **absent/1** keeps v1 semantics — reply
//! shapes byte-identical to the historical protocol, string errors, the
//! legacy `busy` shape; **2** switches failures to structured
//! `{"ok":false,"error":{"code":…,"message":…,"detail":…?}}` bodies
//! (`busy` gains a `retry_after_ms` hint from the queue-wait p50
//! reservoir) and unlocks `describe`, which returns the machine-readable
//! op/field schema ([`api::describe_schema`]) pinned by the drift tests.
//! Success bodies are identical in both versions.
//!
//! Planning ops resolve their `"policy"` through the shared
//! [`crate::scheduler::PolicyRegistry`] (`"approach"` is the accepted
//! legacy spelling) — `list_policies` enumerates them — and may name a
//! workload preset via `"scenario"` instead of inlining a `"system"`
//! object (`list_scenarios` enumerates those).
//!
//! Protocol sketch (one JSON object per line; `{"op":"describe","v":2}`
//! returns the complete field-level schema):
//!
//! ```text
//! {"op":"ping"}
//! {"op":"list_policies"} / {"op":"list_scenarios"}
//! {"op":"plan","budget":80,"scenario":"heavy-tail","policy":"multistart","n_starts":8}
//! {"op":"plan","budget":150,"policy":"deadline","deadline":3600,"threads":4}
//! {"op":"sweep","budgets":[40,45],"system":"paper"}
//! {"op":"simulate","budget":80,"noise":{"task_sigma":0.1},"seed":7}
//! {"op":"campaign","budget":120,"policy":"mi","noise":{"mean_lifetime":2500}}
//! {"op":"estimate_perf","per_cell":20,"noise":{"task_sigma":0.05}}
//! {"op":"plan","budget":80,"detail":true}        # full task-level plan
//!
//! # async jobs on the sharded engine (priority/deadline ride on the
//! # outer submit object; "deadline_ms" is the *queue* deadline, not
//! # the planning-deadline knob "deadline"):
//! {"op":"submit","priority":9,"deadline_ms":5000,
//!  "job":{"op":"campaign","budget":150,"replications":64}}
//!   -> {"ok":true,"job_id":"j-0"}
//!    | {"ok":false,"error":"busy","shard":3,"backlog":256}          # v1
//!    | {"ok":false,"error":{"code":"busy","message":…,              # v2
//!        "detail":{"shard":3,"backlog":256,"retry_after_ms":40}}}
//! {"op":"status","job_id":"j-0","partials_from":17}
//!   # streaming cursor: only partial rows >= 17 (pass the previous
//!   # reply's "partials_next"), so pollers receive each row once
//! {"op":"jobs"}          # all jobs with state + progress
//! {"op":"cancel","job_id":"j-0"}   # fires the job's cancel token
//!
//! {"op":"stats"}         # metrics + engine gauges: per-shard depth /
//!                        # high_water / rejected, max_backlog,
//!                        # jobs_rejected, queue-wait percentiles
//! {"op":"describe","v":2}          # machine-readable op/field schema
//! {"op":"persist","v":2}           # journal + solve-cache stats
//! {"op":"persist","action":"compact","v":2}   # force journal compaction
//! {"op":"health","v":2}            # ok/degraded + per-subsystem detail
//! {"op":"chaos","v":2}             # list armed failpoints (--chaos-allowed)
//! {"op":"chaos","action":"arm","spec":"journal.fsync=error@0.2","v":2}
//! {"op":"chaos","action":"disarm","v":2}
//! {"op":"shutdown"}
//! ```

pub mod api;
pub mod batcher;
pub mod client;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod state;

pub use api::{ApiError, BusyInfo, ErrorCode, Request, Response};
pub use batcher::BatchingEvaluator;
pub use client::{
    Client, ClientError, ClientOptions, HealthReport, JobStatus, RetryPolicy, RetryStats,
};
pub use engine::{Busy, JobCtl, JobEngine, JobError, JobPriority};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig};
pub use state::{JobRegistry, JobState};
