//! The long-running coordinator (leader) process.
//!
//! A thread-per-connection TCP server speaking line-delimited JSON, with
//! job execution unified behind one sharded [`JobEngine`]: a bounded
//! worker pool (`--shards`, default one per core) onto which job ids
//! hash, with FIFO order per shard and work stealing across shards.
//! `submit` enqueues any request as an asynchronous job; synchronous
//! `campaign`/`sweep` calls run on the *same* pool (the connection just
//! waits for its own job), so the pool bounds all campaign/sweep
//! concurrency.  (Single-request `plan`/`simulate` ops still solve
//! inline on their connection thread — they are the latency-sensitive
//! request path; their `threads` knob is wire-bounded at 256 per
//! request.)  All candidate-plan scoring
//! funnels through one shared evaluator — the PJRT/XLA artifact when
//! built, with a [`BatchingEvaluator`] in front of it that coalesces
//! scoring requests from concurrent planner threads into single padded
//! XLA executions.
//!
//! Jobs are **cancellable mid-flight**: `cancel` fires the job's
//! [`CancelToken`](crate::util::CancelToken), and the running work stops
//! cooperatively at its next checkpoint — a campaign replication/round
//! boundary, a sweep cell, a FIND iteration, a bisection probe.  Long
//! jobs publish **progress** (`done/total` replications or sweep cells)
//! and **streaming partial results** (finished replication/round/cell
//! rows), pollable via `status` while the job is still running.
//!
//! Python never runs here; the request path is rust + the AOT artifact.
//!
//! Protocol (one JSON object per line, response mirrors `"op"`):
//!
//! Planning ops resolve their `"policy"` through the shared
//! [`crate::scheduler::PolicyRegistry`] (`"approach"` is the accepted
//! legacy spelling), so every registered policy — budget heuristic,
//! baselines, multistart, deadline, dynamic, non-clairvoyant — is
//! reachable over the wire; `list_policies` enumerates them.
//!
//! ```text
//! {"op":"ping"}
//! {"op":"list_policies"}
//! {"op":"plan","budget":80,"system":"paper","policy":"budget-heuristic"}
//! {"op":"plan","budget":150,"policy":"deadline","deadline":3600,"threads":4}
//! {"op":"plan","budget":80,"policy":"multistart","n_starts":8,"seed":7}
//! {"op":"sweep","budgets":[40,45],"system":"paper"}
//! {"op":"simulate","budget":80,"system":"paper","noise":{"task_sigma":0.1},"seed":7}
//! {"op":"campaign","budget":120,"policy":"mi","noise":{"mean_lifetime":2500}}
//! {"op":"estimate_perf","system":"paper","per_cell":20,"noise":{"task_sigma":0.05}}
//! {"op":"plan","budget":80,"detail":true}        # full task-level plan
//!
//! # async jobs on the sharded engine:
//! {"op":"submit","job":{"op":"campaign","budget":150,"replications":64}}
//!   -> {"ok":true,"job_id":"j-0"}
//! {"op":"status","job_id":"j-0"}
//!   -> {"ok":true,"job":{"id":"j-0","op":"campaign","state":"running",
//!                        "progress":{"done":17,"total":64},
//!                        "partial_results":[{"wall_clock":...,"spent":...},...],
//!                        "partials_next":17}}
//! {"op":"status","job_id":"j-0","partials_from":17}
//!   # streaming cursor: only partial rows >= 17 (pass the previous
//!   # reply's "partials_next"), so pollers receive each row once
//! {"op":"jobs"}          # all jobs with state + progress
//! {"op":"cancel","job_id":"j-0"}   # fires the job's cancel token:
//!                                  # running work stops at the next
//!                                  # replication/cell/iteration boundary
//!
//! {"op":"stats"}         # metrics + engine shard/queue gauges
//! {"op":"shutdown"}
//! ```

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod state;

pub use batcher::BatchingEvaluator;
pub use engine::{JobCtl, JobEngine};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig};
pub use state::{JobRegistry, JobState};
