//! The long-running coordinator (leader) process.
//!
//! A thread-per-connection TCP server speaking line-delimited JSON.
//! Clients submit planning, simulation, campaign and estimation requests;
//! all candidate-plan scoring funnels through one shared evaluator —
//! the PJRT/XLA artifact when built, with a [`BatchingEvaluator`] in
//! front of it that coalesces scoring requests from concurrent planner
//! threads into single padded XLA executions (the serving-system pattern
//! of dynamic batching, applied to plan scoring).
//!
//! Python never runs here; the request path is rust + the AOT artifact.
//!
//! Protocol (one JSON object per line, response mirrors `"op"`):
//!
//! Planning ops resolve their `"policy"` through the shared
//! [`crate::scheduler::PolicyRegistry`] (`"approach"` is the accepted
//! legacy spelling), so every registered policy — budget heuristic,
//! baselines, multistart, deadline, dynamic, non-clairvoyant — is
//! reachable over the wire; `list_policies` enumerates them.
//!
//! ```text
//! {"op":"ping"}
//! {"op":"list_policies"}
//! {"op":"plan","budget":80,"system":"paper","policy":"budget-heuristic"}
//! {"op":"plan","budget":150,"policy":"deadline","deadline":3600}
//! {"op":"plan","budget":80,"policy":"multistart","n_starts":8,"seed":7}
//! {"op":"sweep","budgets":[40,45],"system":"paper"}
//! {"op":"simulate","budget":80,"system":"paper","noise":{"task_sigma":0.1},"seed":7}
//! {"op":"campaign","budget":120,"policy":"mi","noise":{"mean_lifetime":2500}}
//! {"op":"estimate_perf","system":"paper","per_cell":20,"noise":{"task_sigma":0.05}}
//! {"op":"plan","budget":80,"detail":true}        # full task-level plan
//! {"op":"submit","job":{"op":"campaign",...}}    # async: returns job_id
//! {"op":"status","job_id":"j-0"}
//! {"op":"jobs"}
//! {"op":"cancel","job_id":"j-0"}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! ```

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod state;

pub use batcher::BatchingEvaluator;
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig};
pub use state::{JobRegistry, JobState};
