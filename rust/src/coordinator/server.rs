//! The TCP transport: a readiness-driven, admission-controlled line
//! server around [`protocol::handle`].
//!
//! Threads are fixed at startup and independent of the connection count:
//!
//! * **1 accept thread** — polls the listener, hands each accepted
//!   socket to a connection worker round-robin.
//! * **`--conn-workers` connection workers** (default: one per core,
//!   capped at 4) — each owns a set of *non-blocking* sockets plus a
//!   [`netpoll`](crate::util::netpoll) poller and a self-pipe waker.  A
//!   worker buffers reads per connection, splits newline-delimited JSON
//!   requests, and queues at most **one in-flight request per
//!   connection** (pipelined lines wait their turn, so responses keep
//!   the one-JSON-line-per-request framing and ordering).  Thousands of
//!   idle clients therefore cost a poll slot each — zero threads.
//! * **a small request-executor pool** (2× the connection workers,
//!   clamped to [2, 32]) — runs [`protocol::handle`] for dispatched
//!   lines.  Synchronous heavy ops (`campaign`/`sweep`) park *here*
//!   while they wait on the job engine, never on a connection worker, so
//!   slow requests cannot stall unrelated connections' I/O.
//! * **the [`JobEngine`] shards** (`--shards`) — execute every job under
//!   per-shard admission control (`--max-backlog`): a full shard rejects
//!   with the structured `busy` response instead of queuing unboundedly.
//!
//! A `{"op":"shutdown"}` request stops the whole stack — and unlike the
//! old thread-per-connection server, shutdown completes even while idle
//! connections are still open (workers flush pending responses
//! best-effort and drop their sockets).

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::eval::{NativeEvaluator, PlanEvaluator};
use crate::util::{netpoll, Json};

use super::engine::JobEngine;
use super::protocol::{self, Context};
use super::{BatchingEvaluator, Metrics};

/// Server settings.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Listen address, e.g. "127.0.0.1:7077" (port 0 = ephemeral).
    pub addr: String,
    /// Use the XLA artifact evaluator when available.
    pub use_xla: bool,
    /// Wrap the evaluator in the dynamic batcher.
    pub batching: bool,
    /// Batcher linger time.
    pub batch_wait: Duration,
    /// Worker shards of the job engine.  `0` = auto: one per available
    /// core, capped at 8 (job execution itself fans out over
    /// `util::parallel`, so more shards mostly add idle threads).
    /// Explicit values are clamped at 256.  Every campaign/sweep —
    /// synchronous or submitted — runs on this pool; at most `shards`
    /// of them execute at once.
    pub shards: usize,
    /// Readiness-driven connection workers (`--conn-workers`).  `0` =
    /// auto: one per available core, capped at 4; explicit values are
    /// clamped at 64.  The connection count is independent of this —
    /// idle clients cost a poll slot, not a thread.
    pub conn_workers: usize,
    /// Per-shard job-queue bound (`--max-backlog`).  `0` = the default
    /// (256).  Submits beyond the bound are rejected with the
    /// structured `{"error":"busy",...}` response.
    pub max_backlog: usize,
    /// Durable job journal path (`--journal`).  `None` disables
    /// persistence; with a path, accepted submits and terminal results
    /// survive a crash and are replayed on the next start.
    pub journal: Option<std::path::PathBuf>,
    /// Solve-cache capacity in entries (`--cache-capacity`).  `0`
    /// disables the cache; otherwise repeated identical `plan`
    /// requests are answered from the LRU cache without re-solving.
    pub cache_capacity: usize,
    /// Evict connections idle longer than this
    /// (`--conn-idle-timeout`).  `None` keeps the historical behaviour:
    /// idle connections live until the client closes them.  A
    /// connection with a request in flight or unflushed response bytes
    /// is never evicted.
    pub conn_idle_timeout: Option<Duration>,
    /// Allow the v2 `chaos` op to drive the failpoint registry.
    pub chaos_allowed: bool,
    /// Failpoint spec armed at startup (`--chaos`; see
    /// [`crate::util::failpoint`] for the grammar).  Armed before the
    /// journal opens, so even replay-time points can fire.
    pub chaos_spec: Option<String>,
    /// Engine watchdog threshold (`--watchdog-stuck-ms`): a worker
    /// holding one job longer than this is condemned and replaced, and
    /// the job is aborted.  `None` disables the watchdog (the default —
    /// a legitimate hours-long campaign must never be shot by default).
    pub watchdog_stuck: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7077".into(),
            use_xla: true,
            batching: true,
            batch_wait: Duration::from_millis(2),
            shards: 0,
            conn_workers: 0,
            max_backlog: 0,
            journal: None,
            cache_capacity: 0,
            conn_idle_timeout: None,
            chaos_allowed: false,
            chaos_spec: None,
            watchdog_stuck: None,
        }
    }
}

/// Resolve a connection-worker request: `0` = auto (one per available
/// core, capped at 4 — the workers only shuffle bytes; request execution
/// lives in the executor pool).  Explicit requests are clamped to
/// `[1, 64]`.
pub fn resolve_conn_workers(requested: usize) -> usize {
    const MAX_CONN_WORKERS: usize = 64;
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(1, 4)
    } else {
        requested.clamp(1, MAX_CONN_WORKERS)
    }
}

/// Size of the request-executor pool for a given connection-worker
/// count: 2× the workers, clamped to `[2, 32]`.  Executors are where
/// synchronous heavy ops park while waiting on the job engine.
pub fn request_executors(conn_workers: usize) -> usize {
    (conn_workers * 2).clamp(2, 32)
}

/// How long a connection worker sleeps in `poll` with nothing to do.
/// Wakeups (new connections, finished requests, shutdown) arrive via the
/// self-pipe waker; the timeout is only a safety net.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);

/// Requests a single connection may have parsed-but-unexecuted before
/// the worker stops reading from its socket (TCP backpressure on
/// pipelining abusers; normal clients send one line per response).
const PENDING_MAX: usize = 64;

/// A request line larger than this kills the connection (the old
/// BufReader server would buffer it without bound).
const MAX_LINE: usize = 4 << 20;

/// Socket reads drained per connection per poll tick (fairness between
/// connections sharing a worker; level-triggered polling re-reports).
const MAX_READS_PER_TICK: usize = 64;

/// A running coordinator.
pub struct Coordinator {
    pub local_addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build the evaluator stack per config and start listening.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let started = Instant::now();
        // Arm startup chaos before anything else touches an instrumented
        // path — the journal open/replay below must already see armed
        // failpoints.
        if let Some(spec) = &config.chaos_spec {
            crate::util::failpoint::arm(spec)
                .map_err(|e| anyhow::anyhow!("--chaos {spec:?}: {e}"))?;
            eprintln!("coordinator: chaos armed: {spec}");
        }
        let metrics = Arc::new(Metrics::new());

        let base: Arc<dyn PlanEvaluator> = if config.use_xla {
            match crate::runtime::XlaEvaluator::load() {
                Ok(x) => Arc::new(x),
                Err(e) => {
                    eprintln!("coordinator: XLA artifacts unavailable ({e:#}); using native evaluator");
                    Arc::new(NativeEvaluator)
                }
            }
        } else {
            Arc::new(NativeEvaluator)
        };
        let chunk = crate::runtime::ArtifactMeta::load().map(|m| m.k).unwrap_or(64);
        let evaluator: Arc<dyn PlanEvaluator> = if config.batching {
            Arc::new(BatchingEvaluator::new(base, chunk, config.batch_wait, Arc::clone(&metrics)))
        } else {
            base
        };

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        // One job engine + one policy registry for the whole server:
        // every campaign/sweep/submit executes on the sharded pool, and
        // job ids are visible across connections (submit on one socket,
        // poll/cancel on another).
        let engine = Arc::new(JobEngine::with_backlog(
            config.shards,
            config.max_backlog,
            Arc::clone(&metrics),
        ));
        engine.set_watchdog(config.watchdog_stuck);
        let policies = Arc::new(crate::scheduler::PolicyRegistry::builtin());
        let cache = (config.cache_capacity > 0)
            .then(|| Arc::new(crate::persist::SolveCache::new(config.cache_capacity)));
        // Open the journal (attaching it to the registry so every later
        // accept/transition writes through) and replay what survived the
        // last run — all before the transport threads start, so no
        // client can observe a half-recovered registry.
        let journal = match &config.journal {
            Some(path) => {
                let (j, recovered) = crate::persist::Journal::open(path)
                    .with_context(|| format!("opening journal {}", path.display()))?;
                let j = Arc::new(j);
                engine.registry().attach_journal(Arc::clone(&j));
                if !recovered.is_empty() {
                    eprintln!(
                        "coordinator: journal {} replaying {} job(s)",
                        path.display(),
                        recovered.len()
                    );
                    let ctx = Context {
                        evaluator: Arc::clone(&evaluator),
                        metrics: Arc::clone(&metrics),
                        engine: Arc::clone(&engine),
                        registry: Arc::clone(&policies),
                        job: None,
                        cache: cache.clone(),
                        journal: Some(Arc::clone(&j)),
                        chaos_allowed: config.chaos_allowed,
                        started,
                    };
                    protocol::replay_journal(&ctx, recovered);
                }
                Some(j)
            }
            None => None,
        };
        let n_workers = resolve_conn_workers(config.conn_workers);
        let workers: Vec<Arc<WorkerShared>> = (0..n_workers)
            .map(|_| {
                Ok(Arc::new(WorkerShared {
                    waker: netpoll::Waker::new().context("creating connection-worker waker")?,
                    inbox: Mutex::new(Inbox::default()),
                }))
            })
            .collect::<Result<_>>()?;
        let core = Arc::new(ServerCore {
            stop: Arc::clone(&stop),
            workers,
            exec: Arc::new(ExecShared {
                queue: Mutex::new(VecDeque::new()),
                ready: Condvar::new(),
            }),
            evaluator,
            metrics: Arc::clone(&metrics),
            engine,
            policies,
            cache,
            journal,
            chaos_allowed: config.chaos_allowed,
            started,
            idle_timeout: config.conn_idle_timeout,
        });

        let conn_handles: Vec<_> = (0..n_workers)
            .map(|i| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("conn-worker-{i}"))
                    .spawn(move || conn_worker_loop(i, &core))
                    .expect("spawning connection worker")
            })
            .collect();
        let mut exec_handles: Vec<_> = (0..request_executors(n_workers))
            .map(|i| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("req-exec-{i}"))
                    .spawn(move || exec_loop(&core))
                    .expect("spawning request executor")
            })
            .collect();
        // Degraded-journal reattach prober: while the journal is
        // detached (a write error flipped it memory-only), periodically
        // try to re-establish the backing file.  Joined with the
        // executors at shutdown; exits within one stop-poll step.
        if let Some(j) = &core.journal {
            let j = Arc::clone(j);
            let stop = Arc::clone(&stop);
            let metrics = Arc::clone(&metrics);
            let prober = std::thread::Builder::new()
                .name("journal-prober".into())
                .spawn(move || {
                    const PROBE_EVERY: Duration = Duration::from_secs(1);
                    const STOP_POLL: Duration = Duration::from_millis(200);
                    let mut since_probe = Duration::ZERO;
                    while !stop.load(Ordering::Acquire) {
                        std::thread::sleep(STOP_POLL);
                        since_probe += STOP_POLL;
                        if since_probe < PROBE_EVERY {
                            continue;
                        }
                        since_probe = Duration::ZERO;
                        if j.is_degraded() && j.probe_reattach() {
                            metrics.record_journal_reattach();
                        }
                    }
                })
                .expect("spawning journal prober");
            exec_handles.push(prober);
        }
        let accept_thread = {
            let core = Arc::clone(&core);
            std::thread::Builder::new()
                .name("accept".into())
                .spawn(move || accept_loop(listener, core, conn_handles, exec_handles))
                .expect("spawning accept thread")
        };

        Ok(Self { local_addr, metrics, stop, accept_thread: Some(accept_thread) })
    }

    /// Signal the listener to stop and wait for it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Block until the accept loop exits (after a `shutdown` op).
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Everything the fixed thread pools share.
struct ServerCore {
    stop: Arc<AtomicBool>,
    workers: Vec<Arc<WorkerShared>>,
    exec: Arc<ExecShared>,
    evaluator: Arc<dyn PlanEvaluator>,
    metrics: Arc<Metrics>,
    engine: Arc<JobEngine>,
    policies: Arc<crate::scheduler::PolicyRegistry>,
    cache: Option<Arc<crate::persist::SolveCache>>,
    journal: Option<Arc<crate::persist::Journal>>,
    chaos_allowed: bool,
    started: Instant,
    idle_timeout: Option<Duration>,
}

/// One connection worker's mailbox: new sockets from the accept thread,
/// finished requests from the executors.  The waker interrupts the
/// worker's poll whenever either arrives.
struct WorkerShared {
    waker: netpoll::Waker,
    inbox: Mutex<Inbox>,
}

#[derive(Default)]
struct Inbox {
    conns: Vec<TcpStream>,
    done: Vec<Completion>,
}

/// A finished request on its way back to the connection that sent it.
struct Completion {
    conn: u64,
    line: Vec<u8>,
    shutdown: bool,
}

/// One dispatched request line awaiting an executor.
struct ExecTask {
    worker: usize,
    conn: u64,
    line: String,
}

struct ExecShared {
    queue: Mutex<VecDeque<ExecTask>>,
    ready: Condvar,
}

fn wake_all(core: &ServerCore) {
    for w in &core.workers {
        w.waker.wake();
    }
    core.exec.ready.notify_all();
}

#[cfg(unix)]
fn fd_of(s: &TcpStream) -> netpoll::Fd {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn fd_of(_s: &TcpStream) -> netpoll::Fd {
    0
}

#[cfg(unix)]
fn fd_of_listener(l: &TcpListener) -> netpoll::Fd {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
fn fd_of_listener(_l: &TcpListener) -> netpoll::Fd {
    0
}

fn accept_loop(
    listener: TcpListener,
    core: Arc<ServerCore>,
    conn_handles: Vec<std::thread::JoinHandle<()>>,
    exec_handles: Vec<std::thread::JoinHandle<()>>,
) {
    let mut poller = netpoll::Poller::new();
    let mut events = Vec::new();
    let mut next_worker = 0usize;
    while !core.stop.load(Ordering::Acquire) {
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let w = &core.workers[next_worker];
                    next_worker = (next_worker + 1) % core.workers.len();
                    w.inbox.lock().unwrap().conns.push(stream);
                    w.waker.wake();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    // Only that one pending connection died; keep going.
                    continue;
                }
                Err(e) => {
                    // Transient resource errors (EMFILE/ENFILE under fd
                    // exhaustion, etc.) must not tear down a server that
                    // is holding thousands of live connections: log,
                    // back off a beat, and retry — existing connections
                    // keep being served throughout.
                    eprintln!("coordinator: accept error (retrying): {e}");
                    std::thread::sleep(Duration::from_millis(100));
                    break;
                }
            }
        }
        if core.stop.load(Ordering::Acquire) {
            break;
        }
        let sources = [(fd_of_listener(&listener), netpoll::Interest::READ)];
        if poller.wait(&sources, Duration::from_millis(50), &mut events).is_err() {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    // Tear-down, in dependency order: connection workers first (they
    // flush pending responses best-effort and drop their sockets, so
    // shutdown completes even with idle connections still open), then
    // the engine (cancels live jobs, which releases any executor parked
    // in run_sync), then the executors.
    wake_all(&core);
    for h in conn_handles {
        let _ = h.join();
    }
    core.engine.shutdown();
    core.exec.ready.notify_all();
    for h in exec_handles {
        let _ = h.join();
    }
}

/// Request-executor thread: pops dispatched lines, runs the protocol,
/// posts the response line back to the owning connection worker.
fn exec_loop(core: &ServerCore) {
    loop {
        let task = {
            let mut q = core.exec.queue.lock().unwrap();
            loop {
                if core.stop.load(Ordering::Acquire) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = core.exec.ready.wait(q).unwrap();
            }
        };
        let ctx = Context {
            evaluator: Arc::clone(&core.evaluator),
            metrics: Arc::clone(&core.metrics),
            engine: Arc::clone(&core.engine),
            registry: Arc::clone(&core.policies),
            job: None,
            cache: core.cache.clone(),
            journal: core.journal.clone(),
            chaos_allowed: core.chaos_allowed,
            started: core.started,
        };
        let t0 = Instant::now();
        // handle_line is the single error-shape funnel: decode failures
        // and protocol failures encode identically (v1 string form for
        // version-less requests, structured ApiError bodies for v2).
        // A panic that escapes the protocol layer (engine panics are
        // already contained there) must cost one reply, not the
        // executor thread: the client gets an internal error and the
        // loop keeps serving.
        let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            protocol::handle_line(&ctx, &task.line)
        }))
        .unwrap_or_else(|_| protocol::Reply {
            body: Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str("internal: request handler panicked")),
            ]),
            shutdown: false,
        });
        let (body, shutdown) = (reply.body, reply.shutdown);
        let ok = body.get("ok") == Some(&Json::Bool(true));
        core.metrics.record_request(t0.elapsed(), ok);
        let mut line = body.to_string().into_bytes();
        line.push(b'\n');
        let w = &core.workers[task.worker];
        w.inbox.lock().unwrap().done.push(Completion { conn: task.conn, line, shutdown });
        w.waker.wake();
    }
}

/// Per-connection state owned by exactly one connection worker.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet split into lines.
    rbuf: Vec<u8>,
    /// `rbuf[..scan_from]` is known newline-free: the line splitter
    /// resumes scanning here instead of rescanning the whole buffer.
    scan_from: usize,
    /// Response bytes not yet written (`wpos` = progress cursor).
    wbuf: Vec<u8>,
    wpos: usize,
    /// Parsed request lines awaiting dispatch (one at a time).
    pending: VecDeque<String>,
    /// A request from this connection is at / in the executor pool;
    /// responses stay in request order because nothing else dispatches
    /// until its completion lands.
    inflight: bool,
    read_closed: bool,
    close_after_flush: bool,
    dead: bool,
    /// Last time this connection did anything (accepted, read bytes, or
    /// received a response) — drives `--conn-idle-timeout` eviction.
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            rbuf: Vec::new(),
            scan_from: 0,
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            inflight: false,
            read_closed: false,
            close_after_flush: false,
            dead: false,
            last_activity: Instant::now(),
        }
    }

    fn interest(&self) -> netpoll::Interest {
        netpoll::Interest {
            readable: !self.read_closed && self.pending.len() < PENDING_MAX,
            writable: self.wpos < self.wbuf.len(),
        }
    }

    /// Drain the socket (bounded per tick), split complete lines into
    /// `pending`.  EOF with a final unterminated line still yields that
    /// line — parity with the old `BufRead::lines` server.
    fn read_some(&mut self) {
        if crate::util::failpoint::apply("conn.read").is_some() {
            self.dead = true;
            return;
        }
        let mut buf = [0u8; 8192];
        for _ in 0..MAX_READS_PER_TICK {
            match (&self.stream).read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&buf[..n]);
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.extract_lines();
        // After the splitter, rbuf holds only a newline-free partial
        // line; per-tick intake is bounded, so one post-loop check
        // suffices to bound memory.
        if self.rbuf.len() > MAX_LINE {
            self.dead = true;
            return;
        }
        if self.read_closed && !self.rbuf.is_empty() {
            let tail = String::from_utf8_lossy(&self.rbuf).trim().to_string();
            self.rbuf.clear();
            self.scan_from = 0;
            if !tail.is_empty() {
                self.pending.push_back(tail);
            }
        }
    }

    /// Split complete lines out of `rbuf` in one forward pass (resuming
    /// at `scan_from`), draining the consumed prefix exactly once — a
    /// burst of pipelined lines costs O(bytes), not O(lines x bytes).
    fn extract_lines(&mut self) {
        let mut start = 0usize;
        let mut i = self.scan_from;
        while i < self.rbuf.len() {
            if self.rbuf[i] == b'\n' {
                let s = String::from_utf8_lossy(&self.rbuf[start..i]);
                let s = s.trim();
                if !s.is_empty() {
                    self.pending.push_back(s.to_string());
                }
                start = i + 1;
            }
            i += 1;
        }
        if start > 0 {
            self.rbuf.drain(..start);
        }
        self.scan_from = self.rbuf.len();
    }

    /// Write as much of `wbuf` as the socket accepts right now.
    fn flush_nonblocking(&mut self) {
        if self.wpos < self.wbuf.len() && crate::util::failpoint::apply("conn.write").is_some() {
            self.dead = true;
            return;
        }
        while self.wpos < self.wbuf.len() {
            match (&self.stream).write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        if self.close_after_flush {
            self.dead = true;
        }
    }

    /// Best-effort blocking flush at server shutdown (the shutdown reply
    /// must reach its client even though the worker is about to exit).
    fn final_flush(&mut self) {
        if self.wpos >= self.wbuf.len() {
            return;
        }
        self.stream.set_nonblocking(false).ok();
        self.stream.set_write_timeout(Some(Duration::from_millis(200))).ok();
        let _ = (&self.stream).write_all(&self.wbuf[self.wpos..]);
        self.wbuf.clear();
        self.wpos = 0;
    }

    /// Nothing left to do for this connection.
    fn finished(&self) -> bool {
        self.dead
            || (self.read_closed
                && !self.inflight
                && self.pending.is_empty()
                && self.wpos >= self.wbuf.len())
    }
}

fn conn_worker_loop(index: usize, core: &ServerCore) {
    let shared = &core.workers[index];
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn: u64 = 0;
    let mut poller = netpoll::Poller::new();
    let mut sources: Vec<(netpoll::Fd, netpoll::Interest)> = Vec::new();
    let mut keys: Vec<u64> = Vec::new();
    let mut events: Vec<netpoll::Readiness> = Vec::new();
    // Poll-set key of the worker's own waker.
    const WAKER_KEY: u64 = u64::MAX;
    loop {
        // 1. Mailbox: adopt new sockets, apply finished requests.
        let (fresh, done) = {
            let mut inbox = shared.inbox.lock().unwrap();
            (std::mem::take(&mut inbox.conns), std::mem::take(&mut inbox.done))
        };
        for stream in fresh {
            stream.set_nonblocking(true).ok();
            stream.set_nodelay(true).ok();
            conns.insert(next_conn, Conn::new(stream));
            next_conn += 1;
        }
        for c in done {
            if c.shutdown {
                core.stop.store(true, Ordering::Release);
                wake_all(core);
            }
            if let Some(conn) = conns.get_mut(&c.conn) {
                conn.wbuf.extend_from_slice(&c.line);
                conn.inflight = false;
                conn.last_activity = Instant::now();
                if c.shutdown {
                    conn.close_after_flush = true;
                }
            }
        }
        // 2. Opportunistic writes (most responses fit the socket buffer
        // and never need a writable-poll round trip).
        for conn in conns.values_mut() {
            if conn.wpos < conn.wbuf.len() {
                conn.flush_nonblocking();
            }
        }
        // 3. Server stopping: flush what we can and drop everything.
        if core.stop.load(Ordering::Acquire) {
            for conn in conns.values_mut() {
                conn.final_flush();
            }
            return;
        }
        // 4. Reap finished connections — and, when the operator set
        // `--conn-idle-timeout`, fully quiescent ones that have been
        // silent past the bound (never a connection with a request in
        // flight, queued lines, or unflushed response bytes).
        let idle_cutoff = core.idle_timeout.map(|t| Instant::now() - t);
        conns.retain(|_, c| {
            if c.finished() {
                return false;
            }
            match idle_cutoff {
                Some(cutoff) => {
                    c.inflight
                        || !c.pending.is_empty()
                        || c.wpos < c.wbuf.len()
                        || c.last_activity > cutoff
                }
                None => true,
            }
        });
        // 5. Dispatch: at most one in-flight request per connection, and
        // only once the previous response is fully written — a client
        // that pipelines requests without reading responses stalls its
        // own connection instead of growing the write buffer unboundedly.
        let mut dispatched = false;
        {
            let mut q = None;
            for (&id, conn) in conns.iter_mut() {
                if !conn.inflight && conn.wbuf.is_empty() {
                    if let Some(line) = conn.pending.pop_front() {
                        conn.inflight = true;
                        q.get_or_insert_with(|| core.exec.queue.lock().unwrap())
                            .push_back(ExecTask { worker: index, conn: id, line });
                        dispatched = true;
                    }
                }
            }
        }
        if dispatched {
            core.exec.ready.notify_all();
        }
        // 6. Poll: the waker plus every connection's current interest.
        sources.clear();
        keys.clear();
        sources.push((shared.waker.fd(), netpoll::Interest::READ));
        keys.push(WAKER_KEY);
        for (&id, conn) in conns.iter() {
            sources.push((fd_of(&conn.stream), conn.interest()));
            keys.push(id);
        }
        if poller.wait(&sources, POLL_TIMEOUT, &mut events).is_err() {
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        // 7. Readiness: drain the waker, read/write ready connections.
        for (k, ev) in keys.iter().zip(events.iter()) {
            if *k == WAKER_KEY {
                if ev.readable {
                    shared.waker.drain();
                }
                continue;
            }
            let Some(conn) = conns.get_mut(k) else { continue };
            if ev.readable || ev.closed {
                conn.read_some();
            }
            if ev.writable {
                conn.flush_nonblocking();
            }
        }
    }
}

/// Minimal *raw-line* blocking helper: one connection, one verbatim
/// request line, one reply.  This is the v1 escape hatch — the CLI's
/// `client` command (user-supplied JSON) and the v1-parity tests use
/// it; everything else should speak [`super::client::Client`].
pub fn request(addr: &std::net::SocketAddr, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response)?;
    Json::parse(response.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}
