//! The TCP transport: thread-per-connection line server around
//! [`protocol::handle`].
//!
//! The listener accepts on a configurable address; each connection reads
//! newline-delimited JSON requests and writes one JSON response line per
//! request.  A `{"op":"shutdown"}` request stops the listener (used by
//! the tests and the `serve_demo` example; production deployments would
//! front this with their own process manager).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::eval::{NativeEvaluator, PlanEvaluator};
use crate::util::Json;

use super::engine::JobEngine;
use super::protocol::{self, Context};
use super::{BatchingEvaluator, Metrics};

/// Server settings.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Listen address, e.g. "127.0.0.1:7077" (port 0 = ephemeral).
    pub addr: String,
    /// Use the XLA artifact evaluator when available.
    pub use_xla: bool,
    /// Wrap the evaluator in the dynamic batcher.
    pub batching: bool,
    /// Batcher linger time.
    pub batch_wait: Duration,
    /// Worker shards of the job engine (0 = auto: one per core, capped
    /// at 8).  Every campaign/sweep — synchronous or submitted — runs on
    /// this pool; at most `shards` of them execute at once.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7077".into(),
            use_xla: true,
            batching: true,
            batch_wait: Duration::from_millis(2),
            shards: 0,
        }
    }
}

/// A running coordinator.
pub struct Coordinator {
    pub local_addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build the evaluator stack per config and start listening.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());

        let base: Arc<dyn PlanEvaluator> = if config.use_xla {
            match crate::runtime::XlaEvaluator::load() {
                Ok(x) => Arc::new(x),
                Err(e) => {
                    eprintln!("coordinator: XLA artifacts unavailable ({e:#}); using native evaluator");
                    Arc::new(NativeEvaluator)
                }
            }
        } else {
            Arc::new(NativeEvaluator)
        };
        let chunk = crate::runtime::ArtifactMeta::load().map(|m| m.k).unwrap_or(64);
        let evaluator: Arc<dyn PlanEvaluator> = if config.batching {
            Arc::new(BatchingEvaluator::new(base, chunk, config.batch_wait, Arc::clone(&metrics)))
        } else {
            base
        };

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let shards = config.shards;
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let metrics = Arc::clone(&metrics);
            std::thread::spawn(move || {
                accept_loop(listener, stop, evaluator, metrics, shards);
            })
        };

        Ok(Self { local_addr, metrics, stop, accept_thread: Some(accept_thread) })
    }

    /// Signal the listener to stop and wait for it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Block until the accept loop exits (after a `shutdown` op).
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    evaluator: Arc<dyn PlanEvaluator>,
    metrics: Arc<Metrics>,
    shards: usize,
) {
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // One job engine for the whole server: every campaign/sweep/submit
    // executes on its sharded pool, and job ids are visible across
    // connections (submit on one socket, poll/cancel on another).
    // Likewise one policy registry, shared by every connection thread.
    let engine = Arc::new(JobEngine::new(shards, Arc::clone(&metrics)));
    let registry = Arc::new(crate::scheduler::PolicyRegistry::builtin());
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let ctx_stop = Arc::clone(&stop);
                let ctx = Context {
                    evaluator: Arc::clone(&evaluator),
                    metrics: Arc::clone(&metrics),
                    engine: Arc::clone(&engine),
                    registry: Arc::clone(&registry),
                    job: None,
                };
                workers.push(std::thread::spawn(move || {
                    if let Err(e) = serve_connection(stream, ctx, ctx_stop) {
                        eprintln!("coordinator: connection error: {e:#}");
                    }
                }));
                workers.retain(|w| !w.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("coordinator: accept error: {e}");
                break;
            }
        }
    }
    for w in workers {
        let _ = w.join();
    }
    // Connections are drained; stop the pool (cancels any jobs still
    // queued or running — their tokens fire and work stops at the next
    // cooperative checkpoint).
    engine.shutdown();
}

fn serve_connection(stream: TcpStream, ctx: Context, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let (body, shutdown) = match protocol::handle(&ctx, &line) {
            Ok(reply) => (reply.body, reply.shutdown),
            Err(e) => (
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(format!("{e:#}"))),
                ]),
                false,
            ),
        };
        let ok = body.get("ok") == Some(&Json::Bool(true));
        ctx.metrics.record_request(t0.elapsed(), ok);
        writer.write_all(body.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown {
            stop.store(true, Ordering::Release);
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, examples and the CLI's `client` op.
pub fn request(addr: &std::net::SocketAddr, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response)?;
    Json::parse(response.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}
