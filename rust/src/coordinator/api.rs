//! The typed, versioned coordinator API: the single source of truth for
//! the wire protocol.
//!
//! Every request the coordinator accepts and every reply it produces is
//! described here as a plain Rust type with `encode`/`decode` through
//! [`crate::util::Json`].  The protocol layer
//! ([`super::protocol::handle`]) is a thin `decode → dispatch(typed) →
//! encode` pipeline over these types, and the first-class client
//! ([`super::client::Client`]) speaks them directly — nothing in the
//! repo hand-assembles op JSON strings (the explicit v1-parity test
//! fixtures excepted).
//!
//! ## Versioning
//!
//! Requests may carry an optional `"v"` field:
//!
//! * **absent or `1`** — v1 semantics.  Reply shapes are byte-identical
//!   to the historical protocol: success bodies are unchanged, errors
//!   are `{"ok":false,"error":"<string>"}`, and the admission-control
//!   rejection keeps its legacy shape
//!   `{"ok":false,"error":"busy","shard":S,"backlog":N}`.
//! * **`2`** — structured errors.  Every failure becomes
//!   `{"ok":false,"error":{"code":…,"message":…,"detail":…?}}` with a
//!   code from the [`ErrorCode`] taxonomy; `busy` rejections carry
//!   `detail.shard` / `detail.backlog` / `detail.retry_after_ms` (the
//!   hint is derived from the queue-wait p50 reservoir in
//!   [`super::Metrics`]); and the `describe` op becomes available,
//!   returning the machine-readable op/field schema
//!   ([`describe_schema`]) that the drift tests snapshot.
//!
//! Success reply shapes are identical across versions — only error
//! encoding and the `describe` op differ, so a v1 client never sees a
//! byte it does not expect.
//!
//! ## Error codes
//!
//! | code             | meaning                                              |
//! |------------------|------------------------------------------------------|
//! | `bad_request`    | malformed JSON, missing/mistyped/out-of-range fields |
//! | `unknown_policy` | the named policy is not in the registry              |
//! | `unknown_op`     | the `"op"` is not one the coordinator serves         |
//! | `busy`           | admission control rejected the job (shard at bound)  |
//! | `cancelled`      | the job was cancelled before it produced a result    |
//! | `evicted`        | the job id is unknown (never existed or evicted)     |
//! | `internal`       | the job ran and failed                               |
//! | `deadline_exceeded` | the job missed its binding `deadline_ms`          |

use std::fmt;

use crate::model::System;
use crate::util::Json;

use super::engine::JobPriority;

/// Protocol version 1: the historical, string-error wire dialect.
pub const V1: u8 = 1;
/// Protocol version 2: structured errors + the `describe` op.
pub const V2: u8 = 2;

/// Ceiling on a wire-supplied relative queue deadline (~1000 days) —
/// mirrors `config::job_priority_from_json` so both decoders agree.
const MAX_DEADLINE_MS: u64 = 86_400_000_000;

/// Wire-bounded worker-thread ceiling (0 = auto stays allowed).
const MAX_THREADS: u64 = 256;

/// Wire-bounded campaign Monte-Carlo fan-out ceiling.
const MAX_REPLICATIONS: u64 = 4096;

/// Parse a request's protocol version: absent ⇒ v1.
pub fn version_of(req: &Json) -> Result<u8, ApiError> {
    match req.get("v") {
        None => Ok(V1),
        Some(v) => match v.as_u64() {
            Some(n @ 1..=2) => Ok(n as u8),
            _ => Err(ApiError::bad_request(format!("\"v\" must be 1 or 2, got {v}"))),
        },
    }
}

// ---------------------------------------------------------------------------
// Errors.

/// The error taxonomy (see the module docs for the meaning of each).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    UnknownPolicy,
    UnknownOp,
    Busy,
    Cancelled,
    Evicted,
    Internal,
    DeadlineExceeded,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownPolicy => "unknown_policy",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::Busy => "busy",
            ErrorCode::Cancelled => "cancelled",
            ErrorCode::Evicted => "evicted",
            ErrorCode::Internal => "internal",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "bad_request" => ErrorCode::BadRequest,
            "unknown_policy" => ErrorCode::UnknownPolicy,
            "unknown_op" => ErrorCode::UnknownOp,
            "busy" => ErrorCode::Busy,
            "cancelled" => ErrorCode::Cancelled,
            "evicted" => ErrorCode::Evicted,
            "internal" => ErrorCode::Internal,
            "deadline_exceeded" => ErrorCode::DeadlineExceeded,
            _ => return None,
        })
    }
}

/// Every code, in `describe` order.
pub const ERROR_CODES: &[ErrorCode] = &[
    ErrorCode::BadRequest,
    ErrorCode::UnknownPolicy,
    ErrorCode::UnknownOp,
    ErrorCode::Busy,
    ErrorCode::Cancelled,
    ErrorCode::Evicted,
    ErrorCode::Internal,
    ErrorCode::DeadlineExceeded,
];

/// A structured protocol error: taxonomy code + human message + optional
/// machine-readable detail (e.g. `busy` carries shard/backlog/retry).
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    pub detail: Option<Json>,
}

/// The typed form of a `busy` rejection on the client side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BusyInfo {
    pub shard: u64,
    pub backlog: u64,
    /// Server hint: the queue-wait p50, i.e. roughly how long freshly
    /// admitted work is currently waiting for a worker.
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        Self { code, message: message.into(), detail: None }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::BadRequest, message)
    }

    pub fn unknown_policy(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::UnknownPolicy, message)
    }

    pub fn unknown_op(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::UnknownOp, message)
    }

    pub fn cancelled(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::Cancelled, message)
    }

    pub fn evicted(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::Evicted, message)
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::Internal, message)
    }

    pub fn deadline_exceeded(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::DeadlineExceeded, message)
    }

    /// The admission-control rejection.  `retry_after_ms` is the v2
    /// hint (callers omit it for v1 requests, whose byte-pinned reply
    /// never carries it — computing the percentile would be wasted work
    /// on the load-shed path).
    pub fn busy(shard: usize, backlog: usize, retry_after_ms: Option<u64>) -> Self {
        let mut detail = vec![
            ("shard", Json::num(shard as f64)),
            ("backlog", Json::num(backlog as f64)),
        ];
        if let Some(ms) = retry_after_ms {
            detail.push(("retry_after_ms", Json::num(ms as f64)));
        }
        Self {
            code: ErrorCode::Busy,
            message: format!("busy: shard {shard} backlog {backlog} is at its bound"),
            detail: Some(Json::obj(detail)),
        }
    }

    /// The typed busy payload, when this is a `busy` error.
    pub fn busy_info(&self) -> Option<BusyInfo> {
        if self.code != ErrorCode::Busy {
            return None;
        }
        let d = self.detail.as_ref()?;
        Some(BusyInfo {
            shard: d.get("shard").and_then(Json::as_u64)?,
            backlog: d.get("backlog").and_then(Json::as_u64)?,
            retry_after_ms: d.get("retry_after_ms").and_then(Json::as_u64),
        })
    }

    /// The v1 error body.  `busy` keeps its exact legacy shape (no
    /// retry hint: the v1 reply is byte-pinned); everything else is the
    /// legacy `{"ok":false,"error":"<message>"}` string form.
    pub fn encode_v1(&self) -> Json {
        if self.code == ErrorCode::Busy {
            let info = self.busy_info().unwrap_or(BusyInfo {
                shard: 0,
                backlog: 0,
                retry_after_ms: None,
            });
            return Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str("busy")),
                ("shard", Json::num(info.shard as f64)),
                ("backlog", Json::num(info.backlog as f64)),
            ]);
        }
        Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(&self.message)),
        ])
    }

    /// The v2 structured error body.
    pub fn encode_v2(&self) -> Json {
        let mut err = vec![
            ("code", Json::str(self.code.as_str())),
            ("message", Json::str(&self.message)),
        ];
        if let Some(d) = &self.detail {
            err.push(("detail", d.clone()));
        }
        Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::obj(err))])
    }

    /// Parse an error out of a reply body (either version's shape).
    /// `None` when the body is not an error (`ok` is not `false`).
    pub fn decode(body: &Json) -> Option<ApiError> {
        if body.get("ok") != Some(&Json::Bool(false)) {
            return None;
        }
        match body.get("error") {
            Some(Json::Obj(_)) => {
                let e = body.get("error").unwrap();
                let code = e
                    .get("code")
                    .and_then(Json::as_str)
                    .and_then(ErrorCode::parse)
                    .unwrap_or(ErrorCode::Internal);
                Some(ApiError {
                    code,
                    message: e
                        .get("message")
                        .and_then(Json::as_str)
                        .unwrap_or("unspecified error")
                        .to_string(),
                    detail: e.get("detail").cloned(),
                })
            }
            Some(Json::Str(s)) if s == "busy" => {
                // Legacy busy shape: shard/backlog ride at the top level.
                let shard = body.get("shard").and_then(Json::as_u64).unwrap_or(0);
                let backlog = body.get("backlog").and_then(Json::as_u64).unwrap_or(0);
                Some(ApiError {
                    code: ErrorCode::Busy,
                    message: format!("busy: shard {shard} backlog {backlog} is at its bound"),
                    detail: Some(Json::obj(vec![
                        ("shard", Json::num(shard as f64)),
                        ("backlog", Json::num(backlog as f64)),
                    ])),
                })
            }
            Some(Json::Str(s)) => Some(ApiError::internal(s.clone())),
            _ => Some(ApiError::internal("malformed error reply")),
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

// ---------------------------------------------------------------------------
// Strict/lenient field readers (string-for-string with the historical
// parsers, so v1 error messages stay byte-identical).

fn strict_f64(j: &Json, key: &str) -> Result<Option<f64>, ApiError> {
    j.get(key)
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| {
                    ApiError::bad_request(format!("\"{key}\" must be a number, got {v}"))
                })
        })
        .transpose()
}

fn strict_u64(j: &Json, key: &str) -> Result<Option<u64>, ApiError> {
    j.get(key)
        .map(|v| {
            v.as_u64().ok_or_else(|| {
                ApiError::bad_request(format!("\"{key}\" must be a non-negative integer, got {v}"))
            })
        })
        .transpose()
}

fn strict_str(j: &Json, key: &str) -> Result<Option<String>, ApiError> {
    j.get(key)
        .map(|v| {
            v.as_str().map(str::to_string).ok_or_else(|| {
                ApiError::bad_request(format!("\"{key}\" must be a string, got {v}"))
            })
        })
        .transpose()
}

/// The wire-bounded `threads` knob, shared by every op that carries it
/// (plan/simulate/campaign via [`SolveParams`], and sweep).
fn bounded_threads_field(j: &Json) -> Result<Option<u64>, ApiError> {
    let threads = strict_u64(j, "threads")?;
    if let Some(t) = threads {
        if t > MAX_THREADS {
            return Err(ApiError::bad_request(format!(
                "threads {t} exceeds the limit of {MAX_THREADS}"
            )));
        }
    }
    Ok(threads)
}

// ---------------------------------------------------------------------------
// Shared request components.

/// Which problem instance a request targets.
#[derive(Debug, Clone, PartialEq)]
pub enum SystemSpec {
    /// `"paper"`, `"paper:<overhead>"` or a JSON file path (resolved
    /// server-side via `config::load_system`).
    Named(String),
    /// An inline system object (`config::system_from_json` schema).
    Inline(Json),
}

/// The system selector shared by every planning/simulation op: an
/// explicit `system`, a named `scenario` preset, or (neither) the
/// paper's Table I setup with an optional `overhead`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SystemRef {
    pub system: Option<SystemSpec>,
    pub scenario: Option<String>,
    /// Boot overhead for the default (Table I) system; ignored when
    /// `system`/`scenario` is given.
    pub overhead: Option<f64>,
}

impl SystemRef {
    /// A named scenario preset (see [`crate::workload::scenario`]).
    pub fn scenario(name: impl Into<String>) -> Self {
        Self { scenario: Some(name.into()), ..Self::default() }
    }

    /// A named system (`"paper"`, `"paper:<overhead>"`, file path).
    pub fn named(name: impl Into<String>) -> Self {
        Self { system: Some(SystemSpec::Named(name.into())), ..Self::default() }
    }

    fn decode(j: &Json) -> Result<Self, ApiError> {
        let system = j.get("system").map(|v| match v {
            Json::Str(s) => SystemSpec::Named(s.clone()),
            other => SystemSpec::Inline(other.clone()),
        });
        Ok(Self {
            system,
            scenario: strict_str(j, "scenario")?,
            overhead: j.get("overhead").and_then(Json::as_f64),
        })
    }

    fn encode_into(&self, fields: &mut Vec<(&'static str, Json)>) {
        match &self.system {
            Some(SystemSpec::Named(s)) => fields.push(("system", Json::str(s))),
            Some(SystemSpec::Inline(j)) => fields.push(("system", j.clone())),
            None => {}
        }
        if let Some(s) = &self.scenario {
            fields.push(("scenario", Json::str(s)));
        }
        if let Some(o) = self.overhead {
            fields.push(("overhead", Json::num(o)));
        }
    }

    /// Build the targeted [`System`].
    pub fn resolve(&self) -> Result<System, ApiError> {
        match (&self.scenario, &self.system) {
            (Some(_), Some(_)) => Err(ApiError::bad_request(
                "\"scenario\" and \"system\" are mutually exclusive — name one of them",
            )),
            (Some(name), None) => crate::workload::build_scenario(name).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "unknown scenario {name:?} (known: {})",
                    crate::workload::scenario_names().join(", ")
                ))
            }),
            (None, Some(SystemSpec::Named(s))) => crate::config::load_system(s)
                .map_err(|e| ApiError::bad_request(format!("{e:#}"))),
            (None, Some(SystemSpec::Inline(j))) => crate::config::system_from_json(j)
                .map_err(|e| ApiError::bad_request(format!("{e:#}"))),
            (None, None) => Ok(crate::workload::paper::table1_system(
                self.overhead.unwrap_or(0.0),
            )),
        }
    }
}

/// Planner-phase overrides (the nested `"planner"` object).  All fields
/// optional; decoding is lenient like the historical parser.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlannerOverrides {
    pub max_iters: Option<u64>,
    pub replace_k: Option<u64>,
    pub enable_reduce: Option<bool>,
    pub enable_add: Option<bool>,
    pub enable_balance: Option<bool>,
    pub enable_split: Option<bool>,
    pub enable_replace: Option<bool>,
}

impl PlannerOverrides {
    pub(crate) fn decode(j: &Json) -> Self {
        let b = |key: &str| j.get(key).and_then(Json::as_bool);
        Self {
            max_iters: j.get("max_iters").and_then(Json::as_u64),
            replace_k: j.get("replace_k").and_then(Json::as_u64),
            enable_reduce: b("enable_reduce"),
            enable_add: b("enable_add"),
            enable_balance: b("enable_balance"),
            enable_split: b("enable_split"),
            enable_replace: b("enable_replace"),
        }
    }

    fn encode(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(n) = self.max_iters {
            fields.push(("max_iters", Json::num(n as f64)));
        }
        if let Some(k) = self.replace_k {
            fields.push(("replace_k", Json::num(k as f64)));
        }
        let mut flag = |key: &'static str, v: Option<bool>| {
            if let Some(b) = v {
                fields.push((key, Json::Bool(b)));
            }
        };
        flag("enable_reduce", self.enable_reduce);
        flag("enable_add", self.enable_add);
        flag("enable_balance", self.enable_balance);
        flag("enable_split", self.enable_split);
        flag("enable_replace", self.enable_replace);
        Json::obj(fields)
    }

    /// Apply the overrides to the default [`PlannerConfig`].
    pub fn to_config(&self) -> crate::scheduler::PlannerConfig {
        let mut cfg = crate::scheduler::PlannerConfig::default();
        if let Some(n) = self.max_iters {
            cfg.max_iters = n as usize;
        }
        if let Some(k) = self.replace_k {
            cfg.replace_k = k as usize;
        }
        cfg.enable_reduce = self.enable_reduce.unwrap_or(cfg.enable_reduce);
        cfg.enable_add = self.enable_add.unwrap_or(cfg.enable_add);
        cfg.enable_balance = self.enable_balance.unwrap_or(cfg.enable_balance);
        cfg.enable_split = self.enable_split.unwrap_or(cfg.enable_split);
        cfg.enable_replace = self.enable_replace.unwrap_or(cfg.enable_replace);
        cfg
    }
}

/// The solver knobs shared by `plan`, `simulate` and `campaign`.
#[derive(Debug, Clone, PartialEq)]
pub struct SolveParams {
    pub budget: f64,
    /// Canonical `"policy"` (decode also accepts the legacy
    /// `"approach"` spelling; a non-string value is ignored, exactly
    /// like the historical parser).
    pub policy: Option<String>,
    pub deadline: Option<f64>,
    pub seed: Option<u64>,
    pub n_starts: Option<u64>,
    pub perf_jitter: Option<f64>,
    pub sample_frac: Option<f64>,
    /// Worker threads (0 = auto), wire-bounded at 256.
    pub threads: Option<u64>,
    /// Residual task ids for `"dynamic"` re-planning.
    pub remaining: Option<Vec<u32>>,
    pub planner: Option<PlannerOverrides>,
}

impl SolveParams {
    pub fn new(budget: f64) -> Self {
        Self {
            budget,
            policy: None,
            deadline: None,
            seed: None,
            n_starts: None,
            perf_jitter: None,
            sample_frac: None,
            threads: None,
            remaining: None,
            planner: None,
        }
    }

    pub(crate) fn decode(j: &Json) -> Result<Self, ApiError> {
        // Historical quirk, kept for v1 parity: the legacy wire path
        // reported a present-but-mistyped budget as missing (its lenient
        // `budget_of` ran before the strict knob parser could object).
        let budget = j
            .get("budget")
            .and_then(Json::as_f64)
            .ok_or_else(|| ApiError::bad_request("missing \"budget\""))?;
        let policy = j
            .get("policy")
            .or_else(|| j.get("approach"))
            .and_then(Json::as_str)
            .map(str::to_string);
        let perf_jitter = strict_f64(j, "perf_jitter")?;
        if let Some(x) = perf_jitter {
            if !(0.0..1.0).contains(&x) {
                return Err(ApiError::bad_request(format!(
                    "perf_jitter must be in [0, 1), got {x}"
                )));
            }
        }
        let sample_frac = strict_f64(j, "sample_frac")?;
        if let Some(f) = sample_frac {
            if !(f > 0.0 && f <= 1.0) {
                return Err(ApiError::bad_request(format!(
                    "sample_frac must be in (0, 1], got {f}"
                )));
            }
        }
        let threads = bounded_threads_field(j)?;
        let remaining = match j.get("remaining") {
            None => None,
            Some(r) => {
                let arr = r.as_arr().ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "\"remaining\" must be an array of task ids, got {r}"
                    ))
                })?;
                if arr.is_empty() {
                    return Err(ApiError::bad_request(
                        "\"remaining\" must name at least one task (omit it for the full workload)",
                    ));
                }
                let ids: Vec<u32> = arr
                    .iter()
                    .map(|v| {
                        let t = v.as_u64().ok_or_else(|| {
                            ApiError::bad_request(format!(
                                "\"remaining\" task id must be a non-negative integer, got {v}"
                            ))
                        })?;
                        if t > u64::from(u32::MAX) {
                            return Err(ApiError::bad_request(format!(
                                "\"remaining\" task id {t} out of range"
                            )));
                        }
                        Ok(t as u32)
                    })
                    .collect::<Result<_, ApiError>>()?;
                Some(ids)
            }
        };
        Ok(Self {
            budget,
            policy,
            deadline: strict_f64(j, "deadline")?,
            seed: strict_u64(j, "seed")?,
            n_starts: strict_u64(j, "n_starts")?,
            perf_jitter,
            sample_frac,
            threads,
            remaining,
            planner: j.get("planner").map(PlannerOverrides::decode),
        })
    }

    fn encode_into(&self, fields: &mut Vec<(&'static str, Json)>) {
        fields.push(("budget", Json::num(self.budget)));
        if let Some(p) = &self.policy {
            fields.push(("policy", Json::str(p)));
        }
        if let Some(d) = self.deadline {
            fields.push(("deadline", Json::num(d)));
        }
        if let Some(s) = self.seed {
            fields.push(("seed", Json::num(s as f64)));
        }
        if let Some(n) = self.n_starts {
            fields.push(("n_starts", Json::num(n as f64)));
        }
        if let Some(x) = self.perf_jitter {
            fields.push(("perf_jitter", Json::num(x)));
        }
        if let Some(f) = self.sample_frac {
            fields.push(("sample_frac", Json::num(f)));
        }
        if let Some(t) = self.threads {
            fields.push(("threads", Json::num(t as f64)));
        }
        if let Some(r) = &self.remaining {
            fields.push(("remaining", Json::arr(r.iter().map(|t| Json::num(f64::from(*t))))));
        }
        if let Some(p) = &self.planner {
            fields.push(("planner", p.encode()));
        }
    }

    /// Build the in-process [`crate::scheduler::SolveRequest`] these
    /// knobs describe (evaluator/cancel handles attached by the caller).
    pub fn solve_request(&self) -> crate::scheduler::SolveRequest<'static> {
        let mut req = crate::scheduler::SolveRequest::new(self.budget);
        if let Some(d) = self.deadline {
            req = req.with_deadline(d);
        }
        if let Some(s) = self.seed {
            req = req.with_seed(s);
        }
        if let Some(n) = self.n_starts {
            req = req.with_starts(n as usize);
        }
        if let Some(x) = self.perf_jitter {
            req = req.with_perf_jitter(x);
        }
        if let Some(f) = self.sample_frac {
            req = req.with_sample_frac(f);
        }
        if let Some(t) = self.threads {
            req = req.with_threads(t as usize);
        }
        if let Some(r) = &self.remaining {
            req = req.with_remaining(r.iter().map(|t| crate::model::TaskId(*t)).collect());
        }
        if let Some(p) = &self.planner {
            req = req.with_planner(p.to_config());
        }
        req
    }
}

/// The simulator noise model (lenient decode, like the historical
/// parser: mistyped fields fall back to their defaults).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NoiseSpec {
    pub task_sigma: Option<f64>,
    pub boot_sigma: Option<f64>,
    pub mean_lifetime: Option<f64>,
}

impl NoiseSpec {
    pub(crate) fn decode(j: &Json) -> Self {
        Self {
            task_sigma: j.get("task_sigma").and_then(Json::as_f64),
            boot_sigma: j.get("boot_sigma").and_then(Json::as_f64),
            mean_lifetime: j.get("mean_lifetime").and_then(Json::as_f64),
        }
    }

    fn encode(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(x) = self.task_sigma {
            fields.push(("task_sigma", Json::num(x)));
        }
        if let Some(x) = self.boot_sigma {
            fields.push(("boot_sigma", Json::num(x)));
        }
        if let Some(x) = self.mean_lifetime {
            fields.push(("mean_lifetime", Json::num(x)));
        }
        Json::obj(fields)
    }

    pub fn model(&self) -> crate::cloudsim::NoiseModel {
        crate::cloudsim::NoiseModel {
            task_sigma: self.task_sigma.unwrap_or(0.0),
            boot_sigma: self.boot_sigma.unwrap_or(0.0),
            mean_lifetime: self.mean_lifetime,
        }
    }
}

/// Queue placement of an engine-bound request (`submit`, sync
/// `sweep`/`campaign`): `priority` 0..=9 and a relative `deadline_ms`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Placement {
    pub priority: Option<u64>,
    pub deadline_ms: Option<u64>,
}

impl Placement {
    pub(crate) fn decode(j: &Json) -> Result<Self, ApiError> {
        let priority = strict_u64(j, "priority")?;
        if let Some(p) = priority {
            if p > 9 {
                return Err(ApiError::bad_request(format!(
                    "\"priority\" must be in 0..=9, got {p}"
                )));
            }
        }
        let deadline_ms = strict_u64(j, "deadline_ms")?;
        if let Some(d) = deadline_ms {
            if d > MAX_DEADLINE_MS {
                return Err(ApiError::bad_request(format!(
                    "\"deadline_ms\" {d} exceeds the limit of {MAX_DEADLINE_MS}"
                )));
            }
        }
        Ok(Self { priority, deadline_ms })
    }

    fn encode_into(&self, fields: &mut Vec<(&'static str, Json)>) {
        if let Some(p) = self.priority {
            fields.push(("priority", Json::num(p as f64)));
        }
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(d as f64)));
        }
    }

    /// The engine's queue-placement struct.
    pub fn job_priority(&self) -> JobPriority {
        JobPriority {
            priority: self.priority.unwrap_or(0) as u8,
            deadline_ms: self.deadline_ms,
        }
    }
}

// ---------------------------------------------------------------------------
// Requests: one struct per op.

#[derive(Debug, Clone, PartialEq)]
pub struct PlanRequest {
    pub params: SolveParams,
    pub target: SystemRef,
    /// Include the full task-level assignment in the reply.
    pub detail: bool,
}

impl PlanRequest {
    pub fn new(budget: f64) -> Self {
        Self { params: SolveParams::new(budget), target: SystemRef::default(), detail: false }
    }

    pub fn with_policy(mut self, policy: impl Into<String>) -> Self {
        self.params.policy = Some(policy.into());
        self
    }

    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.params.deadline = Some(deadline);
        self
    }

    pub fn with_threads(mut self, threads: u64) -> Self {
        self.params.threads = Some(threads);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.params.seed = Some(seed);
        self
    }

    pub fn with_target(mut self, target: SystemRef) -> Self {
        self.target = target;
        self
    }

    pub fn with_detail(mut self) -> Self {
        self.detail = true;
        self
    }

    /// The canonical solve-cache key for this request: a sorted-field
    /// JSON rendering of the system target and the solve knobs, with
    /// [`crate::persist::CACHE_VERSION`] baked in.  Outcome-irrelevant
    /// knobs are excluded — `threads` only changes how fast the solve
    /// runs (pinned by the sweep determinism tests) and `detail` only
    /// shapes the reply, which is rebuilt per request from the cached
    /// [`crate::scheduler::SolveOutcome`].  `seed` stays in the key
    /// because it changes the solution.  Field order on the wire is
    /// irrelevant: [`Json::obj`] sorts keys, so permuted requests hash
    /// identically.
    pub fn cache_key(&self) -> String {
        let mut params = self.params.clone();
        params.threads = None;
        let mut fields: Vec<(&'static str, Json)> = vec![
            ("cache_version", Json::num(f64::from(crate::persist::CACHE_VERSION))),
            ("op", Json::str("plan")),
        ];
        params.encode_into(&mut fields);
        self.target.encode_into(&mut fields);
        Json::obj(fields).to_string()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct SimulateRequest {
    pub params: SolveParams,
    pub target: SystemRef,
    pub noise: Option<NoiseSpec>,
}

impl SimulateRequest {
    pub fn new(budget: f64) -> Self {
        Self { params: SolveParams::new(budget), target: SystemRef::default(), noise: None }
    }

    pub fn with_noise(mut self, noise: NoiseSpec) -> Self {
        self.noise = Some(noise);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.params.seed = Some(seed);
        self
    }

    pub fn with_target(mut self, target: SystemRef) -> Self {
        self.target = target;
        self
    }
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct SweepRequest {
    pub target: SystemRef,
    /// `None` = the paper's budget grid.
    pub budgets: Option<Vec<f64>>,
    pub threads: Option<u64>,
    pub placement: Placement,
}

impl SweepRequest {
    pub fn with_budgets(mut self, budgets: Vec<f64>) -> Self {
        self.budgets = Some(budgets);
        self
    }

    pub fn with_threads(mut self, threads: u64) -> Self {
        self.threads = Some(threads);
        self
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CampaignRequest {
    pub params: SolveParams,
    pub target: SystemRef,
    pub noise: Option<NoiseSpec>,
    pub max_rounds: Option<u64>,
    /// Monte-Carlo replications (1 = a single closed-loop campaign).
    pub replications: Option<u64>,
    pub placement: Placement,
}

impl CampaignRequest {
    pub fn new(budget: f64) -> Self {
        Self {
            params: SolveParams::new(budget),
            target: SystemRef::default(),
            noise: None,
            max_rounds: None,
            replications: None,
            placement: Placement::default(),
        }
    }

    pub fn with_policy(mut self, policy: impl Into<String>) -> Self {
        self.params.policy = Some(policy.into());
        self
    }

    pub fn with_noise(mut self, noise: NoiseSpec) -> Self {
        self.noise = Some(noise);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.params.seed = Some(seed);
        self
    }

    pub fn with_max_rounds(mut self, rounds: u64) -> Self {
        self.max_rounds = Some(rounds);
        self
    }

    pub fn with_replications(mut self, n: u64) -> Self {
        self.replications = Some(n);
        self
    }

    pub fn with_threads(mut self, threads: u64) -> Self {
        self.params.threads = Some(threads);
        self
    }

    pub fn with_target(mut self, target: SystemRef) -> Self {
        self.target = target;
        self
    }
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct EstimatePerfRequest {
    pub target: SystemRef,
    pub per_cell: Option<u64>,
    pub noise: Option<NoiseSpec>,
    pub seed: Option<u64>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    /// The inner request to run asynchronously (decoded when the job
    /// executes; only its `"op"` is validated at submit time, exactly
    /// like the historical behaviour).
    pub job: Json,
    pub placement: Placement,
}

impl SubmitRequest {
    /// Wrap a typed request as an async job.
    pub fn from_request(job: &Request, placement: Placement) -> Self {
        Self { job: job.encode(), placement }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StatusRequest {
    pub job_id: String,
    /// Streaming cursor: the previous reply's `partials_next`.
    pub partials_from: Option<u64>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CancelRequest {
    pub job_id: String,
}

/// What a `persist` request asks of the durability layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PersistAction {
    /// Journal + cache statistics (the default when `action` is absent).
    Stats,
    /// Trigger a journal compaction, then report statistics.
    Compact,
}

/// The `persist` op (v2 only): durability statistics and maintenance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PersistRequest {
    pub action: PersistAction,
}

impl PersistRequest {
    fn decode(j: &Json) -> Result<Self, ApiError> {
        let action = match j.get("action") {
            None => PersistAction::Stats,
            Some(v) => match v.as_str() {
                Some("stats") => PersistAction::Stats,
                Some("compact") => PersistAction::Compact,
                Some(other) => {
                    return Err(ApiError::bad_request(format!(
                        "persist: unknown action {other:?} (try \"stats\" or \"compact\")"
                    )))
                }
                None => {
                    return Err(ApiError::bad_request(format!(
                        "persist: \"action\" must be a string, got {v}"
                    )))
                }
            },
        };
        Ok(Self { action })
    }
}

/// What a `chaos` request asks of the failpoint registry
/// ([`crate::util::failpoint`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChaosAction {
    /// Report every configured failpoint (the default when `action` is
    /// absent).
    List,
    /// Arm the points named by a spec string (the
    /// `name=action[@prob][xN]` grammar documented in
    /// [`crate::util::failpoint`]).
    Arm(String),
    /// Disarm one named point, or every point when `point` is absent.
    Disarm(Option<String>),
}

/// The `chaos` op (v2 only, and only on servers started with
/// `--chaos-allowed`): inspect and drive the fault-injection registry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosRequest {
    pub action: ChaosAction,
}

impl ChaosRequest {
    fn decode(j: &Json) -> Result<Self, ApiError> {
        let action = match j.get("action") {
            None => ChaosAction::List,
            Some(v) => match v.as_str() {
                Some("list") => ChaosAction::List,
                Some("arm") => {
                    let spec = strict_str(j, "spec")?.ok_or_else(|| {
                        ApiError::bad_request("chaos: action \"arm\" requires a \"spec\" string")
                    })?;
                    ChaosAction::Arm(spec)
                }
                Some("disarm") => ChaosAction::Disarm(strict_str(j, "point")?),
                Some(other) => {
                    return Err(ApiError::bad_request(format!(
                        "chaos: unknown action {other:?} (try \"list\", \"arm\" or \"disarm\")"
                    )))
                }
                None => {
                    return Err(ApiError::bad_request(format!(
                        "chaos: \"action\" must be a string, got {v}"
                    )))
                }
            },
        };
        Ok(Self { action })
    }
}

/// A decoded coordinator request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    Stats,
    Shutdown,
    Jobs,
    ListPolicies,
    ListScenarios,
    /// v2 only: the machine-readable op/field schema.
    Describe,
    Plan(PlanRequest),
    Simulate(SimulateRequest),
    Sweep(SweepRequest),
    Campaign(CampaignRequest),
    EstimatePerf(EstimatePerfRequest),
    Submit(SubmitRequest),
    Status(StatusRequest),
    Cancel(CancelRequest),
    /// v2 only: journal + cache statistics and manual compaction.
    Persist(PersistRequest),
    /// v2 only: overall status + per-subsystem degradation report.
    Health,
    /// v2 only (gated by `--chaos-allowed`): the failpoint registry.
    Chaos(ChaosRequest),
}

impl Request {
    /// The wire op name.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Stats => "stats",
            Request::Shutdown => "shutdown",
            Request::Jobs => "jobs",
            Request::ListPolicies => "list_policies",
            Request::ListScenarios => "list_scenarios",
            Request::Describe => "describe",
            Request::Plan(_) => "plan",
            Request::Simulate(_) => "simulate",
            Request::Sweep(_) => "sweep",
            Request::Campaign(_) => "campaign",
            Request::EstimatePerf(_) => "estimate_perf",
            Request::Submit(_) => "submit",
            Request::Status(_) => "status",
            Request::Cancel(_) => "cancel",
            Request::Persist(_) => "persist",
            Request::Health => "health",
            Request::Chaos(_) => "chaos",
        }
    }

    /// The request's policy name, when the op carries one.
    pub fn policy(&self) -> Option<&str> {
        match self {
            Request::Plan(r) => r.params.policy.as_deref(),
            Request::Simulate(r) => r.params.policy.as_deref(),
            Request::Campaign(r) => r.params.policy.as_deref(),
            _ => None,
        }
    }

    /// Decode a parsed request object.  Field validation errors carry
    /// the exact historical message strings (pinned by the v1 parity
    /// tests); for a request with *several* invalid fields the one
    /// reported first may differ from the legacy parser, which
    /// interleaved field checks with dispatch-time work.  One deliberate
    /// tightening: `priority`/`deadline_ms` on a sweep/campaign are now
    /// validated wherever the request appears — including inside an
    /// async `submit` job object, where the legacy path silently
    /// ignored them (placement rides on the *outer* submit).
    pub fn decode(j: &Json) -> Result<Request, ApiError> {
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| ApiError::bad_request("missing \"op\""))?;
        Ok(match op {
            "ping" => Request::Ping,
            "stats" => Request::Stats,
            "shutdown" => Request::Shutdown,
            "jobs" => Request::Jobs,
            "list_policies" => Request::ListPolicies,
            "list_scenarios" => Request::ListScenarios,
            "describe" => Request::Describe,
            "plan" => Request::Plan(PlanRequest {
                params: SolveParams::decode(j)?,
                target: SystemRef::decode(j)?,
                detail: j.get("detail").and_then(Json::as_bool).unwrap_or(false),
            }),
            "simulate" => Request::Simulate(SimulateRequest {
                params: SolveParams::decode(j)?,
                target: SystemRef::decode(j)?,
                noise: j.get("noise").map(NoiseSpec::decode),
            }),
            "sweep" => Request::Sweep(SweepRequest {
                target: SystemRef::decode(j)?,
                budgets: j
                    .get("budgets")
                    .and_then(Json::as_arr)
                    .map(|arr| arr.iter().filter_map(Json::as_f64).collect()),
                threads: bounded_threads_field(j)?,
                placement: Placement::decode(j)?,
            }),
            "campaign" => {
                let replications = strict_u64(j, "replications")?;
                if let Some(r) = replications {
                    if r > MAX_REPLICATIONS {
                        return Err(ApiError::bad_request(format!(
                            "replications {r} exceeds the limit of {MAX_REPLICATIONS}"
                        )));
                    }
                }
                Request::Campaign(CampaignRequest {
                    params: SolveParams::decode(j)?,
                    target: SystemRef::decode(j)?,
                    noise: j.get("noise").map(NoiseSpec::decode),
                    max_rounds: j.get("max_rounds").and_then(Json::as_u64),
                    replications,
                    placement: Placement::decode(j)?,
                })
            }
            "estimate_perf" => Request::EstimatePerf(EstimatePerfRequest {
                target: SystemRef::decode(j)?,
                per_cell: j.get("per_cell").and_then(Json::as_u64),
                noise: j.get("noise").map(NoiseSpec::decode),
                seed: j.get("seed").and_then(Json::as_u64),
            }),
            "submit" => {
                let job = j
                    .get("job")
                    .ok_or_else(|| ApiError::bad_request("submit: missing \"job\" object"))?
                    .clone();
                let inner_op = job
                    .get("op")
                    .and_then(Json::as_str)
                    .ok_or_else(|| ApiError::bad_request("submit: job missing \"op\""))?;
                if matches!(inner_op, "submit" | "shutdown" | "status" | "jobs" | "cancel") {
                    return Err(ApiError::bad_request(format!(
                        "submit: op {inner_op:?} cannot run as a job"
                    )));
                }
                Request::Submit(SubmitRequest { job, placement: Placement::decode(j)? })
            }
            "status" => Request::Status(StatusRequest {
                job_id: j
                    .get("job_id")
                    .and_then(Json::as_str)
                    .ok_or_else(|| ApiError::bad_request("status: missing \"job_id\""))?
                    .to_string(),
                partials_from: strict_u64(j, "partials_from")?,
            }),
            "cancel" => Request::Cancel(CancelRequest {
                job_id: j
                    .get("job_id")
                    .and_then(Json::as_str)
                    .ok_or_else(|| ApiError::bad_request("cancel: missing \"job_id\""))?
                    .to_string(),
            }),
            "persist" => Request::Persist(PersistRequest::decode(j)?),
            "health" => Request::Health,
            "chaos" => Request::Chaos(ChaosRequest::decode(j)?),
            _ => {
                return Err(ApiError::unknown_op(
                    "no such op (try list_policies, list_scenarios, describe, persist, health, \
                     chaos, plan, sweep, simulate, campaign, estimate_perf, submit, status, \
                     jobs, cancel, stats, ping, shutdown)",
                ))
            }
        })
    }

    /// Encode to the canonical wire object (no `"v"`; see
    /// [`Request::encode_versioned`]).
    pub fn encode(&self) -> Json {
        let mut fields: Vec<(&'static str, Json)> = vec![("op", Json::str(self.op()))];
        match self {
            Request::Ping
            | Request::Stats
            | Request::Shutdown
            | Request::Jobs
            | Request::ListPolicies
            | Request::ListScenarios
            | Request::Describe
            | Request::Health => {}
            Request::Plan(r) => {
                r.params.encode_into(&mut fields);
                r.target.encode_into(&mut fields);
                if r.detail {
                    fields.push(("detail", Json::Bool(true)));
                }
            }
            Request::Simulate(r) => {
                r.params.encode_into(&mut fields);
                r.target.encode_into(&mut fields);
                if let Some(n) = &r.noise {
                    fields.push(("noise", n.encode()));
                }
            }
            Request::Sweep(r) => {
                r.target.encode_into(&mut fields);
                if let Some(b) = &r.budgets {
                    fields.push(("budgets", Json::arr(b.iter().map(|x| Json::num(*x)))));
                }
                if let Some(t) = r.threads {
                    fields.push(("threads", Json::num(t as f64)));
                }
                r.placement.encode_into(&mut fields);
            }
            Request::Campaign(r) => {
                r.params.encode_into(&mut fields);
                r.target.encode_into(&mut fields);
                if let Some(n) = &r.noise {
                    fields.push(("noise", n.encode()));
                }
                if let Some(m) = r.max_rounds {
                    fields.push(("max_rounds", Json::num(m as f64)));
                }
                if let Some(n) = r.replications {
                    fields.push(("replications", Json::num(n as f64)));
                }
                r.placement.encode_into(&mut fields);
            }
            Request::EstimatePerf(r) => {
                r.target.encode_into(&mut fields);
                if let Some(n) = r.per_cell {
                    fields.push(("per_cell", Json::num(n as f64)));
                }
                if let Some(n) = &r.noise {
                    fields.push(("noise", n.encode()));
                }
                if let Some(s) = r.seed {
                    fields.push(("seed", Json::num(s as f64)));
                }
            }
            Request::Submit(r) => {
                r.placement.encode_into(&mut fields);
                fields.push(("job", r.job.clone()));
            }
            Request::Status(r) => {
                fields.push(("job_id", Json::str(&r.job_id)));
                if let Some(f) = r.partials_from {
                    fields.push(("partials_from", Json::num(f as f64)));
                }
            }
            Request::Cancel(r) => {
                fields.push(("job_id", Json::str(&r.job_id)));
            }
            Request::Persist(r) => {
                // Stats is the default: encode it bare so the canonical
                // wire form round-trips.
                if r.action == PersistAction::Compact {
                    fields.push(("action", Json::str("compact")));
                }
            }
            Request::Chaos(r) => match &r.action {
                // List is the default: encode it bare so the canonical
                // wire form round-trips.
                ChaosAction::List => {}
                ChaosAction::Arm(spec) => {
                    fields.push(("action", Json::str("arm")));
                    fields.push(("spec", Json::str(spec)));
                }
                ChaosAction::Disarm(point) => {
                    fields.push(("action", Json::str("disarm")));
                    if let Some(p) = point {
                        fields.push(("point", Json::str(p)));
                    }
                }
            },
        }
        Json::obj(fields)
    }

    /// Encode with an explicit protocol version field.
    pub fn encode_versioned(&self, v: u8) -> Json {
        let mut j = self.encode();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), Json::num(f64::from(v)));
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Responses: one struct per op, encoding to the exact historical shapes.

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("reply missing \"{key}\": {j}"))
}

fn need_f64(j: &Json, key: &str) -> Result<f64, String> {
    need(j, key)?.as_f64().ok_or_else(|| format!("reply field \"{key}\" not a number"))
}

fn need_u64(j: &Json, key: &str) -> Result<u64, String> {
    need(j, key)?.as_u64().ok_or_else(|| format!("reply field \"{key}\" not an integer"))
}

fn need_str(j: &Json, key: &str) -> Result<String, String> {
    Ok(need(j, key)?
        .as_str()
        .ok_or_else(|| format!("reply field \"{key}\" not a string"))?
        .to_string())
}

fn need_bool(j: &Json, key: &str) -> Result<bool, String> {
    need(j, key)?.as_bool().ok_or_else(|| format!("reply field \"{key}\" not a bool"))
}

/// A registered policy, as listed by `list_policies`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyInfo {
    pub name: String,
    pub description: String,
}

/// A named scenario, as listed by `list_scenarios`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioInfo {
    pub name: String,
    pub description: String,
}

/// One VM row of a `plan` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct VmRow {
    pub instance_type: String,
    pub tasks: u64,
    pub exec: f64,
    pub cost: f64,
}

/// The `plan` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanResponse {
    pub policy: String,
    /// Legacy field: the historical spelling of the policy name.
    pub approach: String,
    pub budget: f64,
    pub effective_budget: f64,
    pub makespan: f64,
    pub cost: f64,
    pub feasible: bool,
    pub iterations: u64,
    pub probes: u64,
    pub vms: Vec<VmRow>,
    /// Full task-level assignment (`detail: true` requests only).
    pub plan: Option<Json>,
}

impl PlanResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        let vms = need(j, "vms")?
            .as_arr()
            .ok_or("reply field \"vms\" not an array")?
            .iter()
            .map(|vm| {
                Ok(VmRow {
                    instance_type: need_str(vm, "instance_type")?,
                    tasks: need_u64(vm, "tasks")?,
                    exec: need_f64(vm, "exec")?,
                    cost: need_f64(vm, "cost")?,
                })
            })
            .collect::<Result<_, String>>()?;
        Ok(Self {
            policy: need_str(j, "policy")?,
            approach: need_str(j, "approach")?,
            budget: need_f64(j, "budget")?,
            effective_budget: need_f64(j, "effective_budget")?,
            makespan: need_f64(j, "makespan")?,
            cost: need_f64(j, "cost")?,
            feasible: need_bool(j, "feasible")?,
            iterations: need_u64(j, "iterations")?,
            probes: need_u64(j, "probes")?,
            vms,
            plan: j.get("plan").cloned(),
        })
    }
}

/// The `simulate` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateResponse {
    pub policy: String,
    pub planned_feasible: bool,
    pub makespan: f64,
    pub cost: f64,
    pub completed: u64,
    pub stranded: u64,
    pub failures: u64,
}

impl SimulateResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        Ok(Self {
            policy: need_str(j, "policy")?,
            planned_feasible: need_bool(j, "planned_feasible")?,
            makespan: need_f64(j, "makespan")?,
            cost: need_f64(j, "cost")?,
            completed: need_u64(j, "completed")?,
            stranded: need_u64(j, "stranded")?,
            failures: need_u64(j, "failures")?,
        })
    }
}

/// The `sweep` reply: the full report object (schema documented in
/// `analysis::report`; kept as a payload because it nests per-cell rows
/// that downstream tooling consumes as JSON anyway).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepResponse {
    pub sweep: Json,
}

impl SweepResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        Ok(Self { sweep: need(j, "sweep")?.clone() })
    }
}

/// One Monte-Carlo replication row of a replicated `campaign` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRow {
    pub wall_clock: f64,
    pub spent: f64,
    pub complete: bool,
    pub within_budget: bool,
    pub rounds: u64,
}

impl RunRow {
    fn decode(j: &Json) -> Result<Self, String> {
        Ok(Self {
            wall_clock: need_f64(j, "wall_clock")?,
            spent: need_f64(j, "spent")?,
            complete: need_bool(j, "complete")?,
            within_budget: need_bool(j, "within_budget")?,
            rounds: need_u64(j, "rounds")?,
        })
    }

    fn encode(&self) -> Json {
        Json::obj(vec![
            ("wall_clock", Json::num(self.wall_clock)),
            ("spent", Json::num(self.spent)),
            ("complete", Json::Bool(self.complete)),
            ("within_budget", Json::Bool(self.within_budget)),
            ("rounds", Json::num(self.rounds as f64)),
        ])
    }
}

/// The `campaign` reply: a single closed-loop run, or a Monte-Carlo
/// aggregate over `replications` runs.
#[derive(Debug, Clone, PartialEq)]
pub enum CampaignResponse {
    Single {
        policy: String,
        wall_clock: f64,
        spent: f64,
        complete: bool,
        within_budget: bool,
        rounds: u64,
        planned_makespan: f64,
        cancelled: bool,
    },
    Replicated {
        policy: String,
        replications: u64,
        cancelled: bool,
        /// Absent only when a cancel fired before any replication ran.
        summary: Option<ReplicationSummary>,
    },
}

/// The aggregate block of a replicated campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicationSummary {
    pub complete_frac: f64,
    pub within_budget_frac: f64,
    pub mean_wall_clock: f64,
    pub mean_spent: f64,
    pub runs: Vec<RunRow>,
}

impl CampaignResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        let cancelled = j.get("cancelled").and_then(Json::as_bool).unwrap_or(false);
        if j.get("replications").is_some() {
            let summary = if j.get("runs").is_some() {
                Some(ReplicationSummary {
                    complete_frac: need_f64(j, "complete_frac")?,
                    within_budget_frac: need_f64(j, "within_budget_frac")?,
                    mean_wall_clock: need_f64(j, "mean_wall_clock")?,
                    mean_spent: need_f64(j, "mean_spent")?,
                    runs: need(j, "runs")?
                        .as_arr()
                        .ok_or("reply field \"runs\" not an array")?
                        .iter()
                        .map(RunRow::decode)
                        .collect::<Result<_, String>>()?,
                })
            } else {
                None
            };
            return Ok(CampaignResponse::Replicated {
                policy: need_str(j, "policy")?,
                replications: need_u64(j, "replications")?,
                cancelled,
                summary,
            });
        }
        Ok(CampaignResponse::Single {
            policy: need_str(j, "policy")?,
            wall_clock: need_f64(j, "wall_clock")?,
            spent: need_f64(j, "spent")?,
            complete: need_bool(j, "complete")?,
            within_budget: need_bool(j, "within_budget")?,
            rounds: need_u64(j, "rounds")?,
            planned_makespan: need_f64(j, "planned_makespan")?,
            cancelled,
        })
    }
}

/// The `estimate_perf` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct EstimatePerfResponse {
    pub samples: u64,
    pub estimate: Vec<f64>,
    pub max_rel_error: f64,
}

impl EstimatePerfResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        Ok(Self {
            samples: need_u64(j, "samples")?,
            estimate: need(j, "estimate")?
                .as_arr()
                .ok_or("reply field \"estimate\" not an array")?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| "non-numeric estimate entry".to_string()))
                .collect::<Result<_, String>>()?,
            max_rel_error: need_f64(j, "max_rel_error")?,
        })
    }
}

/// One shard's queue gauges in a `stats` reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRow {
    pub shard: u64,
    pub depth: u64,
    pub high_water: u64,
    pub rejected: u64,
}

/// The engine block of a `stats` reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineInfo {
    pub shards: u64,
    pub queued: u64,
    pub max_backlog: u64,
    pub shard_stats: Vec<ShardRow>,
}

/// The `stats` reply: request/job metrics (schema owned by
/// [`super::Metrics::snapshot`]) plus the typed engine gauges.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsResponse {
    pub stats: Json,
    pub engine: EngineInfo,
}

impl StatsResponse {
    pub fn decode(j: &Json) -> Result<Self, String> {
        let e = need(j, "engine")?;
        let shard_stats = need(e, "shard_stats")?
            .as_arr()
            .ok_or("reply field \"shard_stats\" not an array")?
            .iter()
            .map(|s| {
                Ok(ShardRow {
                    shard: need_u64(s, "shard")?,
                    depth: need_u64(s, "depth")?,
                    high_water: need_u64(s, "high_water")?,
                    rejected: need_u64(s, "rejected")?,
                })
            })
            .collect::<Result<_, String>>()?;
        Ok(Self {
            stats: need(j, "stats")?.clone(),
            engine: EngineInfo {
                shards: need_u64(e, "shards")?,
                queued: need_u64(e, "queued")?,
                max_backlog: need_u64(e, "max_backlog")?,
                shard_stats,
            },
        })
    }

    /// A counter from the metrics block (0 when absent — the block's
    /// schema is owned by [`super::Metrics::snapshot`], so a missing key
    /// means an older server, not an error).
    pub fn counter(&self, key: &str) -> u64 {
        self.stats.get(key).and_then(Json::as_u64).unwrap_or(0)
    }

    /// Jobs the server shed past their binding deadline.  SLO reports
    /// reconcile this against client-observed `deadline_exceeded`
    /// replies (the server also counts sweeper/queue sheds that never
    /// reach a synchronous caller).
    pub fn jobs_deadline_exceeded(&self) -> u64 {
        self.counter("jobs_deadline_exceeded")
    }

    /// Jobs rejected with `busy` at admission.
    pub fn jobs_rejected(&self) -> u64 {
        self.counter("jobs_rejected")
    }

    /// A queue-wait percentile in microseconds (e.g. `"p50"`, `"p95"`).
    pub fn queue_wait_us(&self, pct: &str) -> f64 {
        self.stats.get(&format!("queue_wait_us_{pct}")).and_then(Json::as_f64).unwrap_or(0.0)
    }
}

/// A decoded coordinator reply.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Bye,
    Policies(Vec<PolicyInfo>),
    Scenarios(Vec<ScenarioInfo>),
    /// The `describe` reply: the op/field schema ([`describe_schema`]).
    Schema(Json),
    Plan(Box<PlanResponse>),
    Simulate(SimulateResponse),
    Sweep(SweepResponse),
    Campaign(CampaignResponse),
    EstimatePerf(EstimatePerfResponse),
    Stats(StatsResponse),
    Submitted { job_id: String },
    /// The `status` reply: the job object (schema owned by
    /// [`super::state::JobRegistry`]; `super::client::JobStatus` is the
    /// typed view).
    Status { job: Json },
    Jobs { jobs: Json },
    Cancelled { cancelled: bool },
    /// The `persist` reply: journal + cache durability statistics
    /// (schema owned by the protocol layer's `op_persist`).
    Persist { persist: Json },
    /// The `health` reply: overall status + per-subsystem detail
    /// (schema owned by the protocol layer's `op_health`;
    /// `super::client::HealthReport` is the typed view).
    Health { health: Json },
    /// The `chaos` reply: the failpoint table (schema owned by the
    /// protocol layer's `op_chaos`).
    Chaos { chaos: Json },
}

impl Response {
    /// Whether this reply instructs the server to shut down.
    pub fn is_shutdown(&self) -> bool {
        matches!(self, Response::Bye)
    }

    /// Encode to the wire body.  Shapes are byte-identical to the
    /// historical per-op builders (object keys sort, so field order is
    /// canonical by construction).
    pub fn encode(&self) -> Json {
        let ok = ("ok", Json::Bool(true));
        match self {
            Response::Pong => Json::obj(vec![ok, ("pong", Json::Bool(true))]),
            Response::Bye => Json::obj(vec![ok, ("bye", Json::Bool(true))]),
            Response::Policies(ps) => Json::obj(vec![
                ok,
                (
                    "policies",
                    Json::arr(ps.iter().map(|p| {
                        Json::obj(vec![
                            ("name", Json::str(&p.name)),
                            ("description", Json::str(&p.description)),
                        ])
                    })),
                ),
            ]),
            Response::Scenarios(ss) => Json::obj(vec![
                ok,
                (
                    "scenarios",
                    Json::arr(ss.iter().map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(&s.name)),
                            ("description", Json::str(&s.description)),
                        ])
                    })),
                ),
            ]),
            Response::Schema(schema) => Json::obj(vec![ok, ("schema", schema.clone())]),
            Response::Plan(r) => {
                let mut fields = vec![
                    ok,
                    ("policy", Json::str(&r.policy)),
                    ("approach", Json::str(&r.approach)),
                    ("budget", Json::num(r.budget)),
                    ("effective_budget", Json::num(r.effective_budget)),
                    ("makespan", Json::num(r.makespan)),
                    ("cost", Json::num(r.cost)),
                    ("feasible", Json::Bool(r.feasible)),
                    ("iterations", Json::num(r.iterations as f64)),
                    ("probes", Json::num(r.probes as f64)),
                    ("n_vms", Json::num(r.vms.len() as f64)),
                    (
                        "vms",
                        Json::arr(r.vms.iter().map(|vm| {
                            Json::obj(vec![
                                ("instance_type", Json::str(&vm.instance_type)),
                                ("tasks", Json::num(vm.tasks as f64)),
                                ("exec", Json::num(vm.exec)),
                                ("cost", Json::num(vm.cost)),
                            ])
                        })),
                    ),
                ];
                if let Some(plan) = &r.plan {
                    fields.push(("plan", plan.clone()));
                }
                Json::obj(fields)
            }
            Response::Simulate(r) => Json::obj(vec![
                ok,
                ("policy", Json::str(&r.policy)),
                ("planned_feasible", Json::Bool(r.planned_feasible)),
                ("makespan", Json::num(r.makespan)),
                ("cost", Json::num(r.cost)),
                ("completed", Json::num(r.completed as f64)),
                ("stranded", Json::num(r.stranded as f64)),
                ("failures", Json::num(r.failures as f64)),
            ]),
            Response::Sweep(r) => Json::obj(vec![ok, ("sweep", r.sweep.clone())]),
            Response::Campaign(CampaignResponse::Single {
                policy,
                wall_clock,
                spent,
                complete,
                within_budget,
                rounds,
                planned_makespan,
                cancelled,
            }) => {
                let mut fields = vec![
                    ok,
                    ("policy", Json::str(policy)),
                    ("wall_clock", Json::num(*wall_clock)),
                    ("spent", Json::num(*spent)),
                    ("complete", Json::Bool(*complete)),
                    ("within_budget", Json::Bool(*within_budget)),
                    ("rounds", Json::num(*rounds as f64)),
                    ("planned_makespan", Json::num(*planned_makespan)),
                ];
                if *cancelled {
                    fields.push(("cancelled", Json::Bool(true)));
                }
                Json::obj(fields)
            }
            Response::Campaign(CampaignResponse::Replicated {
                policy,
                replications,
                cancelled,
                summary,
            }) => {
                let mut fields = vec![
                    ok,
                    ("policy", Json::str(policy)),
                    ("replications", Json::num(*replications as f64)),
                ];
                if *cancelled {
                    fields.push(("cancelled", Json::Bool(true)));
                }
                if let Some(s) = summary {
                    fields.extend([
                        ("complete_frac", Json::num(s.complete_frac)),
                        ("within_budget_frac", Json::num(s.within_budget_frac)),
                        ("mean_wall_clock", Json::num(s.mean_wall_clock)),
                        ("mean_spent", Json::num(s.mean_spent)),
                        ("runs", Json::arr(s.runs.iter().map(RunRow::encode))),
                    ]);
                }
                Json::obj(fields)
            }
            Response::EstimatePerf(r) => Json::obj(vec![
                ok,
                ("samples", Json::num(r.samples as f64)),
                ("estimate", Json::arr(r.estimate.iter().map(|p| Json::num(*p)))),
                ("max_rel_error", Json::num(r.max_rel_error)),
            ]),
            Response::Stats(r) => Json::obj(vec![
                ok,
                ("stats", r.stats.clone()),
                (
                    "engine",
                    Json::obj(vec![
                        ("shards", Json::num(r.engine.shards as f64)),
                        ("queued", Json::num(r.engine.queued as f64)),
                        ("max_backlog", Json::num(r.engine.max_backlog as f64)),
                        (
                            "shard_stats",
                            Json::arr(r.engine.shard_stats.iter().map(|s| {
                                Json::obj(vec![
                                    ("shard", Json::num(s.shard as f64)),
                                    ("depth", Json::num(s.depth as f64)),
                                    ("high_water", Json::num(s.high_water as f64)),
                                    ("rejected", Json::num(s.rejected as f64)),
                                ])
                            })),
                        ),
                    ]),
                ),
            ]),
            Response::Submitted { job_id } => {
                Json::obj(vec![ok, ("job_id", Json::str(job_id))])
            }
            Response::Status { job } => Json::obj(vec![ok, ("job", job.clone())]),
            Response::Jobs { jobs } => Json::obj(vec![ok, ("jobs", jobs.clone())]),
            Response::Cancelled { cancelled } => {
                Json::obj(vec![ok, ("cancelled", Json::Bool(*cancelled))])
            }
            Response::Persist { persist } => Json::obj(vec![ok, ("persist", persist.clone())]),
            Response::Health { health } => Json::obj(vec![ok, ("health", health.clone())]),
            Response::Chaos { chaos } => Json::obj(vec![ok, ("chaos", chaos.clone())]),
        }
    }
}

// ---------------------------------------------------------------------------
// The machine-readable schema (`describe`, v2).

/// One request field in the schema table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FieldSpec {
    pub name: &'static str,
    pub ty: &'static str,
    pub required: bool,
}

/// One op in the schema table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpSpec {
    pub name: &'static str,
    pub doc: &'static str,
    pub fields: &'static [FieldSpec],
}

const fn f(name: &'static str, ty: &'static str, required: bool) -> FieldSpec {
    FieldSpec { name, ty, required }
}

/// The system-selector fields shared by planning/simulation ops.
const TARGET_FIELDS: [FieldSpec; 3] = [
    f("system", "string|object", false),
    f("scenario", "string", false),
    f("overhead", "number", false),
];

const SOLVE_FIELDS: [FieldSpec; 10] = [
    f("budget", "number", true),
    f("policy", "string", false),
    f("approach", "string", false),
    f("deadline", "number", false),
    f("seed", "integer", false),
    f("n_starts", "integer", false),
    f("perf_jitter", "number", false),
    f("sample_frac", "number", false),
    f("threads", "integer", false),
    f("remaining", "array[integer]", false),
];

/// The full op table the coordinator serves.  `describe` renders it;
/// the drift tests snapshot that rendering and assert the table covers
/// every [`Request`] variant.
pub const OP_SPECS: &[OpSpec] = &[
    OpSpec { name: "ping", doc: "liveness probe", fields: &[] },
    OpSpec { name: "stats", doc: "request metrics + engine queue gauges", fields: &[] },
    OpSpec {
        name: "health",
        doc: "overall status + per-subsystem degradation report (v2 only)",
        fields: &[],
    },
    OpSpec { name: "list_policies", doc: "registered scheduling policies", fields: &[] },
    OpSpec { name: "list_scenarios", doc: "named workload presets", fields: &[] },
    OpSpec { name: "describe", doc: "this schema (v2 only)", fields: &[] },
    OpSpec {
        name: "persist",
        doc: "journal + cache durability stats; action \"compact\" rewrites the journal (v2 only)",
        fields: &[f("action", "string", false)],
    },
    OpSpec {
        name: "chaos",
        doc: "inspect/arm/disarm fault-injection points (v2 only, requires --chaos-allowed)",
        fields: &[
            f("action", "string", false),
            f("spec", "string", false),
            f("point", "string", false),
        ],
    },
    OpSpec {
        name: "plan",
        doc: "solve one budget through a named policy",
        fields: &[
            SOLVE_FIELDS[0], SOLVE_FIELDS[1], SOLVE_FIELDS[2], SOLVE_FIELDS[3],
            SOLVE_FIELDS[4], SOLVE_FIELDS[5], SOLVE_FIELDS[6], SOLVE_FIELDS[7],
            SOLVE_FIELDS[8], SOLVE_FIELDS[9],
            f("planner", "object", false),
            TARGET_FIELDS[0], TARGET_FIELDS[1], TARGET_FIELDS[2],
            f("detail", "bool", false),
        ],
    },
    OpSpec {
        name: "simulate",
        doc: "plan + execute once on the simulated cloud",
        fields: &[
            SOLVE_FIELDS[0], SOLVE_FIELDS[1], SOLVE_FIELDS[2], SOLVE_FIELDS[3],
            SOLVE_FIELDS[4], SOLVE_FIELDS[5], SOLVE_FIELDS[6], SOLVE_FIELDS[7],
            SOLVE_FIELDS[8], SOLVE_FIELDS[9],
            f("planner", "object", false),
            TARGET_FIELDS[0], TARGET_FIELDS[1], TARGET_FIELDS[2],
            f("noise", "object", false),
        ],
    },
    OpSpec {
        name: "sweep",
        doc: "budget x policy sweep (runs on the job engine)",
        fields: &[
            f("budgets", "array[number]", false),
            f("threads", "integer", false),
            TARGET_FIELDS[0], TARGET_FIELDS[1], TARGET_FIELDS[2],
            f("priority", "integer", false),
            f("deadline_ms", "integer", false),
        ],
    },
    OpSpec {
        name: "campaign",
        doc: "closed-loop execution with failures + replanning (runs on the job engine)",
        fields: &[
            SOLVE_FIELDS[0], SOLVE_FIELDS[1], SOLVE_FIELDS[2], SOLVE_FIELDS[3],
            SOLVE_FIELDS[4], SOLVE_FIELDS[5], SOLVE_FIELDS[6], SOLVE_FIELDS[7],
            SOLVE_FIELDS[8],
            f("planner", "object", false),
            TARGET_FIELDS[0], TARGET_FIELDS[1], TARGET_FIELDS[2],
            f("noise", "object", false),
            f("max_rounds", "integer", false),
            f("replications", "integer", false),
            f("priority", "integer", false),
            f("deadline_ms", "integer", false),
        ],
    },
    OpSpec {
        name: "estimate_perf",
        doc: "bootstrap the performance matrix from sampled runs",
        fields: &[
            f("per_cell", "integer", false),
            f("noise", "object", false),
            f("seed", "integer", false),
            TARGET_FIELDS[0], TARGET_FIELDS[1], TARGET_FIELDS[2],
        ],
    },
    OpSpec {
        name: "submit",
        doc: "run any planning op asynchronously on the sharded engine",
        fields: &[
            f("job", "object", true),
            f("priority", "integer", false),
            f("deadline_ms", "integer", false),
        ],
    },
    OpSpec {
        name: "status",
        doc: "job state, progress and streaming partial results",
        fields: &[f("job_id", "string", true), f("partials_from", "integer", false)],
    },
    OpSpec { name: "jobs", doc: "all jobs with state + progress", fields: &[] },
    OpSpec {
        name: "cancel",
        doc: "fire a job's cancel token",
        fields: &[f("job_id", "string", true)],
    },
    OpSpec { name: "shutdown", doc: "stop the coordinator", fields: &[] },
];

/// Render the schema `describe` returns: versions, error codes, the op
/// table and the scenario names.  Deterministic (object keys sort), so
/// the drift test can snapshot its exact serialisation.
pub fn describe_schema() -> Json {
    Json::obj(vec![
        ("v", Json::num(f64::from(V2))),
        ("versions", Json::arr([Json::num(1.0), Json::num(2.0)])),
        (
            "error_codes",
            Json::arr(ERROR_CODES.iter().map(|c| Json::str(c.as_str()))),
        ),
        (
            "ops",
            Json::arr(OP_SPECS.iter().map(|op| {
                Json::obj(vec![
                    ("op", Json::str(op.name)),
                    ("doc", Json::str(op.doc)),
                    (
                        "fields",
                        Json::arr(op.fields.iter().map(|fs| {
                            Json::obj(vec![
                                ("name", Json::str(fs.name)),
                                ("type", Json::str(fs.ty)),
                                ("required", Json::Bool(fs.required)),
                            ])
                        })),
                    ),
                ])
            })),
        ),
        (
            "scenarios",
            Json::arr(crate::workload::scenario_names().into_iter().map(Json::str)),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_negotiation() {
        assert_eq!(version_of(&Json::parse(r#"{"op":"ping"}"#).unwrap()).unwrap(), V1);
        assert_eq!(version_of(&Json::parse(r#"{"op":"ping","v":1}"#).unwrap()).unwrap(), V1);
        assert_eq!(version_of(&Json::parse(r#"{"op":"ping","v":2}"#).unwrap()).unwrap(), V2);
        for bad in [r#"{"v":3}"#, r#"{"v":0}"#, r#"{"v":"2"}"#, r#"{"v":1.5}"#] {
            let e = version_of(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn decode_keeps_historical_error_strings() {
        let dec = |s: &str| Request::decode(&Json::parse(s).unwrap());
        assert_eq!(dec(r#"{"nop":1}"#).unwrap_err().message, "missing \"op\"");
        assert_eq!(dec(r#"{"op":"plan"}"#).unwrap_err().message, "missing \"budget\"");
        // Historical quirk kept for parity: a mistyped budget reports
        // as missing, exactly like the legacy wire path.
        assert_eq!(
            dec(r#"{"op":"plan","budget":"80"}"#).unwrap_err().message,
            "missing \"budget\""
        );
        assert_eq!(
            dec(r#"{"op":"plan","budget":10,"threads":"x"}"#).unwrap_err().message,
            "\"threads\" must be a non-negative integer, got \"x\""
        );
        assert_eq!(
            dec(r#"{"op":"plan","budget":10,"threads":9999}"#).unwrap_err().message,
            "threads 9999 exceeds the limit of 256"
        );
        assert_eq!(
            dec(r#"{"op":"submit"}"#).unwrap_err().message,
            "submit: missing \"job\" object"
        );
        assert_eq!(
            dec(r#"{"op":"submit","job":{"op":"shutdown"}}"#).unwrap_err().message,
            "submit: op \"shutdown\" cannot run as a job"
        );
        assert_eq!(
            dec(r#"{"op":"status"}"#).unwrap_err().message,
            "status: missing \"job_id\""
        );
        let e = dec(r#"{"op":"nope"}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownOp);
        assert!(e.message.contains("list_policies"), "{}", e.message);
        assert!(e.message.contains("describe"), "{}", e.message);
    }

    #[test]
    fn stats_response_lifts_shed_counters() {
        let j = Json::parse(
            r#"{"stats":{"jobs_deadline_exceeded":3,"jobs_rejected":7,"queue_wait_us_p50":250},
                "engine":{"shards":1,"queued":0,"max_backlog":4,"shard_stats":[]}}"#,
        )
        .unwrap();
        let s = StatsResponse::decode(&j).unwrap();
        assert_eq!(s.jobs_deadline_exceeded(), 3);
        assert_eq!(s.jobs_rejected(), 7);
        assert_eq!(s.queue_wait_us("p50"), 250.0);
        // Absent keys read as zero, not as an error.
        assert_eq!(s.queue_wait_us("p95"), 0.0);
        assert_eq!(s.counter("no_such_counter"), 0);
    }

    #[test]
    fn placement_bounds_match_config() {
        let dec = |s: &str| Request::decode(&Json::parse(s).unwrap());
        let e = dec(r#"{"op":"submit","priority":12,"job":{"op":"ping"}}"#).unwrap_err();
        assert!(e.message.contains("0..=9"), "{}", e.message);
        let e = dec(r#"{"op":"submit","deadline_ms":99999999999999,"job":{"op":"ping"}}"#)
            .unwrap_err();
        assert!(e.message.contains("exceeds the limit"), "{}", e.message);
        // The api decoder and the config decoder agree on every case.
        for s in [
            r#"{"priority":9,"deadline_ms":2500}"#,
            r#"{"priority":10}"#,
            r#"{"priority":"urgent"}"#,
            r#"{"deadline_ms":1.5}"#,
            r#"{}"#,
        ] {
            let j = Json::parse(s).unwrap();
            let api = Placement::decode(&j);
            let cfg = crate::config::job_priority_from_json(&j);
            assert_eq!(api.is_ok(), cfg.is_ok(), "{s}");
            if let (Ok(a), Ok(c)) = (api, cfg) {
                assert_eq!(a.job_priority(), c, "{s}");
            }
        }
    }

    #[test]
    fn request_decode_canonicalises_the_legacy_approach_spelling() {
        let j = Json::parse(r#"{"op":"plan","budget":20,"approach":"mp"}"#).unwrap();
        let Request::Plan(r) = Request::decode(&j).unwrap() else { panic!() };
        assert_eq!(r.params.policy.as_deref(), Some("mp"));
        assert_eq!(
            r#"{"budget":20,"op":"plan","policy":"mp"}"#,
            Request::Plan(r).encode().to_string()
        );
    }

    #[test]
    fn error_encodings() {
        let e = ApiError::busy(3, 256, Some(42));
        assert_eq!(
            e.encode_v1().to_string(),
            r#"{"backlog":256,"error":"busy","ok":false,"shard":3}"#
        );
        let v2 = e.encode_v2();
        assert_eq!(v2.path(&["error", "code"]).unwrap().as_str(), Some("busy"));
        assert_eq!(
            v2.path(&["error", "detail", "retry_after_ms"]).unwrap().as_u64(),
            Some(42)
        );
        let back = ApiError::decode(&v2).unwrap();
        assert_eq!(back.code, ErrorCode::Busy);
        assert_eq!(
            back.busy_info(),
            Some(BusyInfo { shard: 3, backlog: 256, retry_after_ms: Some(42) })
        );
        // The legacy shapes decode too.
        let legacy = ApiError::decode(&e.encode_v1()).unwrap();
        assert_eq!(legacy.busy_info().unwrap().shard, 3);
        assert_eq!(legacy.busy_info().unwrap().retry_after_ms, None);
        let plain = ApiError::bad_request("nope").encode_v1();
        assert_eq!(plain.to_string(), r#"{"error":"nope","ok":false}"#);
        assert!(ApiError::decode(&Json::parse(r#"{"ok":true}"#).unwrap()).is_none());
    }

    #[test]
    fn scenario_field_is_strict_and_exclusive() {
        let j = Json::parse(r#"{"op":"plan","budget":10,"scenario":7}"#).unwrap();
        let e = Request::decode(&j).unwrap_err();
        assert!(e.message.contains("scenario"), "{}", e.message);
        let j = Json::parse(r#"{"op":"plan","budget":10,"scenario":"paper","system":"paper"}"#)
            .unwrap();
        let Request::Plan(r) = Request::decode(&j).unwrap() else { panic!() };
        let e = r.target.resolve().unwrap_err();
        assert!(e.message.contains("mutually exclusive"), "{}", e.message);
        let e = SystemRef::scenario("warp9").resolve().unwrap_err();
        assert!(e.message.contains("unknown scenario"), "{}", e.message);
        assert!(e.message.contains("heavy-tail"), "{}", e.message);
        let sys = SystemRef::scenario("paper").resolve().unwrap();
        assert_eq!(sys.tasks().len(), 750);
    }

    #[test]
    fn op_table_covers_every_request_variant() {
        let table: Vec<&str> = OP_SPECS.iter().map(|o| o.name).collect();
        for op in [
            "ping", "stats", "shutdown", "jobs", "list_policies", "list_scenarios",
            "describe", "persist", "health", "chaos", "plan", "simulate", "sweep",
            "campaign", "estimate_perf", "submit", "status", "cancel",
        ] {
            assert!(table.contains(&op), "op {op:?} missing from OP_SPECS");
        }
        assert_eq!(table.len(), 18, "unknown extra op in OP_SPECS: {table:?}");
        let schema = describe_schema();
        assert_eq!(schema.get("ops").unwrap().as_arr().unwrap().len(), 18);
        assert_eq!(schema.get("error_codes").unwrap().as_arr().unwrap().len(), 8);
    }

    #[test]
    fn chaos_request_decodes_and_roundtrips() {
        let dec = |s: &str| Request::decode(&Json::parse(s).unwrap());
        assert_eq!(
            dec(r#"{"op":"chaos"}"#).unwrap(),
            Request::Chaos(ChaosRequest { action: ChaosAction::List })
        );
        assert_eq!(
            dec(r#"{"op":"chaos","action":"list"}"#).unwrap(),
            Request::Chaos(ChaosRequest { action: ChaosAction::List })
        );
        let arm = dec(r#"{"op":"chaos","action":"arm","spec":"journal.fsync=error@0.5"}"#)
            .unwrap();
        assert_eq!(
            arm,
            Request::Chaos(ChaosRequest {
                action: ChaosAction::Arm("journal.fsync=error@0.5".into()),
            })
        );
        assert_eq!(
            arm.encode().to_string(),
            r#"{"action":"arm","op":"chaos","spec":"journal.fsync=error@0.5"}"#
        );
        let disarm = dec(r#"{"op":"chaos","action":"disarm","point":"journal.fsync"}"#).unwrap();
        assert_eq!(
            disarm,
            Request::Chaos(ChaosRequest {
                action: ChaosAction::Disarm(Some("journal.fsync".into())),
            })
        );
        assert_eq!(
            disarm.encode().to_string(),
            r#"{"action":"disarm","op":"chaos","point":"journal.fsync"}"#
        );
        assert_eq!(
            dec(r#"{"op":"chaos","action":"disarm"}"#).unwrap(),
            Request::Chaos(ChaosRequest { action: ChaosAction::Disarm(None) })
        );
        // The canonical List encoding drops the default action.
        assert_eq!(
            Request::Chaos(ChaosRequest { action: ChaosAction::List }).encode().to_string(),
            r#"{"op":"chaos"}"#
        );
        let e = dec(r#"{"op":"chaos","action":"arm"}"#).unwrap_err();
        assert_eq!(e.message, "chaos: action \"arm\" requires a \"spec\" string");
        let e = dec(r#"{"op":"chaos","action":"explode"}"#).unwrap_err();
        assert_eq!(
            e.message,
            "chaos: unknown action \"explode\" (try \"list\", \"arm\" or \"disarm\")"
        );
        let e = dec(r#"{"op":"chaos","action":9}"#).unwrap_err();
        assert_eq!(e.message, "chaos: \"action\" must be a string, got 9");
    }

    #[test]
    fn persist_request_decodes_and_roundtrips() {
        let dec = |s: &str| Request::decode(&Json::parse(s).unwrap());
        assert_eq!(
            dec(r#"{"op":"persist"}"#).unwrap(),
            Request::Persist(PersistRequest { action: PersistAction::Stats })
        );
        assert_eq!(
            dec(r#"{"op":"persist","action":"stats"}"#).unwrap(),
            Request::Persist(PersistRequest { action: PersistAction::Stats })
        );
        let compact = dec(r#"{"op":"persist","action":"compact"}"#).unwrap();
        assert_eq!(
            compact,
            Request::Persist(PersistRequest { action: PersistAction::Compact })
        );
        assert_eq!(
            compact.encode().to_string(),
            r#"{"action":"compact","op":"persist"}"#
        );
        // The canonical Stats encoding drops the default action.
        assert_eq!(
            Request::Persist(PersistRequest { action: PersistAction::Stats })
                .encode()
                .to_string(),
            r#"{"op":"persist"}"#
        );
        let e = dec(r#"{"op":"persist","action":"flush"}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert_eq!(
            e.message,
            "persist: unknown action \"flush\" (try \"stats\" or \"compact\")"
        );
        let e = dec(r#"{"op":"persist","action":7}"#).unwrap_err();
        assert_eq!(e.message, "persist: \"action\" must be a string, got 7");
    }

    #[test]
    fn plan_cache_key_is_canonical() {
        let dec = |s: &str| match Request::decode(&Json::parse(s).unwrap()).unwrap() {
            Request::Plan(r) => r,
            other => panic!("expected plan, got {other:?}"),
        };
        // Wire field order does not matter.
        let a = dec(r#"{"op":"plan","budget":80,"policy":"mbf","seed":7}"#);
        let b = dec(r#"{"seed":7,"policy":"mbf","budget":80,"op":"plan"}"#);
        assert_eq!(a.cache_key(), b.cache_key());
        // Outcome-irrelevant knobs are excluded from the key...
        let threaded = dec(r#"{"op":"plan","budget":80,"policy":"mbf","seed":7,"threads":4}"#);
        assert_eq!(a.cache_key(), threaded.cache_key());
        let detailed = dec(r#"{"op":"plan","budget":80,"policy":"mbf","seed":7,"detail":true}"#);
        assert_eq!(a.cache_key(), detailed.cache_key());
        // ...while outcome-relevant ones all miss.
        for other in [
            r#"{"op":"plan","budget":90,"policy":"mbf","seed":7}"#,
            r#"{"op":"plan","budget":80,"policy":"mp","seed":7}"#,
            r#"{"op":"plan","budget":80,"policy":"mbf","seed":8}"#,
            r#"{"op":"plan","budget":80,"policy":"mbf","seed":7,"scenario":"paper"}"#,
        ] {
            assert_ne!(a.cache_key(), dec(other).cache_key(), "{other}");
        }
        assert!(a.cache_key().contains("cache_version"), "{}", a.cache_key());
    }
}
