//! The paper's Section III problem model.
//!
//! Multiple Bag-of-Tasks applications `A = {A_1..A_M}`, each a collection of
//! independent tasks with a `size`; a cloud catalogue of instance types
//! `IT = {it_1..it_N}` with an hourly cost `c_it`; and a performance matrix
//! `P[N x M]` giving the seconds each instance type needs per unit of task
//! size of each application (eq. 2: `exec_{it,t} = P[it, A_t] * size_t`).
//!
//! An **execution plan** (eq. 3-8) is a set of VMs, each created from one
//! instance type and holding a disjoint set of tasks covering `T`; VMs boot
//! with overhead `o`, bill by the ceiling of wall-clock hours (eq. 6), run
//! in parallel (makespan = slowest VM, eq. 7) and the plan satisfies the
//! budget when `cost <= B` (eq. 9).

mod application;
mod billing;
mod instance;
mod perf;
mod plan;
mod system;
mod task;
mod vm;

pub use application::{AppId, Application};
pub use billing::{billed_cost, billed_hours, BillingPolicy};
pub use instance::{InstanceType, InstanceTypeId};
pub use perf::PerfMatrix;
pub use plan::{Plan, PlanScore};
pub use system::{System, SystemBuilder, SystemError};
pub use task::{Task, TaskId};
pub use vm::Vm;

/// Default billing quantum (seconds per billed hour, paper eq. 6).
pub const HOUR_SECONDS: f64 = 3600.0;
