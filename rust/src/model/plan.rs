
use super::{InstanceTypeId, System, TaskId, Vm};

/// The two objective values of a plan: eq. 7 makespan and eq. 8 total cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanScore {
    /// eq. 7: `exec = max_vm exec_vm` (seconds).
    pub makespan: f64,
    /// eq. 8: `cost = sum_vm cost_vm`.
    pub cost: f64,
}

impl PlanScore {
    /// eq. 9 (`cost <= B`; the paper writes `cost < B` in eq. 9 but treats
    /// plans that spend exactly the budget as valid throughout Sec. V).
    pub fn satisfies(&self, budget: f64) -> bool {
        self.cost <= budget + 1e-9
    }

    /// Strict improvement in either objective (Algorithm 1 line 14).
    pub fn improves(&self, other: &PlanScore) -> bool {
        self.cost < other.cost - 1e-9 || self.makespan < other.makespan - 1e-9
    }

    /// Pareto dominance: no worse in both, strictly better in one.
    pub fn dominates(&self, other: &PlanScore) -> bool {
        self.cost <= other.cost + 1e-9
            && self.makespan <= other.makespan + 1e-9
            && self.improves(other)
    }
}

/// An execution plan: the set of provisioned VMs and their task
/// assignments (Sec. III-B's `VM` with `T_vm` per element).
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub vms: Vec<Vm>,
}

impl Plan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Provision a fresh empty VM of the given type; returns its index.
    pub fn add_vm(&mut self, sys: &System, it: InstanceTypeId) -> usize {
        self.vms.push(Vm::new(it, sys.n_apps()));
        self.vms.len() - 1
    }

    /// Deprovision a VM (must be empty of tasks unless the caller has
    /// drained it intentionally).
    ///
    /// This shifts every VM after `idx` (a `Vec::remove`), so indices a
    /// caller holds past `idx` go stale.  Callers removing several VMs
    /// should use [`Plan::remove_vms`] (one compaction pass, any victim
    /// order) instead of a descending `remove_vm` loop; the index-stable
    /// alternative for hot paths is [`crate::eval::PlanArena`], whose
    /// free-list recycles slots without shifting anything.
    pub fn remove_vm(&mut self, idx: usize) -> Vm {
        self.vms.remove(idx)
    }

    /// Deprovision several VMs at once: one order-preserving compaction
    /// pass instead of `victims.len()` shifting `Vec::remove` calls.
    /// Returns the removed VMs in ascending index order.  Duplicate
    /// indices collapse into one removal; an out-of-range index panics.
    pub fn remove_vms(&mut self, victims: &[usize]) -> Vec<Vm> {
        if victims.is_empty() {
            return Vec::new();
        }
        let mut doomed = vec![false; self.vms.len()];
        for &v in victims {
            doomed[v] = true;
        }
        let mut removed = Vec::with_capacity(victims.len());
        let mut kept = Vec::with_capacity(self.vms.len().saturating_sub(victims.len()));
        for (i, vm) in std::mem::take(&mut self.vms).into_iter().enumerate() {
            if doomed[i] {
                removed.push(vm);
            } else {
                kept.push(vm);
            }
        }
        self.vms = kept;
        removed
    }

    /// Drop every VM with no assigned tasks (they would still bill their
    /// boot hour under hourly billing when `o > 0`).  Like
    /// [`Plan::remove_vms`] this compacts in one pass, preserving the
    /// survivors' relative order; indices held across the call go stale.
    pub fn drop_empty_vms(&mut self) {
        self.vms.retain(|vm| !vm.is_empty());
    }

    /// Explicit deep copy of the plan.
    ///
    /// This inherent method shadows the derived [`Clone`] impl under
    /// method-call syntax, giving `plan.clone()` call sites a nameable
    /// path for the `clippy.toml` `disallowed-methods` gate: scheduler
    /// hot paths must stay zero-clone (score candidates through
    /// [`crate::eval::PlanArena`] / delta batches), and only allow-listed
    /// boundary sites (FIND's accept-store, REDUCE/SPLIT scratch copies,
    /// API materialisation) may clone a plan.
    #[inline]
    #[allow(clippy::should_implement_trait)]
    pub fn clone(&self) -> Plan {
        Clone::clone(self)
    }

    pub fn is_empty(&self) -> bool {
        self.vms.is_empty()
    }

    pub fn n_vms(&self) -> usize {
        self.vms.len()
    }

    /// Move one task between VMs; panics on bad indices, returns whether
    /// the task was found on `from`.
    pub fn move_task(&mut self, sys: &System, from: usize, to: usize, task: TaskId) -> bool {
        assert_ne!(from, to, "move_task: from == to");
        if !self.vms[from].remove_task(sys, task) {
            return false;
        }
        self.vms[to].push_task(sys, task);
        true
    }

    /// eq. 7 makespan.
    pub fn exec(&self, sys: &System) -> f64 {
        self.vms.iter().map(|vm| vm.exec(sys)).fold(0.0, f64::max)
    }

    /// eq. 8 total cost.
    pub fn cost(&self, sys: &System) -> f64 {
        self.vms.iter().map(|vm| vm.cost(sys)).sum()
    }

    pub fn score(&self, sys: &System) -> PlanScore {
        PlanScore { makespan: self.exec(sys), cost: self.cost(sys) }
    }

    /// Number of VMs of each instance type (Fig. 2's quantity).
    pub fn vm_mix(&self, sys: &System) -> Vec<usize> {
        let mut mix = vec![0usize; sys.n_types()];
        for vm in &self.vms {
            mix[vm.it.index()] += 1;
        }
        mix
    }

    /// Total number of assigned tasks across all VMs.
    pub fn n_assigned(&self) -> usize {
        self.vms.iter().map(Vm::len).sum()
    }

    /// Validate eq. 3 + eq. 4: every task of the system appears on exactly
    /// one VM.  Returns a human-readable violation description.
    pub fn validate_partition(&self, sys: &System) -> Result<(), String> {
        let n = sys.tasks().len();
        let mut seen = vec![false; n];
        for (vi, vm) in self.vms.iter().enumerate() {
            for &t in vm.tasks() {
                let i = t.index();
                if i >= n {
                    return Err(format!("vm {vi} holds unknown task {i}"));
                }
                if seen[i] {
                    return Err(format!("task {i} assigned to multiple VMs (eq. 4)"));
                }
                seen[i] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|s| !s) {
            return Err(format!("task {missing} not assigned to any VM (eq. 3)"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemBuilder;

    fn sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0])
            .app("a2", vec![3.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .build()
            .unwrap()
    }

    #[test]
    fn score_and_mix() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v0].push_task(&s, TaskId(0)); // 20s on small
        p.vms[v0].push_task(&s, TaskId(1)); // 40s on small
        p.vms[v1].push_task(&s, TaskId(2)); // 39s on big
        let sc = p.score(&s);
        assert_eq!(sc.makespan, 60.0);
        assert_eq!(sc.cost, 15.0); // 1h small + 1h big
        assert_eq!(p.vm_mix(&s), vec![1, 1]);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn partition_violations_detected() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v0].push_task(&s, TaskId(0));
        assert!(p.validate_partition(&s).unwrap_err().contains("not assigned"));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v1].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(1));
        p.vms[v1].push_task(&s, TaskId(2));
        assert!(p.validate_partition(&s).unwrap_err().contains("multiple"));
    }

    #[test]
    fn move_task_between_vms() {
        let s = sys();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v0].push_task(&s, TaskId(0));
        assert!(p.move_task(&s, v0, v1, TaskId(0)));
        assert!(!p.move_task(&s, v0, v1, TaskId(0)));
        assert_eq!(p.vms[v1].len(), 1);
        assert_eq!(p.vms[v0].len(), 0);
    }

    #[test]
    fn drop_empty_vms() {
        let s = sys();
        let mut p = Plan::new();
        p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v1].push_task(&s, TaskId(0));
        p.drop_empty_vms();
        assert_eq!(p.n_vms(), 1);
        assert_eq!(p.vms[0].it, InstanceTypeId(1));
    }

    #[test]
    fn remove_vms_compacts_in_order() {
        let s = sys();
        let mut p = Plan::new();
        for it in [0u32, 1, 0, 1, 0] {
            p.add_vm(&s, InstanceTypeId(it));
        }
        p.vms[1].push_task(&s, TaskId(0));
        p.vms[3].push_task(&s, TaskId(1));
        let removed = p.remove_vms(&[0, 2, 4]);
        assert_eq!(removed.len(), 3);
        assert!(removed.iter().all(|vm| vm.it == InstanceTypeId(0)));
        assert_eq!(p.n_vms(), 2);
        assert_eq!(p.vms[0].tasks(), &[TaskId(0)]);
        assert_eq!(p.vms[1].tasks(), &[TaskId(1)]);
        // Duplicates collapse; empty victim list is a no-op.
        assert_eq!(p.remove_vms(&[1, 1]).len(), 1);
        assert_eq!(p.remove_vms(&[]).len(), 0);
        assert_eq!(p.n_vms(), 1);
    }

    #[test]
    #[allow(clippy::disallowed_methods)] // the gated method is the test subject
    fn inherent_clone_deep_copies() {
        let s = sys();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v].push_task(&s, TaskId(0));
        let q = p.clone();
        p.vms[v].push_task(&s, TaskId(1));
        assert_eq!(q.vms[v].len(), 1);
        assert_eq!(p.vms[v].len(), 2);
    }

    #[test]
    fn score_semantics() {
        let a = PlanScore { makespan: 100.0, cost: 50.0 };
        let b = PlanScore { makespan: 90.0, cost: 60.0 };
        assert!(b.improves(&a)); // better makespan
        assert!(a.improves(&b)); // better cost
        assert!(!a.dominates(&b));
        let c = PlanScore { makespan: 90.0, cost: 50.0 };
        assert!(c.dominates(&a));
        assert!(a.satisfies(50.0));
        assert!(!a.satisfies(49.0));
    }

    #[test]
    fn empty_plan() {
        let s = sys();
        let p = Plan::new();
        assert_eq!(p.exec(&s), 0.0);
        assert_eq!(p.cost(&s), 0.0);
        assert!(p.validate_partition(&s).is_err()); // tasks unassigned
    }
}
