
use super::{AppId, InstanceTypeId, Task};

/// The paper's performance matrix `P[N x M]`: seconds an instance of type
/// `it_i` needs to process **one unit of size** of a task of application
/// `A_j` (Sec. III-A).  Lower is faster.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfMatrix {
    n_types: usize,
    n_apps: usize,
    /// Row-major `[it][app]`.
    data: Vec<f64>,
}

impl PerfMatrix {
    /// Build from row-major data; `data.len()` must equal
    /// `n_types * n_apps` and all entries must be finite and positive.
    pub fn new(n_types: usize, n_apps: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n_types * n_apps, "PerfMatrix shape mismatch");
        assert!(
            data.iter().all(|p| p.is_finite() && *p > 0.0),
            "PerfMatrix entries must be finite and positive"
        );
        Self { n_types, n_apps, data }
    }

    /// Build from nested rows (one row per instance type).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n_types = rows.len();
        let n_apps = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|r| r.len() == n_apps), "ragged PerfMatrix rows");
        Self::new(n_types, n_apps, rows.concat())
    }

    pub fn n_types(&self) -> usize {
        self.n_types
    }

    pub fn n_apps(&self) -> usize {
        self.n_apps
    }

    /// `P[it, app]` — seconds per unit size.
    #[inline]
    pub fn get(&self, it: InstanceTypeId, app: AppId) -> f64 {
        self.data[it.index() * self.n_apps + app.index()]
    }

    /// The whole performance vector `P_it` of one instance type.
    pub fn row(&self, it: InstanceTypeId) -> &[f64] {
        let start = it.index() * self.n_apps;
        &self.data[start..start + self.n_apps]
    }

    /// eq. 2: `exec_{it,t} = P[it, A_t] * size_t`.
    #[inline]
    pub fn exec_time(&self, it: InstanceTypeId, task: &Task) -> f64 {
        self.get(it, task.app) * task.size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_row() {
        let p = PerfMatrix::from_rows(&[vec![20.0, 24.0], vec![11.0, 13.0]]);
        assert_eq!(p.n_types(), 2);
        assert_eq!(p.n_apps(), 2);
        assert_eq!(p.get(InstanceTypeId(0), AppId(1)), 24.0);
        assert_eq!(p.row(InstanceTypeId(1)), &[11.0, 13.0]);
    }

    #[test]
    fn exec_time_is_linear_in_size() {
        let p = PerfMatrix::from_rows(&[vec![10.0]]);
        let t = Task::new(super::super::TaskId(0), AppId(0), 3.0);
        assert_eq!(p.exec_time(InstanceTypeId(0), &t), 30.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        PerfMatrix::new(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn nonpositive_panics() {
        PerfMatrix::new(1, 1, vec![0.0]);
    }
}
