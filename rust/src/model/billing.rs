
/// How VM wall-clock time is turned into money.
///
/// The paper (eq. 6) uses per-hour ceiling billing; per-second billing is
/// provided for ablations (several modern clouds bill per second) and for
/// the LP lower bound in [`crate::analysis::bounds`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum BillingPolicy {
    /// eq. 6: `cost = ceil(exec / hour) * rate`.
    #[default]
    HourlyCeil,
    /// Fractional: `cost = (exec / hour) * rate` (no quantisation).
    PerSecond,
}

/// Billed hours of a VM that ran for `exec` seconds (eq. 6 numerator).
///
/// A VM that ran at all (even only its boot overhead) bills at least one
/// hour under [`BillingPolicy::HourlyCeil`]; a VM with `exec == 0` (never
/// started) bills zero.
#[inline]
pub fn billed_hours(exec: f64, hour: f64) -> f64 {
    debug_assert!(exec >= 0.0 && hour > 0.0);
    (exec / hour).ceil()
}

/// Cost of a VM that ran `exec` seconds at `rate` per hour under `policy`.
#[inline]
pub fn billed_cost(exec: f64, rate: f64, hour: f64, policy: BillingPolicy) -> f64 {
    match policy {
        BillingPolicy::HourlyCeil => billed_hours(exec, hour) * rate,
        BillingPolicy::PerSecond => exec / hour * rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: f64 = 3600.0;

    #[test]
    fn zero_exec_bills_zero() {
        assert_eq!(billed_hours(0.0, H), 0.0);
        assert_eq!(billed_cost(0.0, 10.0, H, BillingPolicy::HourlyCeil), 0.0);
    }

    #[test]
    fn sub_hour_bills_one() {
        assert_eq!(billed_hours(1.0, H), 1.0);
        assert_eq!(billed_hours(3599.9, H), 1.0);
    }

    #[test]
    fn exact_hour_boundary() {
        assert_eq!(billed_hours(3600.0, H), 1.0);
        assert_eq!(billed_hours(3600.0001, H), 2.0);
    }

    #[test]
    fn per_second_is_fractional() {
        let c = billed_cost(1800.0, 10.0, H, BillingPolicy::PerSecond);
        assert!((c - 5.0).abs() < 1e-12);
    }
}
