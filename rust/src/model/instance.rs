
/// Instance-type identifier (index into [`super::System::instance_types`]
/// and row of the performance matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceTypeId(pub u16);

impl InstanceTypeId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A cloud instance type offering: name + hourly price `c_it`.
///
/// The per-application speed of the type lives in the
/// [`super::PerfMatrix`], not here, because it is a property of the
/// (type, application) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    pub id: InstanceTypeId,
    pub name: String,
    /// `c_it`: cost per billed hour (paper eq. 6).
    pub cost_per_hour: f64,
}

impl InstanceType {
    pub fn new(id: InstanceTypeId, name: impl Into<String>, cost_per_hour: f64) -> Self {
        Self { id, name: name.into(), cost_per_hour }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct() {
        let it = InstanceType::new(InstanceTypeId(2), "c4.large", 0.1);
        assert_eq!(it.id.index(), 2);
        assert_eq!(it.cost_per_hour, 0.1);
    }
}
