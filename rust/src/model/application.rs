
/// Application identifier (index into [`super::System::apps`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppId(pub u16);

impl AppId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A Bag-of-Tasks application: a named collection of independent, identical
/// tasks distinguished only by their `size` (paper Sec. III-A).
#[derive(Debug, Clone, PartialEq)]
pub struct Application {
    pub id: AppId,
    pub name: String,
    /// Sizes of this application's tasks, in declaration order.
    pub task_sizes: Vec<f64>,
}

impl Application {
    pub fn new(id: AppId, name: impl Into<String>, task_sizes: Vec<f64>) -> Self {
        Self { id, name: name.into(), task_sizes }
    }

    /// Number of tasks, `|A_i|`.
    pub fn len(&self) -> usize {
        self.task_sizes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.task_sizes.is_empty()
    }

    /// Total size of all tasks (used by the planner's work estimates).
    pub fn total_size(&self) -> f64 {
        self.task_sizes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let a = Application::new(AppId(0), "a", vec![1.0, 2.0, 3.0]);
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
        assert_eq!(a.total_size(), 6.0);
    }

    #[test]
    fn empty() {
        let a = Application::new(AppId(0), "a", vec![]);
        assert!(a.is_empty());
        assert_eq!(a.total_size(), 0.0);
    }
}
