
use super::{
    AppId, Application, BillingPolicy, InstanceType, InstanceTypeId, PerfMatrix, Task, TaskId,
    HOUR_SECONDS,
};

/// Validation errors for [`System`] construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SystemError {
    /// The performance matrix shape does not match `|IT| x |A|`.
    PerfShapeMismatch { n_types: usize, n_apps: usize, rows: usize, cols: usize },
    /// eq. 1 violated: two distinct instance types with identical
    /// performance vector *and* identical cost.
    DuplicateInstanceType(InstanceTypeId, InstanceTypeId),
    /// No applications / no instance types / a non-positive price etc.
    Invalid(String),
}

impl std::fmt::Display for SystemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::PerfShapeMismatch { n_types, n_apps, rows, cols } => write!(
                f,
                "performance matrix is {rows}x{cols} but system has {n_types} instance types \
                 and {n_apps} applications"
            ),
            Self::DuplicateInstanceType(a, b) => write!(
                f,
                "instance types {} and {} have identical performance and cost (violates eq. 1)",
                a.0, b.0
            ),
            Self::Invalid(msg) => write!(f, "invalid system: {msg}"),
        }
    }
}

impl std::error::Error for SystemError {}

/// The full problem instance `(A, IT)` of Sec. III plus the environment
/// constants: boot overhead `o`, the billing quantum and policy.
///
/// `tasks` is the flattened union `T` (eq. in Sec. III-A) with stable ids;
/// `TaskId(i)` indexes straight into it.
#[derive(Debug, Clone)]
pub struct System {
    pub apps: Vec<Application>,
    pub instance_types: Vec<InstanceType>,
    pub perf: PerfMatrix,
    /// VM boot overhead `o` in seconds (Sec. III-B).
    pub overhead: f64,
    /// Billing quantum in seconds (3600 in the paper).
    pub hour: f64,
    pub billing: BillingPolicy,
    tasks: Vec<Task>,
}

impl System {
    /// Validated constructor; prefer [`SystemBuilder`] for literals.
    pub fn new(
        apps: Vec<Application>,
        instance_types: Vec<InstanceType>,
        perf: PerfMatrix,
        overhead: f64,
        hour: f64,
        billing: BillingPolicy,
    ) -> Result<Self, SystemError> {
        if apps.is_empty() {
            return Err(SystemError::Invalid("no applications".into()));
        }
        if instance_types.is_empty() {
            return Err(SystemError::Invalid("no instance types".into()));
        }
        if overhead < 0.0 || !overhead.is_finite() {
            return Err(SystemError::Invalid(format!("bad overhead {overhead}")));
        }
        if hour <= 0.0 || !hour.is_finite() {
            return Err(SystemError::Invalid(format!("bad hour {hour}")));
        }
        if perf.n_types() != instance_types.len() || perf.n_apps() != apps.len() {
            return Err(SystemError::PerfShapeMismatch {
                n_types: instance_types.len(),
                n_apps: apps.len(),
                rows: perf.n_types(),
                cols: perf.n_apps(),
            });
        }
        for (i, it) in instance_types.iter().enumerate() {
            if it.cost_per_hour <= 0.0 || !it.cost_per_hour.is_finite() {
                return Err(SystemError::Invalid(format!(
                    "instance type {} has non-positive cost",
                    it.name
                )));
            }
            if it.id.index() != i {
                return Err(SystemError::Invalid(format!(
                    "instance type {} id out of order",
                    it.name
                )));
            }
        }
        for (j, a) in apps.iter().enumerate() {
            if a.id.index() != j {
                return Err(SystemError::Invalid(format!("application {} id out of order", a.name)));
            }
            if a.task_sizes.iter().any(|s| *s <= 0.0 || !s.is_finite()) {
                return Err(SystemError::Invalid(format!(
                    "application {} has non-positive task size",
                    a.name
                )));
            }
        }
        // eq. 1: no two types may share both performance vector and cost.
        for i in 0..instance_types.len() {
            for j in i + 1..instance_types.len() {
                let (a, b) = (InstanceTypeId(i as u16), InstanceTypeId(j as u16));
                if instance_types[i].cost_per_hour == instance_types[j].cost_per_hour
                    && perf.row(a) == perf.row(b)
                {
                    return Err(SystemError::DuplicateInstanceType(a, b));
                }
            }
        }
        let mut tasks = Vec::with_capacity(apps.iter().map(Application::len).sum());
        for app in &apps {
            for &size in &app.task_sizes {
                tasks.push(Task::new(TaskId(tasks.len() as u32), app.id, size));
            }
        }
        Ok(Self { apps, instance_types, perf, overhead, hour, billing, tasks })
    }

    /// The flattened task union `T`.
    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.index()]
    }

    pub fn n_apps(&self) -> usize {
        self.apps.len()
    }

    pub fn n_types(&self) -> usize {
        self.instance_types.len()
    }

    pub fn instance_type(&self, it: InstanceTypeId) -> &InstanceType {
        &self.instance_types[it.index()]
    }

    pub fn rate(&self, it: InstanceTypeId) -> f64 {
        self.instance_types[it.index()].cost_per_hour
    }

    /// eq. 2 for a task id.
    #[inline]
    pub fn exec_time(&self, it: InstanceTypeId, task: TaskId) -> f64 {
        self.perf.exec_time(it, self.task(task))
    }

    /// `exec_{it,T}`: total serial execution time of **all** tasks on one
    /// VM of type `it` (used by ADD/MI to rank types by performance).
    pub fn total_exec_time(&self, it: InstanceTypeId) -> f64 {
        self.apps
            .iter()
            .map(|a| self.perf.get(it, a.id) * a.total_size())
            .sum()
    }

    /// The cheapest instance type `it^c = argmin c_it` (MP baseline).
    pub fn cheapest_type(&self) -> InstanceTypeId {
        self.instance_types
            .iter()
            .min_by(|a, b| a.cost_per_hour.total_cmp(&b.cost_per_hour))
            .map(|it| it.id)
            .expect("validated: at least one instance type")
    }

    /// Sec. IV-C: the best instance type for one application —
    /// lexicographically smallest `(P[it, app], c_it)` among types whose
    /// hourly cost fits the budget (falls back to all types if none fit).
    pub fn best_type_for_app(&self, app: AppId, budget: f64) -> InstanceTypeId {
        let affordable: Vec<&InstanceType> = self
            .instance_types
            .iter()
            .filter(|it| it.cost_per_hour <= budget)
            .collect();
        let pool: Vec<&InstanceType> = if affordable.is_empty() {
            self.instance_types.iter().collect()
        } else {
            affordable
        };
        pool.into_iter()
            .min_by(|a, b| {
                self.perf
                    .get(a.id, app)
                    .total_cmp(&self.perf.get(b.id, app))
                    .then(a.cost_per_hour.total_cmp(&b.cost_per_hour))
            })
            .expect("non-empty pool")
            .id
    }
}

/// Fluent construction of a [`System`].
#[derive(Debug, Default)]
pub struct SystemBuilder {
    apps: Vec<Application>,
    instance_types: Vec<InstanceType>,
    perf_rows: Vec<Vec<f64>>,
    overhead: f64,
    hour: f64,
    billing: BillingPolicy,
}

impl SystemBuilder {
    pub fn new() -> Self {
        Self { hour: HOUR_SECONDS, ..Default::default() }
    }

    /// Add an application with the given task sizes.
    pub fn app(mut self, name: &str, task_sizes: Vec<f64>) -> Self {
        let id = AppId(self.apps.len() as u16);
        self.apps.push(Application::new(id, name, task_sizes));
        self
    }

    /// Add an instance type with hourly cost and its performance row
    /// (seconds per unit size, one entry per application, in the order the
    /// applications were added).
    pub fn instance_type(mut self, name: &str, cost_per_hour: f64, perf_row: Vec<f64>) -> Self {
        let id = InstanceTypeId(self.instance_types.len() as u16);
        self.instance_types.push(InstanceType::new(id, name, cost_per_hour));
        self.perf_rows.push(perf_row);
        self
    }

    /// Set the VM boot overhead `o` (seconds); default 0.
    pub fn overhead(mut self, o: f64) -> Self {
        self.overhead = o;
        self
    }

    /// Set the billing quantum (seconds); default 3600.
    pub fn hour(mut self, hour: f64) -> Self {
        self.hour = hour;
        self
    }

    pub fn billing(mut self, billing: BillingPolicy) -> Self {
        self.billing = billing;
        self
    }

    pub fn build(self) -> Result<System, SystemError> {
        let n_apps = self.apps.len();
        if self.perf_rows.iter().any(|r| r.len() != n_apps) {
            return Err(SystemError::Invalid(
                "a perf row length does not match the number of applications".into(),
            ));
        }
        let perf = PerfMatrix::from_rows(&self.perf_rows);
        System::new(self.apps, self.instance_types, perf, self.overhead, self.hour, self.billing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0])
            .app("a2", vec![3.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .build()
            .unwrap()
    }

    #[test]
    fn tasks_flattened_in_order() {
        let s = tiny();
        assert_eq!(s.tasks().len(), 3);
        assert_eq!(s.task(TaskId(0)).app, AppId(0));
        assert_eq!(s.task(TaskId(2)).app, AppId(1));
        assert_eq!(s.task(TaskId(2)).size, 3.0);
    }

    #[test]
    fn exec_and_totals() {
        let s = tiny();
        assert_eq!(s.exec_time(InstanceTypeId(0), TaskId(1)), 40.0);
        // total on small: (1+2)*20 + 3*24 = 132
        assert_eq!(s.total_exec_time(InstanceTypeId(0)), 132.0);
    }

    #[test]
    fn cheapest_and_best() {
        let s = tiny();
        assert_eq!(s.cheapest_type(), InstanceTypeId(0));
        // app 0: big (11 s/u) is best when affordable…
        assert_eq!(s.best_type_for_app(AppId(0), 10.0), InstanceTypeId(1));
        // …but with budget 7 only small fits.
        assert_eq!(s.best_type_for_app(AppId(0), 7.0), InstanceTypeId(0));
    }

    #[test]
    fn eq1_duplicate_type_rejected() {
        let err = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .instance_type("y", 5.0, vec![10.0])
            .build()
            .unwrap_err();
        assert!(matches!(err, SystemError::DuplicateInstanceType(_, _)));
    }

    #[test]
    fn same_cost_different_perf_allowed() {
        // Paper Table I has three types at the same price — only identical
        // (perf, cost) pairs are forbidden.
        assert!(SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 10.0, vec![10.0])
            .instance_type("y", 10.0, vec![9.0])
            .build()
            .is_ok());
    }

    #[test]
    fn bad_inputs_rejected() {
        assert!(SystemBuilder::new().build().is_err());
        assert!(SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 0.0, vec![10.0])
            .build()
            .is_err());
        assert!(SystemBuilder::new()
            .app("a", vec![-1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .is_err());
    }
}
