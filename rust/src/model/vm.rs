
use super::{billed_cost, InstanceTypeId, System, TaskId};

/// One provisioned virtual machine in an execution plan: an instance type
/// plus the list of tasks assigned to it (`T_vm` in Sec. III-B).
///
/// The VM caches its total task work (`sum_t exec_{vm,t}`) and the per
/// application aggregated task sizes, both maintained incrementally on
/// every assignment change, so `exec()` / `cost()` are O(1) and the XLA
/// evaluator can read the `(vm, app)` size aggregation without a pass over
/// the tasks.
#[derive(Debug, Clone)]
pub struct Vm {
    pub it: InstanceTypeId,
    tasks: Vec<TaskId>,
    /// Aggregated task size per application (index = AppId).
    agg_sizes: Vec<f64>,
    /// Cached `sum_{t in T_vm} P[it, A_t] * size_t` in seconds.
    work: f64,
}

impl Vm {
    pub fn new(it: InstanceTypeId, n_apps: usize) -> Self {
        Self { it, tasks: Vec::new(), agg_sizes: vec![0.0; n_apps], work: 0.0 }
    }

    /// Reassemble a VM from externally maintained caches (the arena's
    /// materialisation path).  The caches are adopted verbatim — NOT
    /// recomputed — so a `Plan -> PlanArena -> Plan` round trip carries
    /// every float bit-for-bit, including the tiny residues incremental
    /// updates can leave behind.
    pub(crate) fn from_parts(
        it: InstanceTypeId,
        tasks: Vec<TaskId>,
        agg_sizes: Vec<f64>,
        work: f64,
    ) -> Self {
        Self { it, tasks, agg_sizes, work }
    }

    pub fn tasks(&self) -> &[TaskId] {
        &self.tasks
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Aggregated size per application (used to build evaluator tensors).
    pub fn agg_sizes(&self) -> &[f64] {
        &self.agg_sizes
    }

    /// Cached total task work in seconds (excludes boot overhead).
    pub fn work(&self) -> f64 {
        self.work
    }

    /// eq. 5: `exec_vm = o + sum_t exec_{vm,t}`.
    ///
    /// A provisioned VM pays its boot overhead even with no tasks; a VM
    /// with neither overhead nor tasks has `exec == 0` and bills nothing.
    #[inline]
    pub fn exec(&self, sys: &System) -> f64 {
        if self.tasks.is_empty() && sys.overhead == 0.0 {
            0.0
        } else {
            sys.overhead + self.work
        }
    }

    /// eq. 6: hourly-ceiling (or configured policy) cost of this VM.
    #[inline]
    pub fn cost(&self, sys: &System) -> f64 {
        billed_cost(self.exec(sys), sys.rate(self.it), sys.hour, sys.billing)
    }

    /// Marginal execution time this VM needs for `task` (eq. 2).
    #[inline]
    pub fn task_time(&self, sys: &System, task: TaskId) -> f64 {
        sys.exec_time(self.it, task)
    }

    /// Would assigning `task` leave this VM's billed cost unchanged?
    /// (ASSIGN criterion i, Sec. IV-A.)
    pub fn fits_without_cost_increase(&self, sys: &System, task: TaskId) -> bool {
        let new_exec = sys.overhead + self.work + self.task_time(sys, task);
        billed_cost(new_exec, sys.rate(self.it), sys.hour, sys.billing) <= self.cost(sys)
    }

    /// Assign a task (updates cached work and aggregation).
    pub fn push_task(&mut self, sys: &System, task: TaskId) {
        let t = sys.task(task);
        self.work += sys.exec_time(self.it, task);
        self.agg_sizes[t.app.index()] += t.size;
        self.tasks.push(task);
    }

    /// Remove a task by id; returns whether it was present.
    pub fn remove_task(&mut self, sys: &System, task: TaskId) -> bool {
        let Some(pos) = self.tasks.iter().position(|t| *t == task) else {
            return false;
        };
        self.tasks.swap_remove(pos);
        let t = sys.task(task);
        self.work -= sys.exec_time(self.it, task);
        self.agg_sizes[t.app.index()] -= t.size;
        // Clamp tiny negative float residue from incremental updates.
        if self.work < 0.0 {
            self.work = 0.0;
        }
        if self.agg_sizes[t.app.index()] < 0.0 {
            self.agg_sizes[t.app.index()] = 0.0;
        }
        true
    }

    /// Remove and return all tasks (used by REDUCE/REPLACE when a VM is
    /// dismantled).
    pub fn drain_tasks(&mut self) -> Vec<TaskId> {
        self.work = 0.0;
        self.agg_sizes.iter_mut().for_each(|s| *s = 0.0);
        std::mem::take(&mut self.tasks)
    }

    /// Recompute caches from scratch (drift check; used by tests/debug).
    pub fn recompute(&mut self, sys: &System) {
        self.work = 0.0;
        self.agg_sizes.iter_mut().for_each(|s| *s = 0.0);
        for &t in &self.tasks {
            self.work += sys.exec_time(self.it, t);
            let task = sys.task(t);
            self.agg_sizes[task.app.index()] += task.size;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemBuilder;

    fn sys() -> System {
        SystemBuilder::new()
            .app("a1", vec![1.0, 2.0])
            .app("a2", vec![3.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("big", 10.0, vec![11.0, 13.0])
            .overhead(30.0)
            .build()
            .unwrap()
    }

    #[test]
    fn push_remove_roundtrip() {
        let s = sys();
        let mut vm = Vm::new(InstanceTypeId(0), 2);
        vm.push_task(&s, TaskId(0));
        vm.push_task(&s, TaskId(2));
        assert_eq!(vm.len(), 2);
        assert_eq!(vm.work(), 20.0 + 72.0);
        assert_eq!(vm.agg_sizes(), &[1.0, 3.0]);
        assert!(vm.remove_task(&s, TaskId(0)));
        assert!(!vm.remove_task(&s, TaskId(0)));
        assert_eq!(vm.work(), 72.0);
        assert_eq!(vm.agg_sizes(), &[0.0, 3.0]);
    }

    #[test]
    fn exec_includes_overhead() {
        let s = sys();
        let mut vm = Vm::new(InstanceTypeId(1), 2);
        assert_eq!(vm.exec(&s), 30.0); // overhead only: still provisioned
        vm.push_task(&s, TaskId(1));
        assert_eq!(vm.exec(&s), 30.0 + 22.0);
    }

    #[test]
    fn empty_vm_zero_overhead_bills_nothing() {
        let s = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let vm = Vm::new(InstanceTypeId(0), 1);
        assert_eq!(vm.exec(&s), 0.0);
        assert_eq!(vm.cost(&s), 0.0);
    }

    #[test]
    fn cost_is_hourly_ceiling() {
        let s = sys();
        let mut vm = Vm::new(InstanceTypeId(0), 2);
        vm.push_task(&s, TaskId(0)); // exec = 30 + 20 = 50s -> 1h * 5
        assert_eq!(vm.cost(&s), 5.0);
    }

    #[test]
    fn fits_without_cost_increase_boundary() {
        let s = SystemBuilder::new()
            .app("a", vec![3500.0, 100.0])
            .instance_type("x", 5.0, vec![1.0])
            .build()
            .unwrap();
        let mut vm = Vm::new(InstanceTypeId(0), 1);
        vm.push_task(&s, TaskId(0)); // 3500s of 3600
        assert!(vm.fits_without_cost_increase(&s, TaskId(1))); // exactly 3600
        vm.push_task(&s, TaskId(1));
        assert!(!vm.fits_without_cost_increase(&s, TaskId(1)));
    }

    #[test]
    fn drain_and_recompute() {
        let s = sys();
        let mut vm = Vm::new(InstanceTypeId(0), 2);
        vm.push_task(&s, TaskId(0));
        vm.push_task(&s, TaskId(1));
        let drained = vm.drain_tasks();
        assert_eq!(drained.len(), 2);
        assert!(vm.is_empty());
        assert_eq!(vm.work(), 0.0);
        for t in drained {
            vm.push_task(&s, t);
        }
        let w = vm.work();
        vm.recompute(&s);
        assert!((vm.work() - w).abs() < 1e-9);
    }
}
