
use super::AppId;

/// Globally unique task identifier (index into [`super::System::tasks`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);

impl TaskId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One task of a Bag-of-Tasks application.
///
/// `size` is the paper's `size_t`: an application-relative complexity
/// measure (input bytes, training iterations, ...).  The execution time of
/// the task on instance type `it` is `P[it, app] * size` (eq. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    pub id: TaskId,
    pub app: AppId,
    pub size: f64,
}

impl Task {
    pub fn new(id: TaskId, app: AppId, size: f64) -> Self {
        Self { id, app, size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_id_roundtrip() {
        let t = Task::new(TaskId(7), AppId(1), 2.5);
        assert_eq!(t.id.index(), 7);
        assert_eq!(t.app.index(), 1);
        assert_eq!(t.size, 2.5);
    }
}
