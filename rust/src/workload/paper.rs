//! The paper's Section V evaluation setup, verbatim.
//!
//! Table I:
//!
//! | instance | description           | cost | P(A1) | P(A2) | P(A3) |
//! |----------|-----------------------|------|-------|-------|-------|
//! | it_1     | small general type    |   5  |  20   |  24   |  22   |
//! | it_2     | big general type      |  10  |  11   |  13   |  12   |
//! | it_3     | CPU optimised type    |  10  |  10   |  15   |   9   |
//! | it_4     | memory optimised type |  10  |  10   |   9   |  12   |
//!
//! Applications: A1 (balanced), A2 (CPU-intensive), A3 (memory-intensive),
//! each with 250 tasks whose sizes are *equally distributed* from 1 to 5
//! (50 tasks of each integer size).  Budgets: 40 to 85 in steps of 5.

use crate::model::{System, SystemBuilder};

/// The budget sweep of Fig. 1 / Fig. 2.
pub const BUDGETS: &[f64] = &[40.0, 45.0, 50.0, 55.0, 60.0, 65.0, 70.0, 75.0, 80.0, 85.0];

/// Tasks per application.
pub const TASKS_PER_APP: usize = 250;

/// 250 sizes equally distributed over {1..5}: 50 tasks of each size.
pub fn paper_task_sizes() -> Vec<f64> {
    let mut sizes = Vec::with_capacity(TASKS_PER_APP);
    for s in 1..=5 {
        sizes.extend(std::iter::repeat_n(s as f64, TASKS_PER_APP / 5));
    }
    sizes
}

/// The full Table I system.  `overhead` is the VM boot overhead `o` in
/// seconds; Table I omits it and Fig. 1's magnitudes are consistent with a
/// negligible value, so the paper-reproduction harness passes 0.0 (see
/// DESIGN.md "Paper ambiguities").
pub fn table1_system(overhead: f64) -> System {
    SystemBuilder::new()
        .app("A1-balanced", paper_task_sizes())
        .app("A2-cpu", paper_task_sizes())
        .app("A3-mem", paper_task_sizes())
        .instance_type("it1-small-general", 5.0, vec![20.0, 24.0, 22.0])
        .instance_type("it2-big-general", 10.0, vec![11.0, 13.0, 12.0])
        .instance_type("it3-cpu-opt", 10.0, vec![10.0, 15.0, 9.0])
        .instance_type("it4-mem-opt", 10.0, vec![10.0, 9.0, 12.0])
        .overhead(overhead)
        .build()
        .expect("Table I system is valid")
}

/// Human-readable rendering of Table I (printed by `botsched figures`).
pub fn table1_text() -> String {
    let sys = table1_system(0.0);
    let mut out = String::from(
        "TABLE I: Costs and Performances\n\
         instance             cost   A1     A2     A3\n",
    );
    for it in &sys.instance_types {
        let row = sys.perf.row(it.id);
        out.push_str(&format!(
            "{:<20} {:>4}  {:>5} {:>6} {:>6}\n",
            it.name, it.cost_per_hour, row[0], row[1], row[2]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AppId, InstanceTypeId};

    #[test]
    fn sizes_equally_distributed() {
        let sizes = paper_task_sizes();
        assert_eq!(sizes.len(), 250);
        for s in 1..=5 {
            assert_eq!(sizes.iter().filter(|x| **x == s as f64).count(), 50);
        }
        assert_eq!(sizes.iter().sum::<f64>(), 750.0);
    }

    #[test]
    fn table1_matches_paper() {
        let sys = table1_system(0.0);
        assert_eq!(sys.n_apps(), 3);
        assert_eq!(sys.n_types(), 4);
        assert_eq!(sys.tasks().len(), 750);
        assert_eq!(sys.rate(InstanceTypeId(0)), 5.0);
        for i in 1..4 {
            assert_eq!(sys.rate(InstanceTypeId(i)), 10.0);
        }
        assert_eq!(sys.perf.get(InstanceTypeId(2), AppId(2)), 9.0);
        assert_eq!(sys.perf.get(InstanceTypeId(3), AppId(1)), 9.0);
    }

    #[test]
    fn total_work_per_type() {
        // Sanity anchor used throughout EXPERIMENTS.md: total serial work.
        let sys = table1_system(0.0);
        assert_eq!(sys.total_exec_time(InstanceTypeId(0)), 750.0 * 66.0); // 49500
        assert_eq!(sys.total_exec_time(InstanceTypeId(3)), 750.0 * 31.0); // 23250
    }

    #[test]
    fn table_text_contains_all_rows() {
        let t = table1_text();
        for name in ["it1", "it2", "it3", "it4"] {
            assert!(t.contains(name));
        }
    }
}
