//! Named workload scenarios: curated presets selectable by name.
//!
//! The coordinator's wire protocol (the `"scenario"` request field and
//! the `list_scenarios` op), the CLI (`--scenario`) and tests all pick
//! problem instances from this one table instead of inlining a full
//! `"system"` object.  Every scenario is deterministic: the generated
//! ones are seeded [`WorkloadGenerator`] specs, so two processes (or a
//! client and a server) naming the same scenario solve the same system.

use crate::model::System;
use crate::workload::generator::{SizeDistribution, WorkloadGenerator, WorkloadSpec};
use crate::workload::paper;

/// One named preset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
}

/// The scenario table (stable order: listed / described in this order).
pub const SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "paper",
        description: "the paper's Table I setup: 3 apps x 250 tasks, 4 instance types, no overhead",
    },
    Scenario {
        name: "uniform-small",
        description: "generated 3 apps x 100 tasks, 4 types, integer sizes 1..=5 (seed 11)",
    },
    Scenario {
        name: "heavy-tail",
        description: "generated 4 apps x 250 tasks, 6 types, log-normal task sizes (seed 12)",
    },
    Scenario {
        name: "wide-catalogue",
        description: "generated 3 apps x 200 tasks, 16 instance types, uniform sizes (seed 13)",
    },
];

/// The scenario names, in table order (for error messages and `describe`).
pub fn scenario_names() -> Vec<&'static str> {
    SCENARIOS.iter().map(|s| s.name).collect()
}

/// Build the named scenario's [`System`], or `None` for an unknown name.
pub fn build_scenario(name: &str) -> Option<System> {
    match name {
        "paper" => Some(paper::table1_system(0.0)),
        "uniform-small" => Some(WorkloadGenerator::new(11).system(&WorkloadSpec {
            n_apps: 3,
            n_types: 4,
            tasks_per_app: 100,
            sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            ..WorkloadSpec::default()
        })),
        "heavy-tail" => Some(WorkloadGenerator::new(12).system(&WorkloadSpec {
            n_apps: 4,
            n_types: 6,
            tasks_per_app: 250,
            sizes: SizeDistribution::LogNormal { mu: 1.0, sigma: 0.8 },
            ..WorkloadSpec::default()
        })),
        "wide-catalogue" => Some(WorkloadGenerator::new(13).system(&WorkloadSpec {
            n_apps: 3,
            n_types: 16,
            tasks_per_app: 200,
            sizes: SizeDistribution::Uniform { lo: 0.5, hi: 9.0 },
            ..WorkloadSpec::default()
        })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_scenario_builds() {
        for s in SCENARIOS {
            let sys = build_scenario(s.name)
                .unwrap_or_else(|| panic!("scenario {:?} listed but not buildable", s.name));
            assert!(!sys.tasks().is_empty(), "{}", s.name);
            assert!(sys.n_types() >= 1, "{}", s.name);
            assert!(!s.description.is_empty(), "{}", s.name);
        }
        assert!(build_scenario("nope").is_none());
    }

    #[test]
    fn scenarios_are_deterministic() {
        for s in SCENARIOS {
            let a = build_scenario(s.name).unwrap();
            let b = build_scenario(s.name).unwrap();
            assert_eq!(a.tasks().len(), b.tasks().len(), "{}", s.name);
            for (x, y) in a.tasks().iter().zip(b.tasks()) {
                assert_eq!(x.size, y.size, "{}", s.name);
            }
            for (x, y) in a.instance_types.iter().zip(&b.instance_types) {
                assert_eq!(x.cost_per_hour, y.cost_per_hour, "{}", s.name);
            }
        }
    }

    #[test]
    fn paper_scenario_is_the_table1_system() {
        let sys = build_scenario("paper").unwrap();
        assert_eq!(sys.tasks().len(), 750);
        assert_eq!(sys.n_types(), 4);
        assert_eq!(sys.overhead, 0.0);
    }

    #[test]
    fn shapes_match_their_descriptions() {
        let s = build_scenario("heavy-tail").unwrap();
        assert_eq!(s.n_apps(), 4);
        assert_eq!(s.n_types(), 6);
        assert_eq!(s.tasks().len(), 1000);
        let s = build_scenario("wide-catalogue").unwrap();
        assert_eq!(s.n_types(), 16);
        assert_eq!(s.tasks().len(), 600);
        let s = build_scenario("uniform-small").unwrap();
        assert_eq!(s.tasks().len(), 300);
    }
}
