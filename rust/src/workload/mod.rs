//! Workload construction: the paper's exact evaluation setup and
//! parameterised generators for scaling / robustness studies.
//!
//! * [`paper`] — Table I instance catalogue, the 3 x 250-task application
//!   mix and the budget sweep of Section V;
//! * [`generator`] — seeded random systems (apps, task-size
//!   distributions, instance catalogues, performance matrices) used by
//!   the property tests, the scaling benches and the coordinator demo
//!   traffic;
//! * [`scenario`] — named presets over the two above, selectable by name
//!   from the coordinator protocol (`"scenario"` field, `list_scenarios`)
//!   and the CLI (`--scenario`);
//! * [`traces`] — versioned, strictly schema-checked JSON traces: both
//!   replayable campaign-arrival streams ([`Trace`]) and the load
//!   generator's recorded traffic tapes ([`LoadTrace`]).

pub mod generator;
pub mod paper;
pub mod scenario;
pub mod traces;

pub use generator::{SizeDistribution, WorkloadGenerator, WorkloadSpec};
pub use scenario::{build_scenario, scenario_names, Scenario, SCENARIOS};
pub use traces::{replay, LoadEntry, LoadTrace, ReplayRow, Trace, TraceEntry, TRACE_VERSION};
