//! Workload construction: the paper's exact evaluation setup and
//! parameterised generators for scaling / robustness studies.
//!
//! * [`paper`] — Table I instance catalogue, the 3 x 250-task application
//!   mix and the budget sweep of Section V;
//! * [`generator`] — seeded random systems (apps, task-size
//!   distributions, instance catalogues, performance matrices) used by
//!   the property tests, the scaling benches and the coordinator demo
//!   traffic.

pub mod generator;
pub mod paper;
pub mod traces;

pub use generator::{SizeDistribution, WorkloadGenerator, WorkloadSpec};
pub use traces::{replay, ReplayRow, Trace, TraceEntry};
