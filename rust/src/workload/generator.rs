//! Seeded random workload generation.
//!
//! Produces [`System`]s with configurable application counts, task-size
//! distributions, instance catalogues and performance matrices.  Used by
//! the property tests (random problem instances), the scaling benches and
//! the coordinator's demo traffic.  Everything is deterministic given the
//! seed.

use crate::model::{BillingPolicy, System, SystemBuilder};
use crate::util::Rng;

/// Task-size distribution of one application.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizeDistribution {
    /// Integer sizes equally distributed over `[lo, hi]` (the paper's
    /// "equally distributed from 1 to 5").
    EquallySpaced { lo: u32, hi: u32 },
    /// Continuous uniform over `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// Log-normal (heavy-tailed sizes, common in real BoT traces).
    LogNormal { mu: f64, sigma: f64 },
}

impl SizeDistribution {
    fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            SizeDistribution::EquallySpaced { lo, hi } => rng.range(lo as i64, hi as i64) as f64,
            SizeDistribution::Uniform { lo, hi } => rng.uniform(lo, hi),
            SizeDistribution::LogNormal { mu, sigma } => rng.log_normal(mu, sigma).max(1e-3),
        }
    }
}

/// Parameters for a random system.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub n_apps: usize,
    pub n_types: usize,
    pub tasks_per_app: usize,
    pub sizes: SizeDistribution,
    /// Hourly price range for instance types.
    pub cost_range: (f64, f64),
    /// Base seconds-per-unit-size range; each (type, app) cell is the
    /// type's base speed times an app-specific affinity factor.
    pub perf_range: (f64, f64),
    /// Spread of per-app affinity around 1.0 (0.0 = uniform machines).
    pub affinity_spread: f64,
    pub overhead: f64,
    pub billing: BillingPolicy,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            n_apps: 3,
            n_types: 4,
            tasks_per_app: 100,
            sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            cost_range: (4.0, 12.0),
            perf_range: (8.0, 25.0),
            affinity_spread: 0.3,
            overhead: 0.0,
            billing: BillingPolicy::HourlyCeil,
        }
    }
}

/// Deterministic generator over a seed.
#[derive(Debug)]
pub struct WorkloadGenerator {
    rng: Rng,
}

impl WorkloadGenerator {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    /// Generate one system from the spec.  Retries internally in the
    /// (astronomically unlikely) event eq. 1 is violated by sampling.
    pub fn system(&mut self, spec: &WorkloadSpec) -> System {
        loop {
            if let Ok(sys) = self.try_system(spec) {
                return sys;
            }
        }
    }

    fn try_system(&mut self, spec: &WorkloadSpec) -> Result<System, crate::model::SystemError> {
        assert!(spec.n_apps >= 1 && spec.n_types >= 1 && spec.tasks_per_app >= 1);
        let mut b = SystemBuilder::new()
            .overhead(spec.overhead)
            .billing(spec.billing);
        for a in 0..spec.n_apps {
            let sizes: Vec<f64> =
                (0..spec.tasks_per_app).map(|_| spec.sizes.sample(&mut self.rng)).collect();
            b = b.app(&format!("app{a}"), sizes);
        }
        for t in 0..spec.n_types {
            let cost = self.rng.uniform(spec.cost_range.0, spec.cost_range.1);
            // Faster machines cost more: base speed anti-correlates with
            // price (plus noise), mirroring real catalogues.
            let price_pos = (cost - spec.cost_range.0)
                / (spec.cost_range.1 - spec.cost_range.0).max(1e-9);
            let base = spec.perf_range.1
                - price_pos * (spec.perf_range.1 - spec.perf_range.0)
                + self.rng.uniform(-1.0, 1.0);
            let base = base.max(0.5);
            let row: Vec<f64> = (0..spec.n_apps)
                .map(|_| {
                    let aff = 1.0 + self.rng.uniform(-spec.affinity_spread, spec.affinity_spread);
                    (base * aff).max(0.1)
                })
                .collect();
            b = b.instance_type(&format!("it{t}"), (cost * 100.0).round() / 100.0, row);
        }
        b.build()
    }

    /// A budget that is comfortably feasible for `sys` (around `factor`
    /// times the cheapest-possible fractional cost); useful for tests.
    pub fn feasible_budget(sys: &System, factor: f64) -> f64 {
        // Fractional lower bound: route each app's work to its most
        // cost-efficient type, ignore hour quantisation.
        let mut total = 0.0;
        for app in &sys.apps {
            let best = sys
                .instance_types
                .iter()
                .map(|it| sys.perf.get(it.id, app.id) * app.total_size() / sys.hour
                    * it.cost_per_hour)
                .fold(f64::INFINITY, f64::min);
            total += best;
        }
        (total * factor).ceil()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let spec = WorkloadSpec::default();
        let s1 = WorkloadGenerator::new(9).system(&spec);
        let s2 = WorkloadGenerator::new(9).system(&spec);
        assert_eq!(s1.tasks().len(), s2.tasks().len());
        for (a, b) in s1.tasks().iter().zip(s2.tasks()) {
            assert_eq!(a.size, b.size);
        }
        for it in &s1.instance_types {
            assert_eq!(it.cost_per_hour, s2.instance_types[it.id.index()].cost_per_hour);
        }
    }

    #[test]
    fn spec_dimensions_respected() {
        let spec = WorkloadSpec { n_apps: 5, n_types: 7, tasks_per_app: 13, ..Default::default() };
        let sys = WorkloadGenerator::new(1).system(&spec);
        assert_eq!(sys.n_apps(), 5);
        assert_eq!(sys.n_types(), 7);
        assert_eq!(sys.tasks().len(), 65);
    }

    #[test]
    fn distributions_produce_positive_sizes() {
        for dist in [
            SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            SizeDistribution::Uniform { lo: 0.5, hi: 9.0 },
            SizeDistribution::LogNormal { mu: 1.0, sigma: 0.8 },
        ] {
            let spec = WorkloadSpec { sizes: dist, ..Default::default() };
            let sys = WorkloadGenerator::new(2).system(&spec);
            assert!(sys.tasks().iter().all(|t| t.size > 0.0));
        }
    }

    #[test]
    fn feasible_budget_is_positive_and_scales() {
        let sys = crate::workload::paper::table1_system(0.0);
        let b1 = WorkloadGenerator::feasible_budget(&sys, 1.0);
        let b2 = WorkloadGenerator::feasible_budget(&sys, 2.0);
        assert!(b1 > 0.0);
        assert!(b2 >= b1 * 1.9);
        // Anchor: paper workload's fractional floor is ~58.3 (DESIGN.md).
        assert!((55.0..62.0).contains(&b1), "fractional floor {b1}");
    }
}
